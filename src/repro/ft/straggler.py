"""Straggler detection and mitigation hooks.

On a real pod, per-host step times are exchanged over an out-of-band
channel (or inferred from collective wait times); a persistent straggler
triggers mitigation: alerting, traffic re-balancing, or ejecting the host
and re-meshing (the elastic-restore path in repro.checkpoint).

In-process we implement the full detection logic against observed step
durations — EMA baseline + threshold ratio, consecutive-hit debouncing —
and a pluggable mitigation callback; the multi-host transport is the only
stubbed piece (documented per the brief).
"""

from __future__ import annotations

import collections
from typing import Callable, Optional

__all__ = ["StragglerMonitor"]


class StragglerMonitor:
    def __init__(self, *, window: int = 32, ratio: float = 1.5,
                 patience: int = 3,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.window = window
        self.ratio = ratio
        self.patience = patience
        self.on_straggler = on_straggler
        self.times = collections.deque(maxlen=window)
        self.hits = 0
        self.events = []

    def record(self, step: int, duration: float) -> bool:
        """Feed one step duration; returns True when mitigation fires."""
        if len(self.times) >= max(4, self.window // 4):
            baseline = sorted(self.times)[len(self.times) // 2]  # median
            if duration > self.ratio * baseline:
                self.hits += 1
                if self.hits >= self.patience:
                    self.events.append((step, duration))
                    self.hits = 0
                    if self.on_straggler is not None:
                        self.on_straggler(step, duration)
                    return True
            else:
                self.hits = 0
        self.times.append(duration)
        return False
