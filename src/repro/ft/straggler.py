"""Straggler detection and mitigation hooks.

On a real pod, per-host step times are exchanged over an out-of-band
channel (or inferred from collective wait times); a persistent straggler
triggers mitigation: alerting, traffic re-balancing, or ejecting the host
and re-meshing (the elastic-restore path in repro.checkpoint).

In-process we implement the full detection logic against observed step
durations — EMA baseline + threshold ratio, consecutive-hit debouncing —
and a pluggable mitigation callback; the multi-host transport is the only
stubbed piece (documented per the brief).
"""

from __future__ import annotations

import collections
from typing import Callable, Optional

__all__ = ["StragglerMonitor", "ReplicaHeartbeat"]


class StragglerMonitor:
    def __init__(self, *, window: int = 32, ratio: float = 1.5,
                 patience: int = 3,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.window = window
        self.ratio = ratio
        self.patience = patience
        self.on_straggler = on_straggler
        self.times = collections.deque(maxlen=window)
        self.hits = 0
        self.events = []

    def record(self, step: int, duration: float) -> bool:
        """Feed one step duration; returns True when mitigation fires."""
        if len(self.times) >= max(4, self.window // 4):
            baseline = sorted(self.times)[len(self.times) // 2]  # median
            if duration > self.ratio * baseline:
                self.hits += 1
                if self.hits >= self.patience:
                    self.events.append((step, duration))
                    self.hits = 0
                    if self.on_straggler is not None:
                        self.on_straggler(step, duration)
                    return True
            else:
                self.hits = 0
        self.times.append(duration)
        return False


class ReplicaHeartbeat:
    """alive → suspect → dead escalation with hysteresis over per-block
    health beats (the fleet's failure detector for one replica).

    The fleet feeds one beat per replica per fleet round: *healthy*
    means the replica made block progress (or was idle) and its block
    time was not flagged by its :class:`StragglerMonitor`.
    ``suspect_after`` consecutive unhealthy beats mark the replica
    SUSPECT (routing avoids it; its in-flight work stays put);
    ``dead_after`` mark it DEAD — terminal, its requests re-dispatch.
    Hysteresis both ways: a suspect returns to ALIVE only after
    ``recover_after`` consecutive healthy beats (one lucky block must
    not flap a struggling replica back into the routing set), and the
    unhealthy streak is only forgiven by a full recovery, so a replica
    alternating good and bad blocks still converges to DEAD instead of
    hovering at the suspect threshold forever.
    """

    def __init__(self, *, suspect_after: int = 2, dead_after: int = 4,
                 recover_after: int = 2):
        if (int(suspect_after) <= 0 or int(dead_after) <= 0
                or int(recover_after) <= 0):
            raise ValueError(
                f"heartbeat thresholds must be positive (got "
                f"suspect_after={suspect_after}, dead_after={dead_after}, "
                f"recover_after={recover_after}); a zero threshold would "
                f"declare a healthy replica suspect or dead on no evidence")
        if int(dead_after) <= int(suspect_after):
            raise ValueError(
                f"dead_after ({dead_after}) must exceed suspect_after "
                f"({suspect_after}): death must escalate from suspicion, "
                f"never race it")
        self.suspect_after = int(suspect_after)
        self.dead_after = int(dead_after)
        self.recover_after = int(recover_after)
        self.state = "alive"
        self._bad = 0
        self._good = 0

    def beat(self, healthy: bool) -> str:
        """Feed one health observation; returns the (possibly new)
        state, one of ``"alive"``/``"suspect"``/``"dead"``.  DEAD is
        terminal — a dead replica's journal may already be re-owned by
        a survivor, so it may never silently rejoin."""
        if self.state == "dead":
            return self.state
        if healthy:
            self._good += 1
            # one healthy beat forgives nothing — only ``recover_after``
            # consecutive ones clear the unhealthy streak (and, for a
            # suspect, restore routing eligibility)
            if self._good >= self.recover_after:
                self.state, self._bad, self._good = "alive", 0, 0
        else:
            self._bad += 1
            self._good = 0
            if self._bad >= self.dead_after:
                self.state = "dead"
            elif self._bad >= self.suspect_after:
                self.state = "suspect"
        return self.state
