"""Fault tolerance: recovery loop, straggler detection, serving chaos."""

from .recovery import FaultInjector, ResilientLoop
from .serving import (CRASH_KIND, FAULT_KINDS, FLEET_FAULT_KINDS,
                      FleetFaultInjector, InjectedCrash, InjectedFault,
                      PageCorruptionError, ServingFaultInjector)
from .straggler import ReplicaHeartbeat, StragglerMonitor

__all__ = ["FaultInjector", "ResilientLoop", "StragglerMonitor",
           "ReplicaHeartbeat", "ServingFaultInjector", "FleetFaultInjector",
           "InjectedFault", "InjectedCrash", "PageCorruptionError",
           "FAULT_KINDS", "CRASH_KIND", "FLEET_FAULT_KINDS"]
