"""Fault tolerance: recovery loop, straggler detection, serving chaos."""

from .recovery import FaultInjector, ResilientLoop
from .serving import (CRASH_KIND, FAULT_KINDS, InjectedCrash, InjectedFault,
                      PageCorruptionError, ServingFaultInjector)
from .straggler import StragglerMonitor

__all__ = ["FaultInjector", "ResilientLoop", "StragglerMonitor",
           "ServingFaultInjector", "InjectedFault", "InjectedCrash",
           "PageCorruptionError", "FAULT_KINDS", "CRASH_KIND"]
