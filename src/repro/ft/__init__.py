"""Fault tolerance: recovery loop, straggler detection, heartbeats."""

from .recovery import FaultInjector, ResilientLoop
from .straggler import StragglerMonitor

__all__ = ["FaultInjector", "ResilientLoop", "StragglerMonitor"]
