"""Fault tolerance: recovery loop, straggler detection, serving chaos."""

from .recovery import FaultInjector, ResilientLoop
from .serving import (FAULT_KINDS, InjectedFault, PageCorruptionError,
                      ServingFaultInjector)
from .straggler import StragglerMonitor

__all__ = ["FaultInjector", "ResilientLoop", "StragglerMonitor",
           "ServingFaultInjector", "InjectedFault", "PageCorruptionError",
           "FAULT_KINDS"]
