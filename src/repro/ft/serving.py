"""Serving-side fault injection: deterministic chaos for the engine.

The training stack already validates itself under perturbation
(``FaultInjector`` + ``ResilientLoop``); this module is the serving
counterpart.  A :class:`ServingFaultInjector` carries a *deterministic*
schedule keyed by the engine's decode-block round counter, so a chaos
run is exactly reproducible: the conformance suite asserts that every
scheduled fault sequence yields token streams byte-identical to the
fault-free run (the hls4ml codesign loop's validate-under-perturbation
step, applied to our own engine).

Fault kinds and their detection paths:

* ``"raise"`` — a step exception before the block runs (worker crash /
  transient runtime error).  Nothing was mutated; the engine replays
  the block from its pre-block snapshot.
* ``"nan"`` — every float leaf of the serving cache is poisoned with
  NaN *before* the block.  Detection is device-side: the fused decode
  loop's fault lane (``train.step``) watches for non-finite logits and
  freezes the affected slot, so the host learns about the corruption
  from the block result itself — no out-of-band signal.
* ``"corrupt"`` — page-pool / cache leaves are overwritten with large
  *finite* garbage before the block, and the injector raises
  :class:`PageCorruptionError` after it (the stand-in for a delayed
  integrity report — ECC / checksum — since finite garbage is
  undetectable from logits alone).  The block's results are discarded
  and replayed from the snapshot.
* ``"slow"`` — the injector sleeps before the block (a straggler step).
  No recovery: the wired-in ``StragglerMonitor`` flags the block and
  the event surfaces in ``Engine.stats()``.
* ``"crash"`` — process death mid-block: :class:`InjectedCrash` is
  raised before the block and deliberately does NOT subclass
  RuntimeError, so the engine's in-process restore-and-replay loop can
  never catch it (a dead process replays nothing).  The recovery path
  is *cross-process*: the test harness abandons the engine object,
  builds a fresh one, and rebuilds it from the durable journal +
  snapshot directory via ``Engine.recover`` — the warm-restart
  conformance suite asserts the rebuilt streams are byte-identical to
  the uninterrupted run.

Each scheduled fault fires exactly once (like the training injector's
``fired`` set), so a recovered replay of the same round runs clean.
``FAULT_KINDS`` lists the four *in-process* kinds the chaos matrix
cycles through; ``crash`` is scheduled the same way but recovered out
of process, so suites that assert "every FAULT_KIND is invisible
in-process" keep their meaning.
"""

from __future__ import annotations

import time
from typing import Dict, Iterable, Tuple, Union

__all__ = ["ServingFaultInjector", "FleetFaultInjector", "InjectedFault",
           "InjectedCrash", "PageCorruptionError", "FAULT_KINDS",
           "CRASH_KIND", "FLEET_FAULT_KINDS"]

FAULT_KINDS = ("raise", "nan", "corrupt", "slow")
#: recovered across processes (Engine.recover), not by in-process replay
CRASH_KIND = "crash"
#: fleet-level kinds (FleetFaultInjector; keyed by fleet round + replica)
FLEET_FAULT_KINDS = ("kill", "lag", "stall")


class InjectedFault(RuntimeError):
    """A scheduled step exception (transient worker failure)."""


class InjectedCrash(BaseException):
    """Scheduled process death.  A BaseException on purpose: nothing in
    the engine (or in driver code with a broad ``except Exception``)
    may swallow it — the only way past a crash is a fresh process and
    ``Engine.recover``."""


class PageCorruptionError(RuntimeError):
    """Delayed integrity report for finite page-pool corruption."""


class ServingFaultInjector:
    """Deterministic fault schedule over decode-block rounds.

    ``schedule`` maps a 1-based block round to a fault kind (or is an
    iterable of ``(round, kind)`` pairs — rounds may repeat across
    kinds but each (round, kind) fires once).  The engine calls
    ``before_block``/``after_block`` around every fused block; the
    injector mutates engine state or raises per the schedule.
    """

    def __init__(self, schedule: Union[Dict[int, str],
                                       Iterable[Tuple[int, str]]],
                 *, slow_s: float = 0.0):
        items = (schedule.items() if isinstance(schedule, dict)
                 else list(schedule))
        self.schedule = {}
        for rnd, kind in items:
            if kind not in FAULT_KINDS + (CRASH_KIND,):
                raise ValueError(f"unknown fault kind {kind!r} "
                                 f"(have {FAULT_KINDS + (CRASH_KIND,)})")
            self.schedule.setdefault(int(rnd), []).append(kind)
        self.slow_s = float(slow_s)
        self.fired = set()
        #: (round, kind) log of every fault actually injected
        self.events = []

    # -- engine hooks -------------------------------------------------------
    def before_block(self, rnd: int, engine) -> None:
        """Runs before the round's fused block; may corrupt or raise."""
        for kind in list(self.schedule.get(rnd, ())):
            key = (rnd, kind)
            if key in self.fired:
                continue
            if kind in ("nan", "corrupt") and not engine.live.any():
                # poison with nothing decoding would go undetected (no
                # logits carry it to the fault lane / integrity check)
                # and outlive the recovery snapshot — defer one round
                self.schedule[rnd].remove(kind)
                self.schedule.setdefault(rnd + 1, []).append(kind)
                continue
            self.fired.add(key)
            self.events.append(key)
            if kind == CRASH_KIND:
                # close the doomed engine's journal handle first: the
                # rebuilt engine reopens the same file, and an abandoned
                # open append handle should not linger on it
                j = getattr(engine, "_journal", None)
                if j is not None:
                    j.close()
                raise InjectedCrash(f"injected process death at block {rnd}")
            if kind == "raise":
                raise InjectedFault(f"injected step fault at block {rnd}")
            if kind == "slow":
                if self.slow_s > 0:
                    time.sleep(self.slow_s)
                # slow_s == 0: the engine's clock seam makes the block
                # *appear* slow instead (deterministic CI straggler)
                engine._injected_slow = True
            elif kind == "nan":
                engine._poison_cache(float("nan"))
            elif kind == "corrupt":
                engine._poison_cache(1e30)
                self._pending_corruption = rnd

    def after_block(self, rnd: int, engine) -> None:
        """Runs after the block: delayed detection of finite corruption."""
        if getattr(self, "_pending_corruption", None) == rnd:
            self._pending_corruption = None
            raise PageCorruptionError(
                f"page-pool integrity check failed after block {rnd}")


class FleetFaultInjector:
    """Deterministic fleet-level fault schedule, keyed by fleet round.

    ``schedule`` is an iterable of ``(round, replica, kind)`` triples
    (or a dict ``{round: (replica, kind)}``); rounds are 1-based fleet
    step rounds, and each scheduled triple fires exactly once.  Kinds
    (:data:`FLEET_FAULT_KINDS`):

    * ``"kill"`` — replica death at that block round.
      :class:`InjectedCrash` is raised out of the replica's step after
      its journal handle is closed, exactly like the engine-level crash
      kind.  The fleet's supervision catches the replica *dying under
      it* — death is detected, never announced.
    * ``"lag"`` — journal-shipping lag spike: the standby's tail apply
      is suppressed for the round (the replica index is ignored).  The
      fleet's bounded-lag promise must hold regardless, so a spike that
      would breach ``max_standby_lag`` forces a drain instead.
    * ``"stall"`` — routing-time stall: the replica makes no progress
      for the round and its block report is penalized, so the fleet's
      heartbeat sees exactly what a hung worker looks like.
    """

    def __init__(self, schedule):
        items = (((rnd,) + tuple(v) for rnd, v in schedule.items())
                 if isinstance(schedule, dict) else list(schedule))
        self.schedule: Dict[int, list] = {}
        for rnd, replica, kind in items:
            if kind not in FLEET_FAULT_KINDS:
                raise ValueError(f"unknown fleet fault kind {kind!r} "
                                 f"(have {FLEET_FAULT_KINDS})")
            self.schedule.setdefault(int(rnd), []).append(
                (None if replica is None else int(replica), kind))
        self.fired = set()
        #: (round, replica, kind) log of every fault actually injected
        self.events = []

    def lag_injected(self, rnd: int) -> bool:
        """True when a ``"lag"`` fault is scheduled for this round (and
        marks it fired).  Queried by the fleet before standby sync."""
        for replica, kind in self.schedule.get(rnd, ()):
            key = (rnd, replica, kind)
            if kind == "lag" and key not in self.fired:
                self.fired.add(key)
                self.events.append(key)
                return True
        return False

    def before_step(self, rnd: int, replica: int, engine) -> tuple:
        """Fire this round's faults against ``replica``; returns the
        non-fatal kinds that fired (``"stall"``) or raises for a kill."""
        kinds = []
        for rep, kind in self.schedule.get(rnd, ()):
            if kind == "lag" or rep != replica:
                continue
            key = (rnd, rep, kind)
            if key in self.fired:
                continue
            self.fired.add(key)
            self.events.append(key)
            if kind == "kill":
                j = getattr(engine, "_journal", None)
                if j is not None:
                    j.close()
                raise InjectedCrash(
                    f"injected death of replica {replica} at fleet "
                    f"round {rnd}")
            kinds.append(kind)
        return tuple(kinds)
