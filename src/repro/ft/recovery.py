"""Checkpoint/restart recovery loop with deterministic data replay.

At cluster scale the failure model is: a worker dies (hardware, preemption)
→ the job scheduler restarts the process set → everyone restores the last
complete checkpoint and replays the data stream from the stored step.  The
pieces that make this safe are all here or in neighbouring modules:

* checkpoints are atomic + retained (repro.checkpoint),
* the data pipeline is a pure function of step (repro.data) — replay needs
  no data-loader state,
* restore is elastic — a *different* mesh shape can adopt the checkpoint
  (repro.dist.sharding specs are recomputed for the new mesh).

``ResilientLoop`` packages that policy for the in-process failure modes we
can exercise in this container (exceptions, injected faults, NaN losses);
process-level death is covered by the same restore path at startup
(``examples/elastic_restart.py`` demonstrates both).
"""

from __future__ import annotations

import logging
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager

logger = logging.getLogger("repro.ft")

__all__ = ["FaultInjector", "ResilientLoop"]


class FaultInjector:
    """Deterministic fault schedule for tests/demos: raise at given steps."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


class ResilientLoop:
    """Run a train step function with checkpoint/restart semantics.

    ``step_fn(state, batch) -> (state, metrics)`` (jitted, donatable),
    ``batch_fn(step) -> batch`` (pure in step).
    """

    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 manager: CheckpointManager, *,
                 checkpoint_every: int = 50,
                 max_restores: int = 8,
                 fault_injector: Optional[FaultInjector] = None,
                 straggler=None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.manager = manager
        self.checkpoint_every = checkpoint_every
        self.max_restores = max_restores
        self.faults = fault_injector
        self.straggler = straggler
        self.restores = 0

    def run(self, state, *, start_step: int = 0, num_steps: int = 100,
            shardings=None, log_every: int = 0) -> Dict:
        """Returns {"state": final, "metrics": last, "restores": n}."""
        step = start_step
        metrics = {}
        while step < start_step + num_steps:
            try:
                if self.faults is not None:
                    self.faults.maybe_fail(step)
                t0 = time.perf_counter()
                batch = self.batch_fn(step)
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
                if self.straggler is not None:
                    self.straggler.record(step, time.perf_counter() - t0)
                step += 1
                if log_every and step % log_every == 0:
                    logger.info("step %d loss %.4f", step, loss)
                if step % self.checkpoint_every == 0:
                    self.manager.save(state, step)
            except (RuntimeError, FloatingPointError) as e:
                self.restores += 1
                logger.warning("fault at step %d (%s); restoring "
                               "(%d/%d)", step, e, self.restores,
                               self.max_restores)
                if self.restores > self.max_restores:
                    raise
                restored, ckpt_step = self.manager.restore_latest(
                    jax.tree_util.tree_map(np.asarray, state),
                    shardings=shardings)
                if restored is None:
                    raise RuntimeError("no checkpoint to restore") from e
                state, step = restored, ckpt_step
        self.manager.save(state, step, blocking=True)
        return {"state": state, "metrics": metrics, "restores": self.restores,
                "step": step}
