"""Pure-jnp oracles for every Pallas kernel (the "portable C++" lowering).

Each function here is the numerics contract: kernels in this package must
match these to tight tolerances across shape/dtype sweeps (see
tests/test_kernels_*.py).  They are also the ``ref`` backend registered in
:mod:`repro.core.registry` — portability means these always work, on any
XLA backend, with no Pallas/Mosaic dependency.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tables import TableSpec, get_table, table_lookup

__all__ = ["lut_activation_ref", "qmatmul_ref", "flash_attention_ref",
           "paged_attention_ref", "paged_attention_split_ref",
           "sample_tokens_ref", "verify_tokens_ref"]


def lut_activation_ref(x: jnp.ndarray, spec: TableSpec) -> jnp.ndarray:
    """Table-lookup activation: gather from a trace-time constant table."""
    table = get_table(spec)
    return table_lookup(x, jnp.asarray(table.np_values), spec.lo, spec.hi,
                        spec.indexing)


def qmatmul_ref(a_data: jnp.ndarray, b_data: jnp.ndarray,
                a_scale: jnp.ndarray, b_scale: jnp.ndarray,
                bias: Optional[jnp.ndarray] = None,
                out_dtype=jnp.float32, *,
                act_spec: Optional[TableSpec] = None,
                act_gated: bool = False) -> jnp.ndarray:
    """Quantized matmul oracle: int8 × int8 → int32 accumulate → rescale,
    plus the optional fused epilogue (bias add + LUT activation) as the
    explicit three-op composition the Pallas kernel fuses.

    ``a_data``: (M, K) int8, row scales ``a_scale``: (M, 1) or scalar.
    ``b_data``: (K, N) int8, col scales ``b_scale``: (1, N) or scalar.
    ``bias``: optional (N,)/(1, N) added after dequantization.
    ``act_spec``: optional LUT activation table; ``act_gated=True``
    computes ``y * table(y)`` (exact gated silu/gelu form).
    Result: (M, N) in ``out_dtype`` ≈ act((a·sa) @ (b·sb) + bias).
    """
    acc = jax.lax.dot_general(
        a_data, b_data, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * a_scale * b_scale
    if bias is not None:
        y = y + jnp.asarray(bias, jnp.float32).reshape(1, -1)
    if act_spec is not None:
        z = lut_activation_ref(y, act_spec)
        y = y * z if act_gated else z
    return y.astype(out_dtype)


def paged_attention_split_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                              v_pages: jnp.ndarray,
                              block_tables: jnp.ndarray,
                              qpos: jnp.ndarray, *,
                              softmax_scale: Optional[float] = None,
                              kv_split: int = 1,
                              pages_per_step: int = 1) -> jnp.ndarray:
    """Split-KV oracle: the flash-decoding recurrence, op for op.

    Mirrors :func:`repro.kernels.flash_attention._paged_split_kernel`
    exactly — same tile order, same ``-1e30`` masking, same online
    ``(m, l, acc)`` update per multi-page tile, and the SAME
    :func:`~repro.kernels.flash_attention.combine_splits` merge (the
    shared-formula rule: a re-derived merge — say log-space addition —
    would drift far beyond ulps) — so the interpret-mode kernel must
    match it to f32 ulp precision (rtol 3e-7, ~100x tighter than the
    kernel suite's 2e-5 tolerance) at every ``(kv_split,
    pages_per_step)`` point.  Bitwise identity is NOT promised across
    the pair: XLA contracts the exp/multiply-add chains differently in
    separately compiled programs, worth ~1 ulp.  Where the kernel
    *skips* a fully-invisible tile, this oracle computes it and masks:
    the update then degenerates to the exact identity (``alpha =
    exp(0)``, all-zero probabilities), which is the property the skip
    relies on.

    The (b, h) python loops make it an eager-test oracle, not a
    serving path; :func:`paged_attention_ref` (the registered ``ref``
    backend) stays the vectorized softmax formula, which this function
    must agree with to tolerance (asserted in tests/test_split_kv.py).
    """
    from .flash_attention import combine_splits
    b, hq, s, d = q.shape
    p_, hkv, ps, _ = k_pages.shape
    np_ = block_tables.shape[1]
    group = hq // hkv
    assert hq % hkv == 0
    rows = group * s
    scale = (softmax_scale if softmax_scale is not None
             else float(1.0 / np.sqrt(d)))

    t = max(1, min(int(pages_per_step), np_))
    tiles = -(-np_ // t)
    split = max(1, min(int(kv_split), tiles))
    nt = -(-tiles // split)
    np_pad = split * nt * t
    bt = jnp.asarray(block_tables, jnp.int32)
    if np_pad > np_:
        bt = jnp.pad(bt, ((0, 0), (0, np_pad - np_)))
    qf = q.reshape(b, hkv, group, s, d).reshape(b, hkv, rows, d)
    qpos = jnp.asarray(qpos, jnp.int32)

    acc_p = np.empty((split, b, hkv), dtype=object)
    m_p = np.empty((split, b, hkv), dtype=object)
    l_p = np.empty((split, b, hkv), dtype=object)
    for sp in range(split):
        for bi in range(b):
            for hi in range(hkv):
                qbh = qf[bi, hi].astype(jnp.float32) * scale
                m = jnp.full((rows, 1), -1e30, jnp.float32)
                l = jnp.zeros((rows, 1), jnp.float32)
                acc = jnp.zeros((rows, d), jnp.float32)
                for it in range(nt):
                    base = (sp * nt + it) * t
                    k = jnp.concatenate(
                        [k_pages[bt[bi, base + j], hi].astype(jnp.float32)
                         for j in range(t)], axis=0)
                    logits = jax.lax.dot_general(
                        qbh, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32)
                    r = jax.lax.broadcasted_iota(jnp.int32, (rows, t * ps),
                                                 0)
                    qp = qpos[bi] + jax.lax.rem(r, s)
                    kvpos = base * ps + jax.lax.broadcasted_iota(
                        jnp.int32, (rows, t * ps), 1)
                    mask = kvpos <= qp
                    logits = jnp.where(mask, logits, -1e30)
                    m_new = jnp.maximum(
                        m, jnp.max(logits, axis=1, keepdims=True))
                    p = jnp.exp(logits - m_new)
                    p = jnp.where(mask, p, 0.0)
                    alpha = jnp.exp(m - m_new)
                    l = alpha * l + jnp.sum(p, axis=1, keepdims=True)
                    v = jnp.concatenate(
                        [v_pages[bt[bi, base + j], hi].astype(jnp.float32)
                         for j in range(t)], axis=0)
                    acc = alpha * acc + jax.lax.dot_general(
                        p, v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)
                    m = m_new
                acc_p[sp, bi, hi], m_p[sp, bi, hi], l_p[sp, bi, hi] = \
                    acc, m, l

    def stack(cells):
        return jnp.stack([jnp.stack([jnp.stack(list(cells[sp, bi]))
                                     for bi in range(b)])
                          for sp in range(split)])

    acc_star, _, l_star = combine_splits(stack(acc_p), stack(m_p),
                                         stack(l_p))
    out = acc_star / jnp.maximum(l_star, 1e-30)
    return out.astype(q.dtype).reshape(b, hkv, group, s, d) \
              .reshape(b, hq, s, d)


def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                        qpos: jnp.ndarray, *,
                        softmax_scale: Optional[float] = None,
                        kv_split: Optional[int] = None,
                        pages_per_step: Optional[int] = None) -> jnp.ndarray:
    """Block-table-indexed attention oracle (decode and chunked prefill).

    The de-specialized serving layout: K/V live in a shared pool of
    fixed-size pages and each sequence owns an ordered list of page ids
    (its *block table*) instead of a contiguous ``max_len`` buffer.
    Logical kv position ``t`` of batch row ``b`` lives at physical page
    ``block_tables[b, t // page_size]``, row ``t % page_size``.

    * ``q``: (B, Hq, S, D) — S == 1 is decode, S > 1 a prefill chunk.
    * ``k_pages``/``v_pages``: (P, Hkv, page_size, D) shared page pool
      (Hq % Hkv == 0; grouped KV is gathered, never broadcast).
    * ``block_tables``: (B, NP) int32 page ids; entries beyond a
      sequence's allocation may point anywhere — they are masked.
    * ``qpos``: (B,) int32 — tokens already in the cache before this
      call, i.e. query row ``i`` of batch ``b`` sits at absolute
      position ``qpos[b] + i``.  Visibility is causal over absolute
      positions (``kvpos <= qpos[b] + i``), assuming the current chunk's
      K/V were scattered into the pages *before* the call
      (write-before-attend, the serving cache contract).

    Returns (B, Hq, S, D).  Masked positions use a finite ``-1e30``
    (exactly-zero softmax weight), so garbage in unallocated /
    not-yet-written page rows can never leak — including freshly
    recycled pages still holding a previous request's KV.

    ``kv_split``/``pages_per_step`` > 1 route through
    :func:`paged_attention_split_ref` — the explicit flash-decoding
    recurrence + log-sum-exp combine that the split Pallas kernel must
    match bit-for-bit.  Unset (None/1) keeps this function's one-shot
    softmax formula: the ``ref`` backend never needs the latency knob,
    only the semantics.
    """
    if (kv_split or 1) > 1 or (pages_per_step or 1) > 1:
        return paged_attention_split_ref(
            q, k_pages, v_pages, block_tables, qpos,
            softmax_scale=softmax_scale, kv_split=kv_split or 1,
            pages_per_step=pages_per_step or 1)
    b, hq, s, d = q.shape
    p_, hkv, page_size, _ = k_pages.shape
    np_ = block_tables.shape[1]
    group = hq // hkv
    assert hq % hkv == 0
    scale = (softmax_scale if softmax_scale is not None
             else 1.0 / np.sqrt(d))

    def gather(pages):                       # (P, Hkv, ps, D) -> contiguous
        g = pages[block_tables]              # (B, NP, Hkv, ps, D)
        return g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, np_ * page_size,
                                                  pages.shape[-1])

    k = gather(k_pages).astype(jnp.float32)
    v = gather(v_pages).astype(jnp.float32)
    qg = q.reshape(b, hkv, group, s, d).astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k) * scale
    kvpos = jnp.arange(np_ * page_size)[None, None, :]
    visible = kvpos <= (qpos[:, None] + jnp.arange(s)[None, :])[:, :, None]
    logits = jnp.where(visible[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, v)
    return out.reshape(b, hq, s, v.shape[-1]).astype(q.dtype)


def sample_tokens_ref(logits: jnp.ndarray, temperature: jnp.ndarray,
                      top_k: jnp.ndarray, key=None) -> jnp.ndarray:
    """Token-sampling oracle: (B, V) logits -> (B,) int32 ids.

    Matches :func:`repro.kernels.sampling.sample_tokens_fused` exactly,
    ties included.  NOTE the limits of this oracle: exact-match testing
    forces both lowerings to share the noise source
    (:func:`~repro.kernels.sampling.gumbel_noise`) and the rank-based
    tie convention (stable argsort; a value threshold would admit > k
    candidates on tied logits), so this checks the *composition* —
    masking, temperature scaling, greedy overrides — not the shared
    draw itself.  The semantic properties of the draw (tokens in range,
    inside the top-k set, greedy == argmax, spread under temperature)
    are asserted independently in tests/test_sampling.py.
    """
    from .sampling import gumbel_noise
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        return greedy
    temperature = jnp.asarray(temperature, jnp.float32).reshape(b)
    top_k = jnp.asarray(top_k, jnp.int32).reshape(b)

    # rank 0 = the largest logit in its row; candidate iff rank < k
    order = jnp.argsort(-logits, axis=-1)                       # (B, V)
    ranks = jnp.argsort(order, axis=-1)                         # (B, V)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    candidate = ranks < k_eff[:, None]

    temp = jnp.maximum(temperature, 1e-6)[:, None]
    perturbed = jnp.where(candidate, logits / temp, -jnp.inf) \
        + gumbel_noise(key, (b, v))
    sampled = jnp.argmax(perturbed, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


def verify_tokens_ref(logits: jnp.ndarray, draft: jnp.ndarray,
                      temperature: jnp.ndarray, top_k: jnp.ndarray,
                      key=None):
    """Draft-verification oracle: (B, S, V) × (B, S-1) -> (next, n_adv).

    Matches :func:`repro.kernels.speculative.verify_tokens_fused`
    bit-for-bit.  NOTE the limits of this oracle (same stance as
    ``sample_tokens_ref``): the stochastic pieces — noise
    (:func:`~repro.kernels.speculative.verify_noise`), temperature/top-k
    restriction, softmax, Gumbel perturbation — must be *shared*
    formulas, because a last-ulp difference in a probability or a
    perturbed logit flips a borderline accept/argmax and exact-match
    testing would be flaky-by-seed.  What IS independently re-derived is
    the verification composition this op exists for: an explicit
    per-position python loop carrying the "chain still alive" flag (vs
    the fused cumprod), per-position residual masking and commit
    selection.  The semantic properties (greedy chain == argmax chain,
    n_adv bounds, committed-token validity) are asserted independently
    in tests/test_speculative.py.
    """
    from .speculative import verify_noise
    logits = logits.astype(jnp.float32)
    b, s, v = logits.shape
    k = s - 1
    draft = draft.astype(jnp.int32)
    greedy_t = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    if key is None:
        accept = draft == greedy_t[:, :k]
        t_full = greedy_t
    else:
        temperature = jnp.asarray(temperature, jnp.float32).reshape(b)
        top_k = jnp.asarray(top_k, jnp.int32).reshape(b)
        order = jnp.argsort(-logits, axis=-1)
        ranks = jnp.argsort(order, axis=-1)
        k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
        candidate = ranks < k_eff[:, None, None]
        temp = jnp.maximum(temperature, 1e-6)[:, None, None]
        scaled = jnp.where(candidate, logits / temp, -jnp.inf)
        probs = jax.nn.softmax(scaled, axis=-1)

        u, g_resample, g_bonus = verify_noise(key, b, k, v)
        cols = []
        accepts = []
        for j in range(k):
            p_d = probs[jnp.arange(b), j, draft[:, j]]
            accepts.append(u[:, j] < p_d)
            res = jnp.where(jnp.arange(v)[None, :] == draft[:, j, None],
                            -jnp.inf, scaled[:, j])
            cols.append(jnp.argmax(res + g_resample[:, j], axis=-1))
        bonus = jnp.argmax(scaled[:, k] + g_bonus, axis=-1)
        t_sampled = jnp.stack(cols + [bonus], axis=1).astype(jnp.int32)

        is_greedy = (temperature <= 0)[:, None]
        accept = jnp.where(is_greedy, draft == greedy_t[:, :k],
                           jnp.stack(accepts, axis=1))
        t_full = jnp.where(is_greedy, greedy_t, t_sampled)

    alive = jnp.ones((b,), bool)
    n_accept = jnp.zeros((b,), jnp.int32)
    for j in range(k):
        alive = alive & accept[:, j]
        n_accept = n_accept + alive.astype(jnp.int32)
    next_token = jnp.take_along_axis(t_full, n_accept[:, None], axis=1)[:, 0]
    return next_token, (n_accept + 1).astype(jnp.int32)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        bias: Optional[jnp.ndarray] = None,
                        softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """Plain attention oracle with f32 softmax accumulation.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        logits = logits + bias.reshape(b, hkv, group, sq, skv)
    if causal:
        # queries are the last sq positions of the skv-long context
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        mask = qpos >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)
