"""Pure-jnp oracles for every Pallas kernel (the "portable C++" lowering).

Each function here is the numerics contract: kernels in this package must
match these to tight tolerances across shape/dtype sweeps (see
tests/test_kernels_*.py).  They are also the ``ref`` backend registered in
:mod:`repro.core.registry` — portability means these always work, on any
XLA backend, with no Pallas/Mosaic dependency.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tables import TableSpec, get_table, table_lookup

__all__ = ["lut_activation_ref", "qmatmul_ref", "flash_attention_ref",
           "sample_tokens_ref"]


def lut_activation_ref(x: jnp.ndarray, spec: TableSpec) -> jnp.ndarray:
    """Table-lookup activation: gather from a trace-time constant table."""
    table = get_table(spec)
    return table_lookup(x, jnp.asarray(table.np_values), spec.lo, spec.hi,
                        spec.indexing)


def qmatmul_ref(a_data: jnp.ndarray, b_data: jnp.ndarray,
                a_scale: jnp.ndarray, b_scale: jnp.ndarray,
                bias: Optional[jnp.ndarray] = None,
                out_dtype=jnp.float32, *,
                act_spec: Optional[TableSpec] = None,
                act_gated: bool = False) -> jnp.ndarray:
    """Quantized matmul oracle: int8 × int8 → int32 accumulate → rescale,
    plus the optional fused epilogue (bias add + LUT activation) as the
    explicit three-op composition the Pallas kernel fuses.

    ``a_data``: (M, K) int8, row scales ``a_scale``: (M, 1) or scalar.
    ``b_data``: (K, N) int8, col scales ``b_scale``: (1, N) or scalar.
    ``bias``: optional (N,)/(1, N) added after dequantization.
    ``act_spec``: optional LUT activation table; ``act_gated=True``
    computes ``y * table(y)`` (exact gated silu/gelu form).
    Result: (M, N) in ``out_dtype`` ≈ act((a·sa) @ (b·sb) + bias).
    """
    acc = jax.lax.dot_general(
        a_data, b_data, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * a_scale * b_scale
    if bias is not None:
        y = y + jnp.asarray(bias, jnp.float32).reshape(1, -1)
    if act_spec is not None:
        z = lut_activation_ref(y, act_spec)
        y = y * z if act_gated else z
    return y.astype(out_dtype)


def sample_tokens_ref(logits: jnp.ndarray, temperature: jnp.ndarray,
                      top_k: jnp.ndarray, key=None) -> jnp.ndarray:
    """Token-sampling oracle: (B, V) logits -> (B,) int32 ids.

    Matches :func:`repro.kernels.sampling.sample_tokens_fused` exactly,
    ties included.  NOTE the limits of this oracle: exact-match testing
    forces both lowerings to share the noise source
    (:func:`~repro.kernels.sampling.gumbel_noise`) and the rank-based
    tie convention (stable argsort; a value threshold would admit > k
    candidates on tied logits), so this checks the *composition* —
    masking, temperature scaling, greedy overrides — not the shared
    draw itself.  The semantic properties of the draw (tokens in range,
    inside the top-k set, greedy == argmax, spread under temperature)
    are asserted independently in tests/test_sampling.py.
    """
    from .sampling import gumbel_noise
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        return greedy
    temperature = jnp.asarray(temperature, jnp.float32).reshape(b)
    top_k = jnp.asarray(top_k, jnp.int32).reshape(b)

    # rank 0 = the largest logit in its row; candidate iff rank < k
    order = jnp.argsort(-logits, axis=-1)                       # (B, V)
    ranks = jnp.argsort(order, axis=-1)                         # (B, V)
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    candidate = ranks < k_eff[:, None]

    temp = jnp.maximum(temperature, 1e-6)[:, None]
    perturbed = jnp.where(candidate, logits / temp, -jnp.inf) \
        + gumbel_noise(key, (b, v))
    sampled = jnp.argmax(perturbed, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        bias: Optional[jnp.ndarray] = None,
                        softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """Plain attention oracle with f32 softmax accumulation.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0 (GQA).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(d)
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        logits = logits + bias.reshape(b, hkv, group, sq, skv)
    if causal:
        # queries are the last sq positions of the skv-long context
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        mask = qpos >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)
