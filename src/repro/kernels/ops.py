"""Public kernel API with backend dispatch (the de-specialized interface).

Every op is registered under the backends it supports; callers use these
wrappers (or the registry directly) and never import a specific lowering.
On CPU hosts the ``pallas`` backend automatically runs in interpret mode,
which executes the kernel body in Python — the portability story the paper
asks for: one interface, ``ref`` everywhere, specialization where the
hardware exists.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.registry import get_impl, register_op
from ..core.tables import TableSpec
from . import ref as _ref
from .flash_attention import (flash_attention_pallas, paged_attention_pallas,
                              paged_attention_xla)
from .lut_activation import lut_activation_pallas
from .qmatmul import qmatmul_pallas
from .sampling import sample_tokens_fused
from .speculative import verify_tokens_fused

__all__ = ["lut_activation", "qmatmul", "attention", "paged_attention",
           "sample_tokens", "verify_tokens"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# -- registrations ---------------------------------------------------------
register_op("lut_activation", "ref")(_ref.lut_activation_ref)


@register_op("lut_activation", "pallas")
def _lut_pallas(x, spec: TableSpec, **kw):
    return lut_activation_pallas(x, spec, interpret=_interpret(), **kw)


register_op("qmatmul", "ref")(_ref.qmatmul_ref)


@register_op("qmatmul", "pallas")
def _qmatmul_pallas(a, b, sa, sb, bias=None, out_dtype=jnp.float32, **kw):
    return qmatmul_pallas(a, b, sa, sb, bias, out_dtype=out_dtype,
                          interpret=_interpret(), **kw)


register_op("sample_tokens", "ref")(_ref.sample_tokens_ref)

# the specialized lowering is an XLA fusion rather than a pallas_call:
# sampling reads (B, V) floats once, so the win is living inside the
# decode jit (token never leaves the device), not a custom kernel.
register_op("sample_tokens", "pallas")(sample_tokens_fused)


register_op("verify_tokens", "ref")(_ref.verify_tokens_ref)

# same stance as sample_tokens: verification touches (B, S, V) floats
# once — the value is running INSIDE the fused decode scan (accepted
# lengths and the rewound position never leave the device), so the
# specialized lowering is an XLA fusion, not a pallas_call.
register_op("verify_tokens", "pallas")(verify_tokens_fused)


register_op("attention", "ref")(_ref.flash_attention_ref)


register_op("paged_attention", "ref")(_ref.paged_attention_ref)

# third lowering: the split-KV *schedule* (scan over page tiles,
# partition axis batched, log-sum-exp combine) through plain XLA — the
# portable way to run/measure the flash-decoding schedule on non-TPU
# hosts, and the serial-chain baseline (split=1, tile=1) the
# long-context bench compares against.
register_op("paged_attention", "xla")(paged_attention_xla)


@register_op("paged_attention", "pallas")
def _paged_attention_pallas(q, k_pages, v_pages, block_tables, qpos, *,
                            softmax_scale=None, **kw):
    return paged_attention_pallas(q, k_pages, v_pages, block_tables, qpos,
                                  softmax_scale=softmax_scale,
                                  interpret=_interpret(), **kw)


#: re-exported tuning helpers (the reuse-factor knob's cost model and
#: the split-merge formula shared with the ref oracle)
from .flash_attention import (auto_pages_per_step, choose_kv_split,  # noqa: E402
                              combine_splits)


@register_op("attention", "pallas")
def _attention_pallas(q, k, v, *, causal=True, softmax_scale=None, **kw):
    return flash_attention_pallas(q, k, v, causal=causal,
                                  softmax_scale=softmax_scale,
                                  interpret=_interpret(), **kw)


# -- public wrappers -------------------------------------------------------
def lut_activation(x: jnp.ndarray, spec: TableSpec, *,
                   backend: Optional[str] = None, **kw) -> jnp.ndarray:
    return get_impl("lut_activation", backend)(x, spec, **kw)


def qmatmul(a_data, b_data, a_scale, b_scale, *, bias=None,
            act_spec: Optional[TableSpec] = None, act_gated: bool = False,
            out_dtype=jnp.float32, backend: Optional[str] = None,
            **kw) -> jnp.ndarray:
    """Quantized matmul with optional fused epilogue (bias + LUT act).

    With ``bias``/``act_spec`` set, linear + bias + activation execute as
    ONE kernel launch (one HBM pass) instead of three — the Pallas
    analogue of hls4ml's dense→activation dataflow fusion.
    """
    kw = dict(kw)
    if bias is not None:
        kw["bias"] = bias
    if act_spec is not None:
        kw.update(act_spec=act_spec, act_gated=act_gated)
    return get_impl("qmatmul", backend)(a_data, b_data, a_scale, b_scale,
                                        out_dtype=out_dtype, **kw)


def attention(q, k, v, *, causal: bool = True, softmax_scale=None,
              backend: Optional[str] = None, **kw) -> jnp.ndarray:
    return get_impl("attention", backend)(q, k, v, causal=causal,
                                          softmax_scale=softmax_scale, **kw)


def paged_attention(q, k_pages, v_pages, block_tables, qpos, *,
                    softmax_scale=None, kv_split: Optional[int] = None,
                    pages_per_step: Optional[int] = None,
                    backend: Optional[str] = None, **kw) -> jnp.ndarray:
    """Attention over a block-table-indexed KV page pool.

    q (B, Hq, S, D) against k/v pages (P, Hkv, page_size, D) addressed
    through ``block_tables`` (B, NP), with causal visibility over
    absolute positions ``qpos[b] + i`` (write-before-attend).  S == 1 is
    the decode step, S > 1 a chunked-prefill step — one op serves both,
    which is what lets the serving engine admit mixed prefill/decode
    batches over one shared pool.

    ``kv_split`` / ``pages_per_step`` — the kernel-level reuse-factor
    knob (see :func:`repro.kernels.flash_attention.choose_kv_split`):
    the Pallas lowering cuts each slot's block table into ``kv_split``
    parallel flash-decoding partitions merged by a log-sum-exp combine,
    fetching ``pages_per_step`` pages per grid step.  ``None`` = pick
    from the cached cost model.  The ``ref`` backend is knob-invariant
    by construction: it only switches to the explicit split recurrence
    when a knob is set > 1 (the oracle the kernel is tested against).
    """
    if kv_split is not None:
        kw["kv_split"] = kv_split
    if pages_per_step is not None:
        kw["pages_per_step"] = pages_per_step
    return get_impl("paged_attention", backend)(
        q, k_pages, v_pages, block_tables, qpos,
        softmax_scale=softmax_scale, **kw)


def sample_tokens(logits, temperature, top_k, key=None, *,
                  backend: Optional[str] = None) -> jnp.ndarray:
    """Per-slot next-token draw: (B, V) logits -> (B,) int32 ids.

    ``temperature`` (B,) f32 (<= 0 means greedy) and ``top_k`` (B,) i32
    (<= 0 means unrestricted) are *per slot*, so one fused decode batch
    can mix greedy and sampled requests.  Deterministic in ``key``
    across jit/scan boundaries — see :mod:`repro.kernels.sampling`.
    """
    return get_impl("sample_tokens", backend)(logits, temperature, top_k,
                                              key)


def verify_tokens(logits, draft, temperature, top_k, key=None, *,
                  backend: Optional[str] = None):
    """Speculative acceptance rule: (B, S, V) target logits over a
    drafted block × (B, S-1) draft ids -> (next_token (B,),
    n_advance (B,) in [1, S]).

    Greedy slots (temperature <= 0) accept the longest draft prefix
    that matches the argmax chain — committed output is byte-identical
    to non-speculative decode.  Sampled slots run point-mass rejection
    sampling, preserving the temperature/top-k output distribution.
    Deterministic in ``key`` across jit/scan boundaries — see
    :mod:`repro.kernels.speculative`.
    """
    return get_impl("verify_tokens", backend)(logits, draft, temperature,
                                              top_k, key)
