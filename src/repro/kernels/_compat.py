"""Small jax-version compatibility shims for the Pallas kernels.

The kernels target the current Pallas API names; older pinned jax
releases (e.g. 0.4.x, where ``pltpu.CompilerParams`` is still
``TPUCompilerParams``) are mapped here so the kernel code stays clean.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
