"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three artifacts (per the de-specialization discipline):

* ``<name>.py`` — the Pallas lowering (``pl.pallas_call`` + BlockSpec),
* ``ref.py``    — the pure-jnp oracle (numerics contract + CPU fallback),
* ``ops.py``    — the backend-dispatched public wrapper.

The split-KV helpers (``choose_kv_split``, ``auto_pages_per_step``,
``combine_splits``) are exported alongside the ops: they are the
reuse-factor knob's cost model and the partial-merge formula shared
between the Pallas lowering and the ref oracle.
"""

from .ops import (attention, auto_pages_per_step, choose_kv_split,
                  combine_splits, lut_activation, paged_attention, qmatmul,
                  sample_tokens, verify_tokens)

__all__ = ["attention", "auto_pages_per_step", "choose_kv_split",
           "combine_splits", "lut_activation", "paged_attention", "qmatmul",
           "sample_tokens", "verify_tokens"]
