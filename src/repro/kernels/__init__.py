"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three artifacts (per the de-specialization discipline):

* ``<name>.py`` — the Pallas lowering (``pl.pallas_call`` + BlockSpec),
* ``ref.py``    — the pure-jnp oracle (numerics contract + CPU fallback),
* ``ops.py``    — the backend-dispatched public wrapper.
"""

from .ops import (attention, lut_activation, paged_attention, qmatmul,
                  sample_tokens, verify_tokens)

__all__ = ["attention", "lut_activation", "paged_attention", "qmatmul",
           "sample_tokens", "verify_tokens"]
