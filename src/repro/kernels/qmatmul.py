"""Pallas TPU kernel: int8 × int8 → int32 quantized matmul with fused
requantization and an optional fused epilogue (bias + LUT activation).

The MXU adaptation of the paper's fixed-point datapath: on FPGA the
``ac_fixed`` multiply-accumulates map to DSP slices; on TPU the analogous
hard resource is the MXU's native int8 systolic path with int32
accumulation.  The kernel tiles (M, N, K) into MXU-aligned blocks
(multiples of 128), accumulates partial products in an int32 VMEM scratch
across the K grid dimension, and fuses the dequantization (per-row ×
per-column scales) into the final K step — so the narrow int8 operands are
what moves through HBM→VMEM, which is the entire bandwidth win of
quantization.

**Fused epilogue** (the hls4ml dense→activation dataflow fusion, ported):
hls4ml's win is that dense output never round-trips through memory before
the activation LUT — the fixed-point result streams straight into the
BRAM table.  Here the same fusion happens in the final K step: while the
(bm, bn) accumulator tile is still VMEM-resident, the kernel optionally

* adds a per-column ``bias`` row, and
* applies a LUT activation (a :class:`~repro.core.tables.TableSpec`
  constant table riding in VMEM, gathered on the VPU; ``act_gated=True``
  computes ``y * table(y)`` — the exact gated silu/gelu form).

One ``pallas_call`` therefore replaces three kernel launches (matmul →
bias add → LUT activation) and two (M, N) HBM round trips of the f32
intermediate.  The pre-quantized serving path
(:func:`repro.core.quantize.ptq_params` → QTensor weights →
:func:`repro.nn.linear.linear`) lands here with zero per-forward weight
quantization work.

VMEM working set per grid step: bm*bk + bk*bn (int8) + bm*bn*4 (acc)
+ bm*bn*out bytes (+ bn*4 bias + 4*n table when fused).  Defaults
(256, 256, 256) → ~0.5 MiB, comfortably inside the ~16 MiB v5e VMEM with
double-buffering headroom; a 1024-entry table adds 4 KiB.

The ``reuse_factor`` knob from the paper maps here: larger ``bk`` = more
MACs per loaded block (lower "reuse", more parallel resource/VMEM), smaller
``bk`` = the same MXU tile re-used across more sequential K steps.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.tables import TableSpec, get_table
from .lut_activation import apply_table

__all__ = ["qmatmul_pallas"]


def _kernel(*refs, k_steps: int, has_bias: bool, act_spec, act_gated: bool):
    a_ref, b_ref, sa_ref, sb_ref = refs[:4]
    rest = list(refs[4:])
    bias_ref = rest.pop(0) if has_bias else None
    t_ref = rest.pop(0) if act_spec is not None else None
    o_ref, acc_ref = rest

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _finish():
        sa = sa_ref[...]            # (bm, 1) f32
        sb = sb_ref[...]            # (1, bn) f32
        y = acc_ref[...].astype(jnp.float32) * sa * sb
        if has_bias:
            y = y + bias_ref[...]   # (1, bn) f32
        if act_spec is not None:    # LUT epilogue on the VMEM-resident tile
            y = apply_table(y, t_ref[...], lo=act_spec.lo,
                            step_inv=1.0 / act_spec.step, n=act_spec.n,
                            indexing=act_spec.indexing, gated=act_gated)
        o_ref[...] = y.astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("out_dtype", "bm", "bn", "bk",
                                             "act_spec", "act_gated",
                                             "interpret"))
def qmatmul_pallas(a_data: jnp.ndarray, b_data: jnp.ndarray,
                   a_scale: jnp.ndarray, b_scale: jnp.ndarray,
                   bias: Optional[jnp.ndarray] = None,
                   *, out_dtype=jnp.float32,
                   act_spec: Optional[TableSpec] = None,
                   act_gated: bool = False,
                   bm: int = 256, bn: int = 256,
                   bk: int = 256, interpret: bool = False) -> jnp.ndarray:
    """(M,K)int8 @ (K,N)int8 with per-row/per-col scales → (M,N) float.

    ``a_scale`` broadcasts as (M, 1) or scalar; ``b_scale`` as (1, N) or
    scalar.  Shapes are padded to block multiples transparently.

    ``bias``: optional (N,)/(1, N) f32 row fused into the final K step.
    ``act_spec``: optional LUT activation applied in the same step
    (``act_gated=True`` → ``y * table(y)``, the exact silu/gelu form).
    """
    m, k = a_data.shape
    k2, n = b_data.shape
    assert k == k2, (a_data.shape, b_data.shape)
    bm = min(bm, max(128, 1 << (m - 1).bit_length())) if m < bm else bm
    bn = min(bn, max(128, 1 << (n - 1).bit_length())) if n < bn else bn
    bk = min(bk, max(128, 1 << (k - 1).bit_length())) if k < bk else bk

    a_scale = jnp.broadcast_to(jnp.asarray(a_scale, jnp.float32), (m, 1))
    b_scale = jnp.broadcast_to(jnp.asarray(b_scale, jnp.float32), (1, n))

    a_data, pm = _pad_to(a_data, 0, bm)
    a_data, _ = _pad_to(a_data, 1, bk)
    b_data, _ = _pad_to(b_data, 0, bk)
    b_data, pn = _pad_to(b_data, 1, bn)
    a_scale, _ = _pad_to(a_scale, 0, bm)
    b_scale, _ = _pad_to(b_scale, 1, bn)

    mp, kp = a_data.shape
    np_ = b_data.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    operands = [a_data, b_data, a_scale, b_scale]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
    ]
    if bias is not None:
        brow = jnp.broadcast_to(
            jnp.asarray(bias, jnp.float32).reshape(1, -1), (1, n))
        brow, _ = _pad_to(brow, 1, bn)
        operands.append(brow)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if act_spec is not None:
        table = jnp.asarray(get_table(act_spec).np_values)
        operands.append(table)
        # the table is replicated into VMEM for every block
        in_specs.append(pl.BlockSpec((act_spec.n,), lambda i, j, kk: (0,)))

    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=grid[2],
                          has_bias=bias is not None, act_spec=act_spec,
                          act_gated=act_gated),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)

    return out[:m, :n]
