"""Pallas TPU kernel: blocked online-softmax (flash) attention, GQA-aware.

Beyond-paper performance layer (recorded separately in EXPERIMENTS.md
§Perf): prefill attention is the dominant compute term at 32k context, and
a blocked online-softmax keeps the (Sq × Skv) logits out of HBM entirely —
the working set per grid step is one (bq, d) query block, one (bk, d)
key/value block, and (bq, d)+(bq, 1)×2 f32 scratch in VMEM.

GQA is honoured structurally: K/V keep their ``Hkv`` head axis and the
BlockSpec index map folds the query head onto its KV group
(``h // group``) — grouped KV is *never* broadcast-materialized, which is
the whole point of GQA's cache-size savings.

Causality is handled at two granularities: whole (iq, ik) blocks strictly
above the diagonal are skipped via ``pl.when`` (no MXU work, no VMEM
traffic), and the diagonal blocks apply an elementwise mask.  Padded tail
positions (wrapper pads Sq/Skv to block multiples) are masked with the
same mechanism.

Head dim should be a multiple of 128 for exact MXU tiling; other sizes
(e.g. MLA's 192) are still correct — Mosaic pads the lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["flash_attention_pallas", "paged_attention_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sq: int, skv: int, bq: int, bk: int, nk: int, causal: bool,
            scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions; queries sit at the tail of the kv context
    q_off = skv - sq

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, bk)

        qpos = q_off + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv                                    # kv padding
        if causal:
            mask = mask & (qpos >= kpos)
        logits = jnp.where(mask, logits, _NEG)

        m_prev = m_ref[...]                                  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal: no kv position in this
        # block is visible to any query in the q block
        visible = (q_off + iq * bq + (bq - 1)) >= (ik * bk)
        pl.when(visible)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "softmax_scale", "bq",
                                             "bk", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True,
                           softmax_scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, skv, dv = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    assert hq % hkv == 0 and dv == d
    group = hq // hkv
    scale = softmax_scale if softmax_scale is not None else float(1.0 / np.sqrt(d))

    bq = min(bq, sq)
    bk = min(bk, skv)
    pq = (-sq) % bq
    pk = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bk

    out = pl.pallas_call(
        functools.partial(_kernel, sq=sq, skv=skv, bq=bq, bk=bk, nk=nk,
                          causal=causal, scale=scale),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)

    return out[:, :, :sq] if pq else out


# ===========================================================================
# Paged attention: block-table-indexed KV pages (decode + chunked prefill)
# ===========================================================================
def _paged_kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, s: int, ps: int, npages: int,
                  scale: float):
    """Grid (B, Hkv, NP): online softmax over one sequence's pages.

    The q block holds all ``group * s`` query rows of one (batch, kv
    head) pair, folded group-major — row ``r`` is query position
    ``qpos[b] + r % s`` of head group member ``r // s``.  The k/v
    blocks are one physical page each, DMA'd via the scalar-prefetched
    block table (``bt_ref``) — the kernel never sees a contiguous
    cache, which is the entire point: block-table position ``ip``
    covers logical kv positions ``[ip*ps, (ip+1)*ps)`` wherever the
    page physically lives.
    """
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = q_ref.shape[2]
    qpos0 = qpos_ref[b]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rows, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (rows, ps)

        # absolute positions: query row r sits at qpos0 + r % s; kv
        # column c of block-table entry ip is logical position ip*ps + c
        r = jax.lax.broadcasted_iota(jnp.int32, (rows, ps), 0)
        qp = qpos0 + jax.lax.rem(r, s)
        kvpos = ip * ps + jax.lax.broadcasted_iota(jnp.int32, (rows, ps), 1)
        mask = kvpos <= qp                                   # write-before-attend
        logits = jnp.where(mask, logits, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # skip pages wholly beyond the last visible position (no MXU work,
    # no VMEM traffic) — the paged analogue of the causal block skip
    pl.when(ip * ps <= qpos0 + (s - 1))(_compute)

    @pl.when(ip == npages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softmax_scale", "interpret"))
def paged_attention_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                           qpos: jnp.ndarray, *,
                           softmax_scale: float | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """Block-table-indexed flash attention over a shared KV page pool.

    Shapes as :func:`repro.kernels.ref.paged_attention_ref` (the
    numerics oracle): q (B, Hq, S, D), pages (P, Hkv, ps, D), block
    tables (B, NP) int32, qpos (B,) int32.  S == 1 is the decode step;
    S > 1 a prefill chunk whose K/V were already scattered into the
    pages.  GQA is honoured structurally — the page BlockSpec folds the
    query head onto its KV group and each page is fetched once per
    (batch, kv head), never broadcast to Hq.

    Block tables ride in SMEM via scalar prefetch
    (``PrefetchScalarGridSpec``) so the page DMA address for grid step
    (b, h, ip) — physical page ``block_tables[b, ip]`` — is known
    before the kernel body runs.
    """
    b, hq, s, d = q.shape
    p_, hkv, ps, _ = k_pages.shape
    np_ = block_tables.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    rows = group * s
    scale = (softmax_scale if softmax_scale is not None
             else float(1.0 / np.sqrt(d)))

    # fold query heads group-major onto their kv head: (B, Hkv, G*S, D)
    qf = q.reshape(b, hkv, group, s, d).reshape(b, hkv, rows, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, np_),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda bb, h, ip, bt, qp: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bb, h, ip, bt, qp: (bt[bb, ip], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bb, h, ip, bt, qp: (bt[bb, ip], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, d),
                               lambda bb, h, ip, bt, qp: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # running max
            pltpu.VMEM((rows, 1), jnp.float32),   # running denom
            pltpu.VMEM((rows, d), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, s=s, ps=ps, npages=np_,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(qpos, jnp.int32),
      qf, k_pages, v_pages)

    return out.reshape(b, hkv, group, s, d).reshape(b, hq, s, d)
