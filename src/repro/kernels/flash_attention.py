"""Pallas TPU kernel: blocked online-softmax (flash) attention, GQA-aware.

Beyond-paper performance layer (recorded separately in EXPERIMENTS.md
§Perf): prefill attention is the dominant compute term at 32k context, and
a blocked online-softmax keeps the (Sq × Skv) logits out of HBM entirely —
the working set per grid step is one (bq, d) query block, one (bk, d)
key/value block, and (bq, d)+(bq, 1)×2 f32 scratch in VMEM.

GQA is honoured structurally: K/V keep their ``Hkv`` head axis and the
BlockSpec index map folds the query head onto its KV group
(``h // group``) — grouped KV is *never* broadcast-materialized, which is
the whole point of GQA's cache-size savings.

Causality is handled at two granularities: whole (iq, ik) blocks strictly
above the diagonal are skipped via ``pl.when`` (no MXU work, no VMEM
traffic), and the diagonal blocks apply an elementwise mask.  Padded tail
positions (wrapper pads Sq/Skv to block multiples) are masked with the
same mechanism.

Head dim should be a multiple of 128 for exact MXU tiling; other sizes
(e.g. MLA's 192) are still correct — Mosaic pads the lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

__all__ = ["flash_attention_pallas", "paged_attention_pallas",
           "paged_attention_xla", "combine_splits", "choose_kv_split",
           "auto_pages_per_step", "get_cost_constants",
           "set_cost_constants"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            sq: int, skv: int, bq: int, bk: int, nk: int, causal: bool,
            scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions; queries sit at the tail of the kv context
    q_off = skv - sq

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, bk)

        qpos = q_off + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv                                    # kv padding
        if causal:
            mask = mask & (qpos >= kpos)
        logits = jnp.where(mask, logits, _NEG)

        m_prev = m_ref[...]                                  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal: no kv position in this
        # block is visible to any query in the q block
        visible = (q_off + iq * bq + (bq - 1)) >= (ik * bk)
        pl.when(visible)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "softmax_scale", "bq",
                                             "bk", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True,
                           softmax_scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, skv, dv = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    assert hq % hkv == 0 and dv == d
    group = hq // hkv
    scale = softmax_scale if softmax_scale is not None else float(1.0 / np.sqrt(d))

    bq = min(bq, sq)
    bk = min(bk, skv)
    pq = (-sq) % bq
    pk = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bk

    out = pl.pallas_call(
        functools.partial(_kernel, sq=sq, skv=skv, bq=bq, bk=bk, nk=nk,
                          causal=causal, scale=scale),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)

    return out[:, :, :sq] if pq else out


# ===========================================================================
# Paged attention: block-table-indexed KV pages (decode + chunked prefill)
# ===========================================================================
def _paged_kernel(bt_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, s: int, ps: int, npages: int,
                  scale: float):
    """Grid (B, Hkv, NP): online softmax over one sequence's pages.

    The q block holds all ``group * s`` query rows of one (batch, kv
    head) pair, folded group-major — row ``r`` is query position
    ``qpos[b] + r % s`` of head group member ``r // s``.  The k/v
    blocks are one physical page each, DMA'd via the scalar-prefetched
    block table (``bt_ref``) — the kernel never sees a contiguous
    cache, which is the entire point: block-table position ``ip``
    covers logical kv positions ``[ip*ps, (ip+1)*ps)`` wherever the
    page physically lives.
    """
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = q_ref.shape[2]
    qpos0 = qpos_ref[b]

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rows, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (rows, ps)

        # absolute positions: query row r sits at qpos0 + r % s; kv
        # column c of block-table entry ip is logical position ip*ps + c
        r = jax.lax.broadcasted_iota(jnp.int32, (rows, ps), 0)
        qp = qpos0 + jax.lax.rem(r, s)
        kvpos = ip * ps + jax.lax.broadcasted_iota(jnp.int32, (rows, ps), 1)
        mask = kvpos <= qp                                   # write-before-attend
        logits = jnp.where(mask, logits, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                  # (ps, d)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # skip pages wholly beyond the last visible position (no MXU work,
    # no VMEM traffic) — the paged analogue of the causal block skip
    pl.when(ip * ps <= qpos0 + (s - 1))(_compute)

    @pl.when(ip == npages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_attention_unsplit(q: jnp.ndarray, k_pages: jnp.ndarray,
                             v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                             qpos: jnp.ndarray, *,
                             softmax_scale: float | None = None,
                             interpret: bool = False) -> jnp.ndarray:
    """The original one-page-per-step lowering (``kv_split=1``,
    ``pages_per_step=1``).  Kept verbatim: the split dispatcher routes
    the (1, 1) knob point here so it reproduces the pre-split kernel
    byte-for-byte."""
    b, hq, s, d = q.shape
    p_, hkv, ps, _ = k_pages.shape
    np_ = block_tables.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    rows = group * s
    scale = (softmax_scale if softmax_scale is not None
             else float(1.0 / np.sqrt(d)))

    # fold query heads group-major onto their kv head: (B, Hkv, G*S, D)
    qf = q.reshape(b, hkv, group, s, d).reshape(b, hkv, rows, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, np_),
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda bb, h, ip, bt, qp: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bb, h, ip, bt, qp: (bt[bb, ip], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bb, h, ip, bt, qp: (bt[bb, ip], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, d),
                               lambda bb, h, ip, bt, qp: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # running max
            pltpu.VMEM((rows, 1), jnp.float32),   # running denom
            pltpu.VMEM((rows, d), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, s=s, ps=ps, npages=np_,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(qpos, jnp.int32),
      qf, k_pages, v_pages)

    return out.reshape(b, hkv, group, s, d).reshape(b, hq, s, d)


# ===========================================================================
# Split-KV paged attention: flash-decoding partials + log-sum-exp combine
# ===========================================================================
def _paged_split_kernel(bt_ref, qpos_ref, q_ref, *refs, s: int, ps: int,
                        t: int, nt: int, scale: float):
    """Grid (B, Hkv, kv_split, NT): per-partition online-softmax partials.

    Flash-decoding layout: each slot's block table is cut into
    ``kv_split`` contiguous partitions of ``nt`` *tiles* (a tile is
    ``t = pages_per_step`` consecutive block-table entries, DMA'd as
    ``t`` concurrent page fetches and concatenated in VMEM — the
    pipeline double-buffers them across grid steps).  The partition
    axis is a *parallel* grid dimension: partitions never share
    scratch, so long-context decode stops being one serial page chain.
    Each partition emits its raw online-softmax state — ``acc`` (the
    un-normalized weighted V sum), ``m`` (running max) and ``l``
    (running denominator) — and :func:`combine_splits` merges them in a
    second log-sum-exp stage.

    Masking is identical to :func:`_paged_kernel`: tile entry ``base +
    j`` covers logical kv positions ``[(base+j)*ps, (base+j+1)*ps)``,
    visibility is ``kvpos <= qpos[b] + r % s``, and tiles wholly beyond
    the last visible position are skipped (dead partitions keep their
    init state — ``m = -1e30, l = 0`` — which the combine maps to
    exactly-zero weight, so trash-page garbage and dead lanes cannot
    leak into any partition's sum).
    """
    b = pl.program_id(0)
    sp = pl.program_id(2)
    it = pl.program_id(3)
    k_refs, v_refs = refs[:t], refs[t:2 * t]
    acc_o, m_o, l_o = refs[2 * t:2 * t + 3]
    m_s, l_s, acc_s = refs[2 * t + 3:]

    @pl.when(it == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    rows = q_ref.shape[2]
    qpos0 = qpos_ref[b]
    base = (sp * nt + it) * t      # first block-table entry of this tile

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (rows, d)
        k = jnp.concatenate(
            [kr[0, 0].astype(jnp.float32) for kr in k_refs], axis=0)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (rows, t*ps)

        r = jax.lax.broadcasted_iota(jnp.int32, (rows, t * ps), 0)
        qp = qpos0 + jax.lax.rem(r, s)
        kvpos = base * ps + jax.lax.broadcasted_iota(
            jnp.int32, (rows, t * ps), 1)
        mask = kvpos <= qp                                   # write-before-attend
        logits = jnp.where(mask, logits, _NEG)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = alpha * l_s[...] + jnp.sum(p, axis=1, keepdims=True)
        v = jnp.concatenate(
            [vr[0, 0].astype(jnp.float32) for vr in v_refs], axis=0)
        acc_s[...] = alpha * acc_s[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    # the paged analogue of the causal block skip, per tile
    pl.when(base * ps <= qpos0 + (s - 1))(_compute)

    @pl.when(it == nt - 1)
    def _finish():
        acc_o[0, 0, 0] = acc_s[...]
        m_o[0, 0, 0] = m_s[...]
        l_o[0, 0, 0] = l_s[...]


def combine_splits(acc: jnp.ndarray, m: jnp.ndarray, l: jnp.ndarray):
    """Log-sum-exp merge of per-partition online-softmax partials.

    ``acc`` (kv_split, ..., rows, d), ``m``/``l`` (kv_split, ..., rows,
    1) — partition axis leading.  Returns the merged ``(acc*, m*, l*)``
    such that ``acc* / max(l*, eps)`` equals the unsplit online softmax
    over the concatenated partitions.

    This is THE combine formula: the Pallas wrapper and the ``ref.py``
    oracle both call it (shared-formula rule — a re-derived but
    last-ulp-different merge would break the fused≡ref exact-match
    contract).  Dead partitions (``m = -1e30, l = 0`` init state —
    nothing visible, e.g. a trash-page-only tail) contribute
    ``exp(-1e30 - m*) = 0`` weight; if *every* partition is dead the
    caller's ``max(l*, eps)`` guard maps the output to exactly 0, the
    same convention as the unsplit kernel's dead-lane output.
    """
    m_star = jnp.max(m, axis=0)                         # (..., rows, 1)
    alpha = jnp.exp(m - m_star[None])                   # (split, ..., rows, 1)
    l_star = jnp.sum(alpha * l, axis=0)
    acc_star = jnp.sum(alpha * acc, axis=0)
    return acc_star, m_star, l_star


#: relative latency units of the split cost model: one multi-page tile
#: (DMA + MXU pass) vs one partition's extra combine traffic.  Coarse on
#: purpose — the model only has to rank splits, not predict walltime
#: (rule4ml's lesson: a cheap learned/analytic ranker beats hand-tuning).
#: These are the *analytic defaults*; ``set_cost_constants`` installs
#: values fitted from measured latencies (launch/autotune.py) without
#: the ranking formula changing shape.
_ANALYTIC_COST_CONSTANTS = {
    "tile_cost": 4.0,        # one multi-page tile: DMA + MXU pass
    "combine_cost": 1.0,     # one partition's extra combine traffic
    "target_lanes": 512.0,   # grid lanes that saturate the pipeline
}
_TILE_COST = _ANALYTIC_COST_CONSTANTS["tile_cost"]
_COMBINE_COST = _ANALYTIC_COST_CONSTANTS["combine_cost"]
_TARGET_LANES = _ANALYTIC_COST_CONSTANTS["target_lanes"]


def get_cost_constants() -> dict:
    """Current split cost-model constants (a copy; mutate via
    :func:`set_cost_constants`)."""
    return {"tile_cost": _TILE_COST, "combine_cost": _COMBINE_COST,
            "target_lanes": _TARGET_LANES}


def set_cost_constants(tile_cost: float | None = None,
                       combine_cost: float | None = None,
                       target_lanes: float | None = None) -> dict:
    """Install cost-model constants (``None`` = reset to the analytic
    default) and invalidate every cached ``choose_kv_split`` decision.

    This is the seam the autotuner uses: ``launch/autotune.py`` fits
    tile/combine costs from measured ``paged_attention`` latencies and
    installs them here, so *every* downstream auto split — fused decode
    loops, spec verify, direct kernel calls — re-ranks under the fitted
    model with no call-site changes.  Returns the constants now in
    effect.
    """
    global _TILE_COST, _COMBINE_COST, _TARGET_LANES
    _TILE_COST = float(tile_cost) if tile_cost is not None \
        else _ANALYTIC_COST_CONSTANTS["tile_cost"]
    _COMBINE_COST = float(combine_cost) if combine_cost is not None \
        else _ANALYTIC_COST_CONSTANTS["combine_cost"]
    _TARGET_LANES = float(target_lanes) if target_lanes is not None \
        else _ANALYTIC_COST_CONSTANTS["target_lanes"]
    choose_kv_split.cache_clear()       # decisions depend on the constants
    return get_cost_constants()


@functools.lru_cache(maxsize=None)
def choose_kv_split(seq_len: int, pages: int, hkv: int, *, batch: int = 1,
                    pages_per_step: int = 1) -> int:
    """Pick ``kv_split`` from a cached analytic latency model.

    The serving-side reuse-factor selector (the paper's knob, chosen
    rule4ml-style from a cost model instead of hand-tuning): modeled
    decode latency of a split is its serial tile chain plus the
    per-partition combine overhead,

        cost(split) = ceil(tiles / split) * TILE + split * COMBINE,

    minimized over power-of-two splits — with an occupancy guard: once
    ``batch * hkv * split`` already saturates the pipeline's parallel
    lanes, further splitting only buys combine overhead, so deeper
    candidates are skipped.  The *boundary* candidate — the first split
    whose predecessor saturates — is still costed before the guard
    fires (an earlier revision broke out before costing it, pinning
    every ``lanes >= target`` geometry to ``split=1`` no matter how
    long the tile chain was).  Ties break toward the smaller split
    (fewer partials in HBM).  Cached per shape tuple — the engine
    resolves it once per cache geometry, not per step.

    ``seq_len`` (the table capacity in tokens, ``pages * page_size`` at
    every current call site) is part of the knob's public shape key but
    not yet a cost term: it is reserved for hardware-fitted constants
    (ROADMAP: fit TILE/COMBINE from measured TPU latency curves, where
    absolute context length sets the DMA/compute balance).
    """
    pages = max(1, int(pages))
    t = max(1, int(pages_per_step))
    tiles = -(-pages // t)
    lanes = max(1, int(batch) * max(1, int(hkv)))
    best, best_cost = 1, None
    split = 1
    while split <= tiles:
        cost = (-(-tiles // split)) * _TILE_COST + split * _COMBINE_COST
        if best_cost is None or cost < best_cost:
            best, best_cost = split, cost
        if split > 1 and lanes * (split // 2) >= _TARGET_LANES:
            # saturated: deeper splits only add combine overhead (this
            # boundary candidate was costed above, not skipped — the
            # old guard broke one candidate too early).
            break
        split *= 2
    return best


def auto_pages_per_step(page_size: int, pages: int) -> int:
    """Default multi-page tile: enough consecutive pages per grid step
    to feed the MXU a ~128-row K/V operand (one full systolic pass),
    capped by the table width."""
    return max(1, min(128 // max(1, int(page_size)), max(1, int(pages))))


def _resolve_knobs(np_: int, ps: int, hkv: int, batch: int,
                   kv_split, pages_per_step):
    """One resolution rule for every lowering (and the engine mirrors
    it): explicit values clamp to the table; an *auto* tile additionally
    shrinks to honour an *explicit* split (otherwise a tile that
    swallows the whole table would silently clamp a requested
    ``kv_split`` back to 1); an auto split comes from the cost model at
    the resolved tile.  Returns ``(pages_per_step, kv_split)``.
    """
    if pages_per_step is None:
        if kv_split is not None and int(kv_split) == 1:
            # the documented regression baseline: an explicit split of 1
            # alone means "today's serial page chain, byte-identical" —
            # an auto tile would route through the split kernel (same
            # math, different float association).  Tiling WITH split=1
            # is still reachable by pinning pages_per_step explicitly.
            t = 1
        else:
            t = auto_pages_per_step(ps, np_)
            if kv_split is not None and int(kv_split) > 1:
                t = min(t, max(1, -(-np_ // int(kv_split))))
    else:
        t = max(1, min(int(pages_per_step), np_))
    tiles = -(-np_ // t)
    if kv_split is None:
        split = choose_kv_split(np_ * ps, np_, hkv, batch=batch,
                                pages_per_step=t)
    else:
        split = max(1, int(kv_split))
    return t, min(split, tiles)


@functools.partial(jax.jit, static_argnames=("softmax_scale", "interpret",
                                             "kv_split", "pages_per_step"))
def paged_attention_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                           qpos: jnp.ndarray, *,
                           softmax_scale: float | None = None,
                           kv_split: int | None = None,
                           pages_per_step: int | None = None,
                           interpret: bool = False) -> jnp.ndarray:
    """Block-table-indexed flash attention over a shared KV page pool.

    Shapes as :func:`repro.kernels.ref.paged_attention_ref` (the
    numerics oracle): q (B, Hq, S, D), pages (P, Hkv, ps, D), block
    tables (B, NP) int32, qpos (B,) int32.  S == 1 is the decode step;
    S > 1 a prefill chunk whose K/V were already scattered into the
    pages (write-before-attend: ``qpos + S <= NP * page_size`` is the
    op contract — every query position fits the table).  GQA is
    honoured structurally — the page BlockSpec folds the query head
    onto its KV group and each page is fetched once per (batch, kv
    head), never broadcast to Hq.  Block tables ride in SMEM via scalar
    prefetch (``PrefetchScalarGridSpec``) so every page DMA address is
    known before the kernel body runs.

    ``kv_split`` / ``pages_per_step`` are the kernel's reuse-factor
    knob (None = choose from the cached cost model): the block table is
    cut into ``kv_split`` parallel partitions whose flash-decoding
    partials merge in a log-sum-exp combine stage
    (:func:`combine_splits`), and each grid step DMAs a tile of
    ``pages_per_step`` consecutive table entries instead of one —
    double-buffered by the Pallas pipeline — so decode latency stops
    scaling with the serial page chain.  ``kv_split=1,
    pages_per_step=1`` routes through the original kernel unchanged
    (byte-for-byte identical results).
    """
    b, hq, s, d = q.shape
    p_, hkv, ps, _ = k_pages.shape
    np_ = block_tables.shape[1]
    assert hq % hkv == 0

    t, split = _resolve_knobs(np_, ps, hkv, b, kv_split, pages_per_step)
    tiles = -(-np_ // t)

    if split == 1 and t == 1:
        return _paged_attention_unsplit(q, k_pages, v_pages, block_tables,
                                        qpos, softmax_scale=softmax_scale,
                                        interpret=interpret)

    group = hq // hkv
    rows = group * s
    scale = (softmax_scale if softmax_scale is not None
             else float(1.0 / np.sqrt(d)))
    qf = q.reshape(b, hkv, group, s, d).reshape(b, hkv, rows, d)

    # pad the table so every partition holds exactly nt full tiles; pad
    # entries point at page 0 — always a valid DMA target, and always
    # masked (their logical positions are >= NP*ps > qpos + s - 1 by
    # the op contract above)
    nt = -(-tiles // split)
    np_pad = split * nt * t
    bt = jnp.asarray(block_tables, jnp.int32)
    if np_pad > np_:
        bt = jnp.pad(bt, ((0, 0), (0, np_pad - np_)))

    def _page_spec(j):
        return pl.BlockSpec(
            (1, 1, ps, d),
            lambda bb, h, sp, it, bt, qp, j=j:
                (bt[bb, (sp * nt + it) * t + j], h, 0, 0))

    def _out_spec(last):
        return pl.BlockSpec(
            (1, 1, 1, rows, last),
            lambda bb, h, sp, it, bt, qp: (sp, bb, h, 0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, split, nt),
        in_specs=[pl.BlockSpec((1, 1, rows, d),
                               lambda bb, h, sp, it, bt, qp: (bb, h, 0, 0))]
                 + [_page_spec(j) for j in range(t)] * 2,
        out_specs=[_out_spec(d), _out_spec(1), _out_spec(1)],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # running max
            pltpu.VMEM((rows, 1), jnp.float32),   # running denom
            pltpu.VMEM((rows, d), jnp.float32),   # output accumulator
        ],
    )
    acc, m, l = pl.pallas_call(
        functools.partial(_paged_split_kernel, s=s, ps=ps, t=t, nt=nt,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((split, b, hkv, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((split, b, hkv, rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((split, b, hkv, rows, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(bt, jnp.asarray(qpos, jnp.int32), qf,
      *([k_pages] * t), *([v_pages] * t))

    acc_star, _, l_star = combine_splits(acc, m, l)
    out = acc_star / jnp.maximum(l_star, 1e-30)
    return out.astype(q.dtype).reshape(b, hkv, group, s, d) \
              .reshape(b, hq, s, d)


@functools.partial(jax.jit, static_argnames=("softmax_scale", "kv_split",
                                             "pages_per_step"))
def paged_attention_xla(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                        qpos: jnp.ndarray, *,
                        softmax_scale: float | None = None,
                        kv_split: int | None = None,
                        pages_per_step: int | None = None) -> jnp.ndarray:
    """The split-KV *schedule* lowered through plain XLA (no Pallas).

    The third lowering of the op (ref = semantics, pallas = TPU, this =
    portable schedule model): a ``lax.scan`` whose carried state is the
    online-softmax ``(m, l, acc)`` triple and whose step processes one
    ``pages_per_step``-page tile of EVERY partition at once — the
    partition axis rides as a batch dimension, so the serial dependence
    chain is ``ceil(tiles / kv_split)`` scan steps instead of the
    unsplit kernel's one-step-per-page chain.  ``kv_split=1,
    pages_per_step=1`` is therefore the faithful executable model of
    the serial kernel's latency (one page per dependence-chain step),
    which is what the long-context bench measures split-KV against on
    CPU hosts — where interpret-mode Pallas walltime measures the
    interpreter, not the schedule.  Shares :func:`combine_splits` and
    the masking convention with the kernel and the ref oracle.
    """
    b, hq, s, d = q.shape
    p_, hkv, ps, _ = k_pages.shape
    np_ = block_tables.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    rows = group * s
    scale = (softmax_scale if softmax_scale is not None
             else float(1.0 / np.sqrt(d)))

    t, split = _resolve_knobs(np_, ps, hkv, b, kv_split, pages_per_step)
    tiles = -(-np_ // t)
    nt = -(-tiles // split)
    np_pad = split * nt * t
    bt = jnp.asarray(block_tables, jnp.int32)
    if np_pad > np_:
        bt = jnp.pad(bt, ((0, 0), (0, np_pad - np_)))
    bt4 = bt.reshape(b, split, nt, t)

    qf = (q.reshape(b, hkv, group, s, d).reshape(b, hkv, rows, d)
          .astype(jnp.float32) * scale)
    qp_rows = (jnp.asarray(qpos, jnp.int32)[:, None]
               + jnp.arange(rows, dtype=jnp.int32) % s)       # (B, rows)
    base_sp = jnp.arange(split, dtype=jnp.int32) * (nt * t * ps)

    def body(carry, it):
        m, l, acc = carry
        idx = jax.lax.dynamic_index_in_dim(bt4, it, axis=2,
                                           keepdims=False)    # (B, S, t)
        k = k_pages[idx].transpose(0, 1, 3, 2, 4, 5) \
            .reshape(b, split, hkv, t * ps, d).astype(jnp.float32)
        v = v_pages[idx].transpose(0, 1, 3, 2, 4, 5) \
            .reshape(b, split, hkv, t * ps, d).astype(jnp.float32)
        logits = jnp.einsum("bhrd,bshkd->bshrk", qf, k,
                            preferred_element_type=jnp.float32)
        kvpos = (base_sp[:, None] + it * (t * ps)
                 + jnp.arange(t * ps, dtype=jnp.int32)[None, :])  # (S, K)
        mask = (kvpos[None, :, None, None, :]
                <= qp_rows[:, None, None, :, None])
        logits = jnp.where(mask, logits, _NEG)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = alpha * acc + jnp.einsum("bshrk,bshkd->bshrd", p, v,
                                       preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((b, split, hkv, rows, 1), _NEG, jnp.float32),
            jnp.zeros((b, split, hkv, rows, 1), jnp.float32),
            jnp.zeros((b, split, hkv, rows, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init,
                                  jnp.arange(nt, dtype=jnp.int32))
    # partition axis leading, as combine_splits expects
    acc_star, _, l_star = combine_splits(acc.transpose(1, 0, 2, 3, 4),
                                         m.transpose(1, 0, 2, 3, 4),
                                         l.transpose(1, 0, 2, 3, 4))
    out = acc_star / jnp.maximum(l_star, 1e-30)
    return out.astype(q.dtype).reshape(b, hkv, group, s, d) \
              .reshape(b, hq, s, d)
