"""Fused token-sampling lowering for the device-resident decode loop.

``sample_tokens`` draws one next-token id per batch slot from (B, V)
logits, entirely on device, with *per-slot* sampling parameters:

* ``temperature <= 0``  — greedy (argmax); the serving default, and the
  mode the byte-identical acceptance comparisons run under.
* ``temperature > 0``   — softmax sampling at that temperature via the
  Gumbel-max trick (one argmax, no materialized CDF).
* ``top_k > 0``         — restrict sampling to the k highest logits
  (k is clamped to the vocab size); ``top_k <= 0`` means unrestricted.

Sampling is the one step of the decode loop that is *stateful across
steps* (the PRNG), so determinism is part of the op contract: given the
same (logits, params, key) the draw is identical whether the op runs
standalone, under ``jax.jit``, or inside the ``lax.scan`` of
``build_decode_loop`` — callers derive per-step keys with
``jax.random.fold_in`` so a block of N fused steps consumes exactly the
keys N per-token steps would.

This lowering is the registry's specialized backend for the op.  Unlike
qmatmul / attention there is no ``pallas_call`` here on purpose: sampling
touches (B, V) floats once — it is bandwidth-trivial next to the matmuls
it follows — and the win is *fusing it into the decode jit* so the
sampled token never leaves the device.  The ``ref`` backend in
:mod:`repro.kernels.ref` re-derives the composition (masking,
temperature, greedy overrides) from the same noise source and tie
convention — see its docstring for what that does and does not verify.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["sample_tokens_fused", "gumbel_noise"]


def gumbel_noise(key, shape) -> jnp.ndarray:
    """Shared Gumbel(0, 1) noise: both lowerings must perturb logits with
    bit-identical noise so the fused/ref argmaxes agree exactly."""
    return jax.random.gumbel(key, shape, dtype=jnp.float32)


def sample_tokens_fused(logits: jnp.ndarray, temperature: jnp.ndarray,
                        top_k: jnp.ndarray, key: Optional[jax.Array] = None,
                        ) -> jnp.ndarray:
    """(B, V) logits -> (B,) int32 token ids.

    ``temperature``: (B,) f32; ``top_k``: (B,) int32.  ``key`` may be
    None only if every slot is greedy (no randomness consumed).
    """
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        return greedy

    temperature = jnp.asarray(temperature, jnp.float32).reshape(b)
    top_k = jnp.asarray(top_k, jnp.int32).reshape(b)

    # per-slot candidate set: the k highest logits, k <= 0 disables the
    # restriction.  Candidacy is RANK-based (stable argsort), not a
    # value threshold: tied logits at the k-th place — routine under
    # int8-dequantized heads — must resolve to exactly k candidates the
    # same way in every lowering, or backends sample different tokens
    # from the same (logits, key).  O(V log V) on (B, V), negligible
    # next to the decode matmuls.
    order = jnp.argsort(-logits, axis=-1)                         # (B, V)
    ranks = jnp.argsort(order, axis=-1)
    k_eff = jnp.clip(top_k, 1, v)
    restricted = jnp.where(top_k[:, None] > 0,
                           ranks < k_eff[:, None],
                           jnp.ones((b, v), bool))

    temp = jnp.maximum(temperature, 1e-6)[:, None]
    perturbed = jnp.where(restricted, logits / temp, -jnp.inf) \
        + gumbel_noise(key, (b, v))
    sampled = jnp.argmax(perturbed, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)
