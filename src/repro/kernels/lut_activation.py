"""Pallas TPU kernel: VMEM-resident lookup-table activation.

The BRAM→VMEM adaptation of the paper's constant-table activations.  The
table (built at trace time by :mod:`repro.core.tables`) rides into VMEM
once per block via a replicated BlockSpec; each input block is mapped to
table indices on the VPU and gathered (plus an optional linear
interpolation — two gathers and an FMA).  This replaces transcendental
``exp/tanh/erf`` evaluations, which are the slow path on the VPU, with a
gather — the same trade the paper's BRAM tables make against DSP/LUT logic.

Layout: the wrapper flattens any input to (rows, LANES) with LANES=128 so
the last dimension is lane-aligned; ``block_rows`` rows are processed per
grid step (8 sublanes × k).  VMEM working set per step:
``block_rows*128*4`` bytes for x/out + ``4*n`` bytes for the table —
a 1024-entry table is 4 KiB, the BRAM-sized footprint the paper targets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.tables import TableSpec, get_table

__all__ = ["lut_activation_pallas", "apply_table"]

LANES = 128


def apply_table(y: jnp.ndarray, t: jnp.ndarray, *, lo: float,
                step_inv: float, n: int, indexing: str,
                gated: bool = False) -> jnp.ndarray:
    """In-kernel LUT gather on a VMEM-resident tile (``jnp.take`` form of
    :func:`repro.core.tables.table_lookup`, which Mosaic can lower).

    Shared by this kernel and the fused qmatmul epilogue so the
    interp/nearest/trunc numerics have exactly one in-kernel
    implementation.  ``gated=True`` returns ``y * table(y)`` (the exact
    gated silu/gelu form).
    """
    pos = (y - lo) * step_inv
    if indexing == "interp":
        pos = jnp.clip(pos, 0.0, n - 1.0)
        i0f = jnp.floor(pos)
        frac = pos - i0f
        i0 = i0f.astype(jnp.int32)
        i1 = jnp.minimum(i0 + 1, n - 1)
        y0 = jnp.take(t, i0.reshape(-1), axis=0).reshape(y.shape)
        y1 = jnp.take(t, i1.reshape(-1), axis=0).reshape(y.shape)
        z = y0 * (1.0 - frac) + y1 * frac
    else:
        if indexing == "nearest":
            idx = jnp.clip(jnp.round(pos), 0, n - 1).astype(jnp.int32)
        else:  # trunc — hls4ml-faithful
            idx = jnp.clip(jnp.floor(pos), 0, n - 1).astype(jnp.int32)
        z = jnp.take(t, idx.reshape(-1), axis=0).reshape(y.shape)
    return y * z if gated else z


def _kernel(x_ref, t_ref, o_ref, *, lo: float, step_inv: float, n: int,
            indexing: str):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = apply_table(x, t_ref[...], lo=lo, step_inv=step_inv, n=n,
                             indexing=indexing)


@functools.partial(jax.jit, static_argnames=("spec", "block_rows", "interpret"))
def lut_activation_pallas(x: jnp.ndarray, spec: TableSpec, *,
                          block_rows: int = 256,
                          interpret: bool = False) -> jnp.ndarray:
    """Apply the table described by ``spec`` to ``x`` (any shape)."""
    table = jnp.asarray(get_table(spec).np_values)
    n = spec.n
    orig_shape, orig_dtype = x.shape, x.dtype

    flat = x.reshape(-1)
    cols = LANES
    pad = (-flat.shape[0]) % (block_rows * cols)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    x2 = flat.reshape(-1, cols)
    rows = x2.shape[0]
    grid = (rows // block_rows,)

    out = pl.pallas_call(
        functools.partial(_kernel, lo=spec.lo, step_inv=1.0 / spec.step,
                          n=n, indexing=spec.indexing),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            # the table is replicated into VMEM for every block
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, table)

    out = out.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(orig_shape).astype(orig_dtype)
