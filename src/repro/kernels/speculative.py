"""Fused draft-verification lowering for speculative decoding.

``verify_tokens`` is the acceptance rule of the draft→verify pipeline:
given the target model's logits over a drafted block and the drafted
token ids, it decides — entirely on device — how many drafted tokens
survive and what the next input token is.

The op consumes ``logits`` (B, S, V) produced by ONE target-model call
over the block ``[cur, d_1, .., d_k]`` (S = k + 1: the current token
plus k drafts), where row ``j`` conditions on the block prefix up to and
including token ``j``.  Rows are compared against the drafts one step
ahead: row ``j`` predicts the token after consuming ``d_j``, so it is
judged against ``d_{j+1}``.

* ``temperature <= 0`` — greedy: draft ``d_{j+1}`` is accepted iff it
  equals ``argmax(logits[:, j])``.  The committed stream is therefore
  *exactly* the target model's argmax chain regardless of what the
  drafter proposed — the byte-identical-to-non-speculative contract.
* ``temperature > 0`` — rejection sampling against a *deterministic*
  (point-mass) proposal: every drafter in this library proposes greedily
  (prompt-lookup copies history, a draft model argmaxes), so the
  proposal distribution is ``q(x) = 1[x == d]``.  The standard
  speculative-sampling rule then reduces to: accept ``d`` with
  probability ``p(d)`` (the target's post-temperature/top-k probability
  of the draft), and on rejection sample from the residual
  ``max(0, p - q) ∝ p`` with the draft token's mass removed.  Each
  committed token is marginally distributed exactly as the
  non-speculative sampler's — temperature/top-k distributions are
  preserved (the token *sequence* differs from the non-speculative
  stream's, as it must: different randomness consumption).

The final row (``j == k``) never judges a draft: when every draft is
accepted it supplies the "bonus" token (greedy argmax or a regular
sample), so a fully-accepted step commits k + 1 tokens and a fully
rejected one still commits 1 — the ``n_advance >= 1`` guarantee that
makes speculation never slower than plain decode in steps.

Determinism contract (mirrors :mod:`repro.kernels.sampling`): both
lowerings derive their noise from the same key-splitting helper
(:func:`verify_noise`) and share the rank-based top-k tie convention,
so fused and ``ref`` agree bit-for-bit on the same inputs, under jit and
inside ``lax.scan``.  ``key=None`` is legal when every slot is greedy —
greedy verification consumes no randomness.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .sampling import gumbel_noise

__all__ = ["verify_tokens_fused", "verify_noise", "draft_ngram"]


def verify_noise(key, batch: int, k: int, vocab: int):
    """Shared noise for the three stochastic legs of verification.

    Returns ``(u, g_resample, g_bonus)``: acceptance uniforms (B, k),
    residual-resample Gumbel (B, k, V) and bonus-sample Gumbel (B, V).
    Both lowerings MUST draw through this helper — the fused/ref
    exact-match contract is bit-level.
    """
    ku, kr, kb = jax.random.split(key, 3)
    u = jax.random.uniform(ku, (batch, k), dtype=jnp.float32)
    g_resample = gumbel_noise(kr, (batch, k, vocab))
    g_bonus = gumbel_noise(kb, (batch, vocab))
    return u, g_resample, g_bonus


def _topk_restricted(logits: jnp.ndarray, top_k: jnp.ndarray) -> jnp.ndarray:
    """(B, S, V) -> bool candidacy mask, rank-based (same tie convention
    as sample_tokens: exactly k candidates even on tied logits)."""
    b, s, v = logits.shape
    order = jnp.argsort(-logits, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    k_eff = jnp.clip(top_k, 1, v)
    return jnp.where(top_k[:, None, None] > 0,
                     ranks < k_eff[:, None, None],
                     jnp.ones((b, s, v), bool))


def verify_tokens_fused(logits: jnp.ndarray, draft: jnp.ndarray,
                        temperature: jnp.ndarray, top_k: jnp.ndarray,
                        key: Optional[jax.Array] = None,
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(B, S, V) logits × (B, S-1) drafts -> (next_token (B,), n_advance (B,)).

    ``n_advance`` in [1, S]: the number of block tokens committed
    (``cur`` plus the accepted draft prefix).  ``next_token`` is the new
    input token — the correction sampled/argmaxed at the first rejected
    position, or the bonus token from the final row when every draft
    survived.  ``temperature`` (B,) f32 and ``top_k`` (B,) i32 are per
    slot, exactly as in ``sample_tokens``; ``key`` may be None only if
    every slot is greedy.
    """
    logits = logits.astype(jnp.float32)
    b, s, v = logits.shape
    k = s - 1
    draft = draft.astype(jnp.int32)
    greedy_t = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, S)

    if key is None:
        accept = draft == greedy_t[:, :k]                        # (B, k)
        t_full = greedy_t                                        # (B, S)
    else:
        temperature = jnp.asarray(temperature, jnp.float32).reshape(b)
        top_k = jnp.asarray(top_k, jnp.int32).reshape(b)
        restricted = _topk_restricted(logits, top_k)             # (B, S, V)
        temp = jnp.maximum(temperature, 1e-6)[:, None, None]
        scaled = jnp.where(restricted, logits / temp, -jnp.inf)  # (B, S, V)
        probs = jax.nn.softmax(scaled, axis=-1)                  # (B, S, V)

        u, g_resample, g_bonus = verify_noise(key, b, k, v)
        # accept d_{j+1} with prob p_j(d_{j+1}) — point-mass proposal
        p_draft = jnp.take_along_axis(probs[:, :k], draft[..., None],
                                      axis=-1)[..., 0]           # (B, k)
        accept_s = u < p_draft
        # residual max(0, p - q) ∝ p with the draft's mass removed:
        # Gumbel-max over the restricted logits minus the draft token
        res_logits = jnp.where(
            jax.nn.one_hot(draft, v, dtype=bool), -jnp.inf, scaled[:, :k])
        resample = jnp.argmax(res_logits + g_resample,
                              axis=-1).astype(jnp.int32)         # (B, k)
        bonus = jnp.argmax(scaled[:, k] + g_bonus,
                           axis=-1).astype(jnp.int32)            # (B,)
        t_sampled = jnp.concatenate([resample, bonus[:, None]], axis=1)

        is_greedy = (temperature <= 0)[:, None]
        accept = jnp.where(is_greedy, draft == greedy_t[:, :k], accept_s)
        t_full = jnp.where(is_greedy, greedy_t, t_sampled)       # (B, S)

    # committed drafts = the leading run of accepts; n_advance counts
    # them plus cur itself
    n_accept = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1),
                       axis=1)                                   # (B,) 0..k
    n_advance = (n_accept + 1).astype(jnp.int32)
    next_token = jnp.take_along_axis(t_full, n_accept[:, None],
                                     axis=1)[:, 0]
    return next_token, n_advance


# ---------------------------------------------------------------------------
# Prompt-lookup (n-gram self-speculation) drafting — the default drafter:
# no second model, so it runs anywhere the target does (CPU CI included).
# ---------------------------------------------------------------------------
def draft_ngram(hist: jnp.ndarray, tok: jnp.ndarray, pos: jnp.ndarray,
                k: int, ngram: int = 2) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Draft ``k`` tokens per slot by prompt lookup over ``hist``.

    ``hist`` (B, H) holds each slot's committed tokens (prompt + accepted
    generations) at their absolute positions; ``tok`` (B, 1) is the
    current input token at position ``pos`` (B,).  The current token is
    committed into ``hist`` here (it is emitted unconditionally by the
    spec step), then the most recent earlier occurrence of the trailing
    ``ngram`` tokens is located and its continuation proposed.  A slot
    with no match (or not enough history/continuation) falls back to
    repeating the current token — a deliberately weak proposal that the
    verifier simply rejects, degrading to ≥ 1 token per step.

    Returns ``(drafts (B, k), hist)`` with the current token written.
    Pure jnp, O(B·H·ngram) per call — bandwidth noise next to the
    verification matmuls, and shape-stable so it scans.
    """
    b, h = hist.shape
    lane = jnp.arange(b)
    hist = hist.at[lane, pos].set(tok[:, 0])
    # window ending at t matches the window ending at pos iff
    # hist[t - i] == hist[pos - i] for all i < ngram
    match = jnp.ones((b, h), bool)
    for i in range(ngram):
        ref = hist[lane, jnp.maximum(pos - i, 0)]                # (B,)
        shifted = jnp.pad(hist, ((0, 0), (i, 0)))[:, :h]         # hist[t-i]
        match = match & (shifted == ref[:, None])
    t_arr = jnp.arange(h)[None, :]
    # need a full window at t, a full k-token continuation inside the
    # committed history, t strictly earlier than pos, and enough history
    # for the query window itself
    valid = ((t_arr >= ngram - 1)
             & (t_arr + k <= pos[:, None])
             & (pos[:, None] >= ngram))
    best = jnp.max(jnp.where(match & valid, t_arr, -1), axis=1)  # (B,)
    found = best >= 0
    idx = jnp.clip(jnp.where(found, best, 0)[:, None] + 1
                   + jnp.arange(k)[None, :], 0, h - 1)
    cont = jnp.take_along_axis(hist, idx, axis=1)                # (B, k)
    drafts = jnp.where(found[:, None], cont,
                       jnp.broadcast_to(tok, (b, k)))
    return drafts.astype(jnp.int32), hist
