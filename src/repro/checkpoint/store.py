"""Checkpoint store: per-leaf .npy shards + JSON manifest.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json        # flat path -> {shape, dtype, file}
        <leaf-path>.npy      # full logical value (single-host)

Design points for cluster scale:

* **Elastic restore**: files store the *logical* (global) array; restore
  re-shards onto whatever mesh the job restarts with (``device_put`` with
  the target sharding) — growing or shrinking the mesh between runs needs
  no conversion step.  On a real multi-host pod each host would write its
  addressable shards with an index (the manifest schema already carries
  shape/dtype per leaf); the single-process container writes the fused
  value, which is the degenerate n_hosts=1 case of the same format.
* **Async save**: device→host transfer happens on the caller thread (cheap
  since checkpoints read sharded buffers), file IO in a worker thread;
  ``wait()`` joins before the next save or process exit.
* **Atomicity**: writes go to ``<dir>.tmp`` then ``os.replace`` — a crash
  mid-save never corrupts the latest complete checkpoint.
* **Retention**: ``keep`` most recent complete checkpoints.
"""

from __future__ import annotations

import json
import os
import pickle
import shutil
import struct
import threading
import zlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_state", "restore_state", "latest_step", "save_blob",
           "load_blob", "BlobLog", "BlobLogFollower", "CheckpointManager"]

_SEP = "."


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def name(k):
        for attr in ("key", "name", "idx"):
            if hasattr(k, attr):
                return str(getattr(k, attr))
        return str(k)

    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_SEP.join(name(k) for k in kp)] = leaf
    return flat


def save_state(state, directory: str, step: int) -> str:
    """Blocking save.  Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = path.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest[path] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                          "file": fn}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def save_blob(obj, directory: str, step: int, *, name: str = "blob") -> str:
    """Atomically persist an arbitrary host-side object snapshot.

    The per-leaf .npy format above needs a template to restore into;
    engine snapshots carry ragged host state (queues, partial-output
    lists, spilled page payloads) whose structure only the snapshot
    itself knows, so they go down as ONE object-pickled .npy under the
    same ``step_%08d`` layout and the same tmp + ``os.replace``
    atomics — ``latest_step`` and retention apply unchanged.  Only for
    trusted self-written state (pickle), like every checkpoint here."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arr = np.empty((), dtype=object)
    arr[()] = obj
    np.save(os.path.join(tmp, name + ".npy"), arr, allow_pickle=True)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "blob": name + ".npy"}, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_blob(directory: str, step: int, *, name: str = "blob"):
    """Load a :func:`save_blob` snapshot."""
    path = os.path.join(directory, f"step_{step:08d}", name + ".npy")
    return np.load(path, allow_pickle=True)[()]


class BlobLog:
    """Append-only write-ahead log of pickled records (the journal
    primitive under the serving engine's crash-safe warm restart).

    Framing: each record is ``<u32 length><u32 crc32>`` followed by the
    pickled payload.  :meth:`append` flushes AND ``os.fsync``\\ s before
    returning, so an append that returned is durable — kill -9 the
    process the next instruction and the record replays.

    Torn-tail tolerance: a crash *mid-append* leaves a short or
    CRC-mismatched frame at the end of the file.  Opening for append
    scans the existing frames, keeps every complete one, and truncates
    the torn tail (an os.replace-style atomicity guarantee built from
    sequential appends: the prefix of durable records is always
    intact).  Corruption anywhere *before* the tail cannot be repaired
    and raises — silently resuming past a hole would replay a wrong
    history.

    Only for trusted self-written state (pickle), like every
    checkpoint in this module.
    """

    _HEADER = struct.Struct("<II")

    def __init__(self, path: str, *, fresh: bool = False):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if fresh or not os.path.exists(path):
            self._f = open(path, "wb")
            self.count = 0
        else:
            self.count, good = self._scan()
            with open(path, "r+b") as f:
                f.truncate(good)        # drop a torn tail, keep the rest
            self._f = open(path, "ab")

    def _scan(self):
        """(record count, byte offset after the last complete record).

        Stops at the first short/CRC-broken frame ONLY if it is the
        file's tail (an interrupted append); a broken frame with valid
        data after it is real corruption and raises.
        """
        count, good = 0, 0
        with open(self.path, "rb") as f:
            data = f.read()
        off, end = 0, len(data)
        while off + self._HEADER.size <= end:
            length, crc = self._HEADER.unpack_from(data, off)
            body = data[off + self._HEADER.size:
                        off + self._HEADER.size + length]
            if len(body) < length or zlib.crc32(body) != crc:
                break
            count += 1
            off += self._HEADER.size + length
        good = off
        # anything after the torn frame means mid-file damage, not an
        # interrupted append — refuse to silently drop committed history
        tail = data[good:]
        max_torn = self._HEADER.size + (self._HEADER.unpack_from(
            data, good)[0] if good + self._HEADER.size <= end else len(tail))
        if len(tail) > max_torn:
            raise IOError(
                f"journal {self.path} corrupt at byte {good}: broken "
                f"frame followed by {len(tail) - max_torn} more bytes "
                f"(not a torn tail)")
        # second net, for damage the length bound can't see: a bit flip
        # that ENLARGES a mid-file length field makes every committed
        # record after it look like one huge torn frame.  A torn tail is
        # a partial write of ONE record, so a complete CRC-valid frame
        # anywhere inside it proves the break happened before committed
        # history — refuse rather than drop it.  (Non-empty frames only:
        # crc32(b"") == 0, so eight zero bytes inside a genuinely torn
        # pickle body would otherwise masquerade as a valid empty frame.)
        for probe in range(good + 1, end - self._HEADER.size + 1):
            length, crc = self._HEADER.unpack_from(data, probe)
            if length == 0:
                continue
            body = data[probe + self._HEADER.size:
                        probe + self._HEADER.size + length]
            if len(body) == length and zlib.crc32(body) == crc:
                raise IOError(
                    f"journal {self.path} corrupt at byte {good}: broken "
                    f"frame with a complete valid frame at byte {probe} "
                    f"after it (mid-file damage, not a torn tail)")
        return count, good

    def follow(self) -> "BlobLogFollower":
        """A cursor over this journal for another engine to tail."""
        return BlobLogFollower(self.path)

    def append(self, obj) -> int:
        """Durably append one record; returns its index."""
        body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        self._f.write(self._HEADER.pack(len(body), zlib.crc32(body)))
        self._f.write(body)
        self._f.flush()
        os.fsync(self._f.fileno())
        idx = self.count
        self.count += 1
        return idx

    def read(self, start: int = 0) -> list:
        """Records ``start..`` re-read from disk (tail-tolerant)."""
        out = []
        with open(self.path, "rb") as f:
            data = f.read()
        off, end, i = 0, len(data), 0
        while off + self._HEADER.size <= end:
            length, crc = self._HEADER.unpack_from(data, off)
            body = data[off + self._HEADER.size:
                        off + self._HEADER.size + length]
            if len(body) < length or zlib.crc32(body) != crc:
                break
            if i >= start:
                out.append(pickle.loads(body))
            i += 1
            off += self._HEADER.size + length
        return out

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class BlobLogFollower:
    """Incremental cursor over a :class:`BlobLog` another engine appends
    to — the journal-shipping primitive under the fleet's hot standby.

    :meth:`poll` returns every record that became durable since the
    last call, advancing a (byte offset, record index) cursor.  The
    writer only ever appends, so the follower distinguishes two tail
    states it can observe:

    * a **short frame** (header or body not fully on disk yet) is an
      append in flight — stop, keep the cursor, pick it up next poll;
    * a **complete frame with a CRC mismatch** can never be an append
      in flight (bytes land in order, so a frame whose full claimed
      length is on disk was fully written) — that is corruption, and
      silently skipping it would ship the standby a wrong history, so
      it raises.
    """

    def __init__(self, path: str):
        self.path = path
        self.offset = 0         # byte offset of the next unread frame
        self.count = 0          # records consumed so far

    def poll(self, max_records: Optional[int] = None) -> list:
        """New durable records since the last poll (possibly none)."""
        out: list = []
        if not os.path.exists(self.path):
            return out
        with open(self.path, "rb") as f:
            f.seek(self.offset)
            data = f.read()
        hdr = BlobLog._HEADER
        off, end = 0, len(data)
        while off + hdr.size <= end:
            if max_records is not None and len(out) >= max_records:
                break
            length, crc = hdr.unpack_from(data, off)
            body = data[off + hdr.size: off + hdr.size + length]
            if len(body) < length:
                break               # append in flight: wait for the rest
            if zlib.crc32(body) != crc:
                raise IOError(
                    f"journal {self.path} corrupt at byte "
                    f"{self.offset + off}: CRC mismatch on a complete "
                    f"frame while following")
            out.append(pickle.loads(body))
            off += hdr.size + length
            self.count += 1
        self.offset += off
        return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore_state(template, directory: str, step: int, *,
                  shardings=None):
    """Restore into the structure of ``template`` (a state pytree or
    ShapeDtypeStruct pytree).  ``shardings``: optional matching pytree of
    NamedShardings for elastic placement on the current mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, leaf in flat_t.items():
        fn = os.path.join(path, key.replace("/", "_") + ".npy")
        arr = np.load(fn)
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"ckpt leaf {key}: shape {arr.shape} != {want}")
        sh = flat_s.get(key)
        loaded[key] = (jax.device_put(arr, sh) if sh is not None
                       else jnp.asarray(arr))
    # rebuild the pytree in template order
    treedef = jax.tree_util.tree_structure(template)
    keys = list(_flatten(template).keys())
    return jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in keys])


class CheckpointManager:
    """Async save + retention + restore-latest."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, state, step: int, *, blocking: bool = False):
        self.wait()
        host_state = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), state)

        def work():
            save_state(host_state, self.directory, step)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template, *, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore_state(template, self.directory, step,
                             shardings=shardings), step

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
