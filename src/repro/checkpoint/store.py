"""Checkpoint store: per-leaf .npy shards + JSON manifest.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json        # flat path -> {shape, dtype, file}
        <leaf-path>.npy      # full logical value (single-host)

Design points for cluster scale:

* **Elastic restore**: files store the *logical* (global) array; restore
  re-shards onto whatever mesh the job restarts with (``device_put`` with
  the target sharding) — growing or shrinking the mesh between runs needs
  no conversion step.  On a real multi-host pod each host would write its
  addressable shards with an index (the manifest schema already carries
  shape/dtype per leaf); the single-process container writes the fused
  value, which is the degenerate n_hosts=1 case of the same format.
* **Async save**: device→host transfer happens on the caller thread (cheap
  since checkpoints read sharded buffers), file IO in a worker thread;
  ``wait()`` joins before the next save or process exit.
* **Atomicity**: writes go to ``<dir>.tmp`` then ``os.replace`` — a crash
  mid-save never corrupts the latest complete checkpoint.
* **Retention**: ``keep`` most recent complete checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_state", "restore_state", "latest_step", "save_blob",
           "load_blob", "CheckpointManager"]

_SEP = "."


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def name(k):
        for attr in ("key", "name", "idx"):
            if hasattr(k, attr):
                return str(getattr(k, attr))
        return str(k)

    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_SEP.join(name(k) for k in kp)] = leaf
    return flat


def save_state(state, directory: str, step: int) -> str:
    """Blocking save.  Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = path.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest[path] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                          "file": fn}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def save_blob(obj, directory: str, step: int, *, name: str = "blob") -> str:
    """Atomically persist an arbitrary host-side object snapshot.

    The per-leaf .npy format above needs a template to restore into;
    engine snapshots carry ragged host state (queues, partial-output
    lists, spilled page payloads) whose structure only the snapshot
    itself knows, so they go down as ONE object-pickled .npy under the
    same ``step_%08d`` layout and the same tmp + ``os.replace``
    atomics — ``latest_step`` and retention apply unchanged.  Only for
    trusted self-written state (pickle), like every checkpoint here."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arr = np.empty((), dtype=object)
    arr[()] = obj
    np.save(os.path.join(tmp, name + ".npy"), arr, allow_pickle=True)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "blob": name + ".npy"}, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_blob(directory: str, step: int, *, name: str = "blob"):
    """Load a :func:`save_blob` snapshot."""
    path = os.path.join(directory, f"step_{step:08d}", name + ".npy")
    return np.load(path, allow_pickle=True)[()]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore_state(template, directory: str, step: int, *,
                  shardings=None):
    """Restore into the structure of ``template`` (a state pytree or
    ShapeDtypeStruct pytree).  ``shardings``: optional matching pytree of
    NamedShardings for elastic placement on the current mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, leaf in flat_t.items():
        fn = os.path.join(path, key.replace("/", "_") + ".npy")
        arr = np.load(fn)
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"ckpt leaf {key}: shape {arr.shape} != {want}")
        sh = flat_s.get(key)
        loaded[key] = (jax.device_put(arr, sh) if sh is not None
                       else jnp.asarray(arr))
    # rebuild the pytree in template order
    treedef = jax.tree_util.tree_structure(template)
    keys = list(_flatten(template).keys())
    return jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in keys])


class CheckpointManager:
    """Async save + retention + restore-latest."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, state, step: int, *, blocking: bool = False):
        self.wait()
        host_state = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), state)

        def work():
            save_state(host_state, self.directory, step)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template, *, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return restore_state(template, self.directory, step,
                             shardings=shardings), step

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
