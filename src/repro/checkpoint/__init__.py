"""Sharded checkpointing with async save and elastic restore."""

from .store import (BlobLog, BlobLogFollower, CheckpointManager,
                    latest_step, restore_state, save_state)

__all__ = ["BlobLog", "BlobLogFollower", "CheckpointManager", "latest_step",
           "restore_state", "save_state"]
