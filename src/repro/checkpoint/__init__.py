"""Sharded checkpointing with async save and elastic restore."""

from .store import (CheckpointManager, latest_step, restore_state,
                    save_state)

__all__ = ["CheckpointManager", "latest_step", "restore_state", "save_state"]
