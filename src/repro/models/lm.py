"""Decoder-only language models: dense (yi/gemma/glm4/command-r) and MoE
(deepseek-v2 with MLA + shared experts, olmoe).

The layer stack is organised as (optional) leading dense layers followed by
the homogeneous body — each run of identical blocks is one ``lax.scan``
over stacked params, so the HLO is depth-independent.  Decode maintains a
per-layer KV cache scanned alongside the params (MLA uses the latent cache;
GQA the standard (B, Hkv, S, Dh) pair).

Paged decode rides the split-KV kernel: every layer's ``gqa_apply`` call
resolves ``ctx.kv_split``/``ctx.pages_per_step`` against its block table,
so one engine-level knob tunes the whole stack (and speculative
verification, which is just an S = k+1 call of the same path).  MLA's
absorbed decode scores against the gathered latent instead — the latent
has no per-head pages to split (the paged MLA pool is (P, ps, lora)).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..nn.attention import (gqa_cache_spec, gqa_paged_cache_spec,
                            mla_cache_spec, mla_paged_cache_spec)
from ..nn.blocks import (dense_block_apply, dense_block_init, moe_block_apply,
                         moe_block_init, norm_apply, norm_init, scan_apply,
                         stack_init)
from ..nn.context import DEFAULT_CTX, QuantContext
from ..nn.embedding import embed, embedding_init, unembed
from ..nn.linear import linear, linear_init
from .common import cross_entropy
from .config import ModelConfig

__all__ = ["init", "forward", "loss", "init_cache", "init_paged_cache",
           "prefill", "decode_step"]


def _split_layers(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_dense, n_moe) leading-dense split."""
    if cfg.moe is None:
        return cfg.n_layers, 0
    k = cfg.moe.first_k_dense
    return k, cfg.n_layers - k


def init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    n_dense, n_moe = _split_layers(cfg)
    params = {"embed": embedding_init(ks[0], cfg.vocab, cfg.d_model,
                                      dtype=dtype),
              "final_norm": norm_init(cfg)}
    if not cfg.tie_embeddings:
        params["head"] = linear_init(ks[3], cfg.d_model, cfg.vocab,
                                     dtype=dtype)
    if n_dense:
        params["dense"] = stack_init(
            ks[1], n_dense, lambda k: dense_block_init(k, cfg, dtype=dtype))
    if n_moe:
        params["moe"] = stack_init(
            ks[2], n_moe, lambda k: moe_block_init(k, cfg, dtype=dtype))
    return params


def _dense_body(cfg, ctx, cache_pos):
    def body(p_l, x, cache_l):
        x2, new_c = dense_block_apply(p_l, x, cfg, ctx, cache=cache_l,
                                      cache_pos=cache_pos)
        return x2, new_c, jnp.zeros((), jnp.float32)
    return body


def _moe_body(cfg, ctx, cache_pos):
    def body(p_l, x, cache_l):
        x2, new_c, aux = moe_block_apply(p_l, x, cfg, ctx, cache=cache_l,
                                         cache_pos=cache_pos)
        return x2, new_c, aux
    return body


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            ctx: QuantContext = DEFAULT_CTX, *, cache=None,
            cache_pos: Optional[jnp.ndarray] = None):
    """tokens (B, S) → (logits (B, S, V), new_cache, aux_loss)."""
    x = embed(params["embed"], tokens, ctx, scale_by_dim=cfg.embed_scale)
    n_dense, n_moe = _split_layers(cfg)
    remat = cfg.remat if cache is None else "none"
    unroll = ctx.scan_unroll
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}

    if n_dense:
        c = cache.get("dense") if cache else None
        x, nc, a = scan_apply(params["dense"], x,
                              _dense_body(cfg, ctx, cache_pos), remat=remat,
                              unroll=unroll, per_layer=c)
        new_cache["dense"], aux = nc, aux + a
    if n_moe:
        c = cache.get("moe") if cache else None
        x, nc, a = scan_apply(params["moe"], x,
                              _moe_body(cfg, ctx, cache_pos), remat=remat,
                              unroll=unroll, per_layer=c)
        new_cache["moe"], aux = nc, aux + a

    x = norm_apply(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, ctx)
    else:
        logits = linear(params["head"], x, ctx, path="head")
    from ..dist.constrain import constrain
    logits = constrain(logits, "dp", None, "tp")
    return logits, (new_cache if cache is not None else None), aux


def loss(params, batch, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX):
    logits, _, aux = forward(params, batch["tokens"], cfg, ctx)
    ce, metrics = cross_entropy(logits, batch["labels"])
    total = ce
    if cfg.moe is not None:
        total = total + cfg.moe.aux_loss_weight * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = total
    return total, metrics


# -- serving ------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    n_dense, n_moe = _split_layers(cfg)

    def one(_):
        if cfg.attn_kind == "mla":
            return mla_cache_spec(cfg.mla, batch, max_len, dtype)
        return gqa_cache_spec(cfg.attn_dims(), batch, max_len, dtype)

    cache = {}
    if n_dense:
        cache["dense"] = jax.vmap(one)(jnp.arange(n_dense))
    if n_moe:
        cache["moe"] = jax.vmap(one)(jnp.arange(n_moe))
    return cache


def init_paged_cache(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int, table_width: int, dtype=jnp.bfloat16):
    """Paged serving cache: per-layer KV pages + per-layer block tables.

    The page *pool* is per layer ((L, P+1, ...) leaves — every layer
    needs its own KV rows) but the page *assignment* is shared: one
    host-side allocation covers all layers, and the engine broadcasts
    the (B, NP) block table across the layer axis
    (:func:`repro.models.api.set_block_table`), so logical token ``t``
    of a slot lives at the same physical page index in every layer.
    """
    n_dense, n_moe = _split_layers(cfg)

    def one(_):
        if cfg.attn_kind == "mla":
            return mla_paged_cache_spec(cfg.mla, batch, num_pages,
                                        page_size, table_width, dtype)
        return gqa_paged_cache_spec(cfg.attn_dims(), batch, num_pages,
                                    page_size, table_width, dtype)

    cache = {}
    if n_dense:
        cache["dense"] = jax.vmap(one)(jnp.arange(n_dense))
    if n_moe:
        cache["moe"] = jax.vmap(one)(jnp.arange(n_moe))
    return cache


# slot invalidation / merge: dense cache leaves are (layers, B, ...), so
# the generic axis-1 implementations in models.api apply; the paged
# cache has NO batch-indexed KV state to zero (a retired slot's pages
# become unreachable the moment the engine resets its block table), so
# the generic paged no-op in models.api applies too (no hook here).
def prefill(params, tokens: jnp.ndarray, cache, cfg: ModelConfig,
            ctx: QuantContext = DEFAULT_CTX, *, pos=None,
            full_logits: bool = False):
    """Run prompt tokens through the model, filling the cache.

    ``pos`` (B,): per-slot start positions for chunked prefill (None =
    whole prompt from 0).  ``full_logits=True`` returns logits at every
    position of this chunk instead of only the last.
    """
    b = tokens.shape[0]
    start = jnp.zeros((b,), jnp.int32) if pos is None else pos
    logits, new_cache, _ = forward(params, tokens, cfg, ctx, cache=cache,
                                   cache_pos=start)
    return (logits if full_logits else logits[:, -1:]), new_cache


def decode_step(params, tokens: jnp.ndarray, cache, pos: jnp.ndarray,
                cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX):
    """One decode step.  tokens (B, 1); pos (B,) current cache length."""
    logits, new_cache, _ = forward(params, tokens, cfg, ctx, cache=cache,
                                   cache_pos=pos)
    return logits, new_cache
