"""Vision-language backbone (llama-3.2-vision-11b): dense self-attention
layers with gated cross-attention layers interleaved every
``cross_attn_every`` layers, attending to stub image-patch embeddings.

Per the brief the vision frontend is a STUB: ``batch["img_embed"]`` carries
precomputed patch embeddings (B, n_img_tokens, D).  Structure: G groups of
(scan over k-1 self layers → gated cross layer); upstream places cross
layers at {3, 8, ..., 38} — our grouping is the same cadence shifted by
one (DESIGN.md notes the deviation).

Serving: self layers keep per-layer KV caches; cross K/V are projected
once at prefill and reused every decode step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..nn.attention import gqa_cache_spec, gqa_project_kv
from ..nn.blocks import (cross_block_apply, cross_block_init,
                         dense_block_apply, dense_block_init, norm_apply,
                         norm_init, scan_apply, stack_init)
from ..nn.context import DEFAULT_CTX, QuantContext
from ..nn.embedding import embed, embedding_init, unembed
from .common import cross_entropy
from .config import ModelConfig

__all__ = ["init", "forward", "loss", "init_cache", "prefill", "decode_step"]


def _group_structure(cfg: ModelConfig):
    k = cfg.cross_attn_every
    n_groups = cfg.n_layers // k
    return n_groups, k - 1  # (groups, self layers per group)


def init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    n_groups, k_self = _group_structure(cfg)
    return {
        "embed": embedding_init(ks[0], cfg.vocab, cfg.d_model, dtype=dtype),
        "groups": stack_init(
            ks[1], n_groups,
            lambda kk: stack_init(kk, k_self,
                                  lambda k2: dense_block_init(k2, cfg,
                                                              dtype=dtype))),
        "cross": stack_init(ks[2], n_groups,
                            lambda kk: cross_block_init(kk, cfg, gated=True,
                                                        dtype=dtype)),
        "final_norm": norm_init(cfg),
    }


def forward(params, tokens, img_embed, cfg: ModelConfig,
            ctx: QuantContext = DEFAULT_CTX, *, cache=None, cache_pos=None,
            cross_kv=None):
    n_groups, k_self = _group_structure(cfg)
    x = embed(params["embed"], tokens, ctx)
    remat = cfg.remat if cache is None else "none"
    img = (img_embed.astype(ctx.compute_dtype)
           if img_embed is not None else None)

    def body(p_l, x, cache_l):
        x2, nc = dense_block_apply(p_l, x, cfg, ctx, cache=cache_l,
                                   cache_pos=cache_pos)
        return x2, nc, jnp.zeros(())

    new_self, kv_out = [], []
    for g in range(n_groups):
        p_g = jax.tree_util.tree_map(lambda t: t[g], params["groups"])
        c_g = (jax.tree_util.tree_map(lambda t: t[g], cache["self"])
               if cache is not None else None)
        x, ns, _ = scan_apply(p_g, x, body, remat=remat,
                              unroll=ctx.scan_unroll, per_layer=c_g)
        new_self.append(ns)
        p_x = jax.tree_util.tree_map(lambda t: t[g], params["cross"])
        kv_g = (jax.tree_util.tree_map(lambda t: t[g], cross_kv)
                if cross_kv is not None else None)
        if kv_g is None and img is not None:
            kv_g = gqa_project_kv(p_x["attn"], img,
                                  cfg.attn_dims(causal=False), ctx)
        kv_out.append(kv_g)
        x = cross_block_apply(p_x, x, img, cfg, ctx) if kv_g is None else \
            _cross_with_cached(p_x, x, kv_g, cfg, ctx)

    x = norm_apply(cfg, params["final_norm"], x)
    from ..dist.constrain import constrain
    logits = constrain(unembed(params["embed"], x, ctx), "dp", None, "tp")
    new_cache = None
    if cache is not None:
        stack = lambda ts: jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *ts)
        new_cache = {"self": stack(new_self), "cross_kv": stack(kv_out)}
    return logits, new_cache


def _cross_with_cached(p, x, kv, cfg, ctx):
    from ..nn.attention import gqa_apply
    from ..nn.blocks import mlp_apply
    a, _ = gqa_apply(p["attn"], norm_apply(cfg, p["ln1"], x),
                     cfg.attn_dims(causal=False), ctx, cached_kv=kv,
                     path="cross/attn")
    a = a * jnp.tanh(p["gate_attn"]).astype(a.dtype)
    x = x + a
    m = mlp_apply(p["mlp"], norm_apply(cfg, p["ln2"], x), cfg.mlp_act, ctx,
                  path="cross/mlp")
    return x + m * jnp.tanh(p["gate_mlp"]).astype(m.dtype)


def loss(params, batch, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX):
    logits, _ = forward(params, batch["tokens"], batch["img_embed"], cfg, ctx)
    ce, metrics = cross_entropy(logits, batch["labels"])
    metrics["loss"] = ce
    return ce, metrics


# -- serving -------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    n_groups, k_self = _group_structure(cfg)
    dims = cfg.attn_dims()
    self_c = jax.vmap(lambda _: jax.vmap(
        lambda __: gqa_cache_spec(dims, batch, max_len, dtype))(
            jnp.arange(k_self)))(jnp.arange(n_groups))
    kv = jnp.zeros((n_groups, batch, dims.n_kv_heads, cfg.n_img_tokens,
                    dims.head_dim), dtype)
    return {"self": self_c, "cross_kv": (kv, kv)}


def prefill(params, batch, cache, cfg: ModelConfig,
            ctx: QuantContext = DEFAULT_CTX, *, pos=None,
            full_logits: bool = False):
    b = batch["tokens"].shape[0]
    start = jnp.zeros((b,), jnp.int32) if pos is None else pos
    logits, new_cache = forward(params, batch["tokens"], batch["img_embed"],
                                cfg, ctx, cache=cache, cache_pos=start)
    new_cache["cross_kv"] = tuple(
        t.astype(cache["cross_kv"][0].dtype) for t in new_cache["cross_kv"])
    return (logits if full_logits else logits[:, -1:]), new_cache


def decode_step(params, tokens, cache, pos, cfg: ModelConfig,
                ctx: QuantContext = DEFAULT_CTX):
    logits, new_cache = forward(params, tokens, None, cfg, ctx,
                                cache=cache, cache_pos=pos,
                                cross_kv=cache["cross_kv"])
    return logits, new_cache
