"""Pure SSM language model (mamba2-370m): stack of Mamba-2 SSD blocks.

Decode carries the O(1) recurrence state per layer — this is the family
that runs the ``long_500k`` shape (state size independent of context).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..nn.blocks import (mamba_block_apply, mamba_block_init, norm_apply,
                         norm_init, scan_apply, stack_init)
from ..nn.context import DEFAULT_CTX, QuantContext
from ..nn.embedding import embed, embedding_init, unembed
from ..nn.ssm import mamba2_state_spec
from .common import cross_entropy
from .config import ModelConfig

__all__ = ["init", "forward", "loss", "init_cache", "init_paged_cache",
           "prefill", "decode_step", "spec_state", "spec_restore"]


def init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 2)
    return {
        "embed": embedding_init(ks[0], cfg.vocab, cfg.d_model, dtype=dtype),
        "layers": stack_init(ks[1], cfg.n_layers,
                             lambda k: mamba_block_init(k, cfg, dtype=dtype)),
        "final_norm": norm_init(cfg),
    }


def forward(params, tokens, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX,
            *, state=None, decode: bool = False):
    x = embed(params["embed"], tokens, ctx)

    def body(p_l, x, state_l):
        x2, new_s = mamba_block_apply(p_l, x, cfg, ctx, state=state_l,
                                      decode=decode)
        return x2, new_s, jnp.zeros(())

    x, new_states, _ = scan_apply(params["layers"], x, body,
                                  remat=cfg.remat if not decode else "none",
                                  unroll=ctx.scan_unroll, per_layer=state)
    x = norm_apply(cfg, params["final_norm"], x)
    from ..dist.constrain import constrain
    logits = constrain(unembed(params["embed"], x, ctx), "dp", None, "tp")
    return logits, new_states


def loss(params, batch, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX):
    logits, _ = forward(params, batch["tokens"], cfg, ctx)
    ce, metrics = cross_entropy(logits, batch["labels"])
    metrics["loss"] = ce
    return ce, metrics


# -- serving -------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    del max_len  # state is O(1) in context length

    def one(_):
        return mamba2_state_spec(cfg.ssm, batch, jnp.float32)

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def init_paged_cache(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int, table_width: int, dtype=jnp.bfloat16):
    """There is no KV axis to page: the recurrent state is O(1) in
    context length, so the paged engine serves this family with the
    dense state cache unchanged (the page pool only meters admission)."""
    del num_pages, page_size, table_width
    return init_cache(cfg, batch, 0, dtype)


# slot invalidation / merge: state leaves are (layers, B, ...), so the
# generic axis-1 implementations in models.api apply (no hook here).
def spec_state(cache):
    """The whole cache is recurrent state — speculative rollback must
    checkpoint every leaf.  Leaves go batch-first ((L, B, ...) →
    (B, L, ...)) so per-slot checkpoint selection is uniform."""
    return jax.tree_util.tree_map(lambda t: jnp.moveaxis(t, 1, 0), cache)


def spec_restore(cache, state):
    del cache  # fully recurrent: the restored state IS the cache
    return jax.tree_util.tree_map(lambda t: jnp.moveaxis(t, 0, 1), state)


def prefill(params, tokens, cache, cfg: ModelConfig,
            ctx: QuantContext = DEFAULT_CTX, *, pos=None,
            full_logits: bool = False):
    """Full-sequence SSD prefill; final per-layer states seed decode.

    The recurrent state is position-free, so ``pos`` is ignored — and
    because the state is rebuilt from this call's tokens alone, SSM
    prefill must ingest the whole prompt in one call."""
    del cache, pos  # rebuilt from the prefill pass; state is position-free
    logits, states = forward(params, tokens, cfg, ctx)
    return (logits if full_logits else logits[:, -1:]), states


def decode_step(params, tokens, cache, pos, cfg: ModelConfig,
                ctx: QuantContext = DEFAULT_CTX):
    del pos  # recurrent state is position-free
    logits, new_state = forward(params, tokens, cfg, ctx, state=cache,
                                decode=True)
    return logits, new_state
