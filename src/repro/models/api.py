"""Family registry: uniform interface over the five model families.

Every family module exposes::

    init(rng, cfg, *, dtype)                        -> params
    loss(params, batch, cfg, ctx)                   -> (scalar, metrics)
    init_cache(cfg, batch, max_len, dtype)          -> cache pytree
    prefill(params, <tokens|batch>, cache, cfg, ctx)-> (last_logits, cache)
    decode_step(params, tokens, cache, pos, cfg, ctx)-> (logits, cache)

``batch`` layouts (see repro.data): lm/ssm/hybrid use {"tokens",
"labels"}; encdec adds "enc_input"; vlm adds "img_embed".
"""

from __future__ import annotations

from types import ModuleType

from . import encdec, hybrid, lm, ssm_lm, vlm
from .config import ModelConfig

__all__ = ["get_family", "FAMILIES"]

FAMILIES = {
    "lm": lm,
    "encdec": encdec,
    "ssm": ssm_lm,
    "hybrid": hybrid,
    "vlm": vlm,
}


def get_family(cfg: ModelConfig) -> ModuleType:
    try:
        return FAMILIES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown family {cfg.family!r} "
                       f"(have {sorted(FAMILIES)})") from None


def loss_fn(params, batch, cfg: ModelConfig, ctx):
    """Family-dispatched training loss."""
    fam = get_family(cfg)
    return fam.loss(params, batch, cfg, ctx)


def prefill_fn(params, batch, cache, cfg: ModelConfig, ctx, *,
               pos=None, full_logits: bool = False):
    """Family-dispatched prefill.

    ``pos``: optional (B,) start positions — the chunked-prefill regime
    (each call ingests one prompt chunk; the KV cache continues from
    ``pos`` instead of 0).  ``full_logits=True`` returns logits for every
    chunk position instead of only the last one, so a serving engine can
    read each sequence's true last-token logits when prompts end
    mid-chunk.
    """
    fam = get_family(cfg)
    if cfg.family in ("encdec", "vlm"):
        return fam.prefill(params, batch, cache, cfg, ctx, pos=pos,
                           full_logits=full_logits)
    return fam.prefill(params, batch["tokens"], cache, cfg, ctx, pos=pos,
                       full_logits=full_logits)


def decode_fn(params, tokens, cache, pos, cfg: ModelConfig, ctx):
    return get_family(cfg).decode_step(params, tokens, cache, pos, cfg, ctx)
