"""Family registry: uniform interface over the five model families.

Every family module exposes::

    init(rng, cfg, *, dtype)                        -> params
    loss(params, batch, cfg, ctx)                   -> (scalar, metrics)
    init_cache(cfg, batch, max_len, dtype)          -> cache pytree
    prefill(params, <tokens|batch>, cache, cfg, ctx)-> (last_logits, cache)
    decode_step(params, tokens, cache, pos, cfg, ctx)-> (logits, cache)

``batch`` layouts (see repro.data): lm/ssm/hybrid use {"tokens",
"labels"}; encdec adds "enc_input"; vlm adds "img_embed".
"""

from __future__ import annotations

from types import ModuleType

from . import encdec, hybrid, lm, ssm_lm, vlm
from .config import ModelConfig

__all__ = ["get_family", "FAMILIES"]

FAMILIES = {
    "lm": lm,
    "encdec": encdec,
    "ssm": ssm_lm,
    "hybrid": hybrid,
    "vlm": vlm,
}


def get_family(cfg: ModelConfig) -> ModuleType:
    try:
        return FAMILIES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown family {cfg.family!r} "
                       f"(have {sorted(FAMILIES)})") from None


def loss_fn(params, batch, cfg: ModelConfig, ctx):
    """Family-dispatched training loss."""
    fam = get_family(cfg)
    return fam.loss(params, batch, cfg, ctx)


def prefill_fn(params, batch, cache, cfg: ModelConfig, ctx, *,
               pos=None, full_logits: bool = False):
    """Family-dispatched prefill.

    ``pos``: optional (B,) start positions — the chunked-prefill regime
    (each call ingests one prompt chunk; the KV cache continues from
    ``pos`` instead of 0).  ``full_logits=True`` returns logits for every
    chunk position instead of only the last one, so a serving engine can
    read each sequence's true last-token logits when prompts end
    mid-chunk.
    """
    fam = get_family(cfg)
    if cfg.family in ("encdec", "vlm"):
        return fam.prefill(params, batch, cache, cfg, ctx, pos=pos,
                           full_logits=full_logits)
    return fam.prefill(params, batch["tokens"], cache, cfg, ctx, pos=pos,
                       full_logits=full_logits)


def decode_fn(params, tokens, cache, pos, cfg: ModelConfig, ctx):
    """Family-dispatched single decode step.

    Pure in (cache, pos) with a shape/dtype-stable cache pytree, so it
    can be threaded as a ``lax.scan`` carry — the contract
    ``build_decode_loop`` relies on for the fused multi-token decode.
    """
    return get_family(cfg).decode_step(params, tokens, cache, pos, cfg, ctx)


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Families whose cache continues across prefill calls (attention
    KV); recurrent-state families rebuild from one call's tokens."""
    return cfg.family == "lm"


def invalidate_fn(cache, slot, cfg: ModelConfig):
    """Zero one slot's serving state (KV rows / recurrent state) so a
    recycled slot can never observe its previous occupant.

    The shared implementation zeroes batch-axis 1 — the (layers, B, ...)
    layout every uniform cache uses (lm KV stacks, ssm state stacks).
    A family whose cache mixes batch axes overrides via its own
    ``invalidate_slot`` hook (hybrid: grouped ssm states are
    (G, k, B, ...)).
    """
    fam = get_family(cfg)
    if hasattr(fam, "invalidate_slot"):
        return fam.invalidate_slot(cache, slot)
    import jax
    return jax.tree_util.tree_map(lambda c: c.at[:, slot].set(0), cache)


def merge_slot_fn(new_cache, old_cache, slot, cfg: ModelConfig):
    """``old_cache`` with only ``slot``'s lane taken from ``new_cache``.

    The per-slot prefill isolation primitive: the looped prefill runs
    full-batch decode calls, which advance EVERY lane's state on
    recurrent families (even for pad-token inputs) — restoring the
    other lanes afterwards keeps a slot's prefill exactly equivalent to
    a solo prefill and leaves mid-generation neighbours untouched.
    Batch-axis dispatch as in :func:`invalidate_fn`.
    """
    fam = get_family(cfg)
    if hasattr(fam, "merge_slot"):
        return fam.merge_slot(new_cache, old_cache, slot)
    import jax
    return jax.tree_util.tree_map(
        lambda n, o: o.at[:, slot].set(n[:, slot]), new_cache, old_cache)
