"""Family registry: uniform interface over the five model families.

Every family module exposes::

    init(rng, cfg, *, dtype)                        -> params
    loss(params, batch, cfg, ctx)                   -> (scalar, metrics)
    init_cache(cfg, batch, max_len, dtype)          -> cache pytree
    prefill(params, <tokens|batch>, cache, cfg, ctx)-> (last_logits, cache)
    decode_step(params, tokens, cache, pos, cfg, ctx)-> (logits, cache)

``batch`` layouts (see repro.data): lm/ssm/hybrid use {"tokens",
"labels"}; encdec adds "enc_input"; vlm adds "img_embed".
"""

from __future__ import annotations

from types import ModuleType

from . import encdec, hybrid, lm, ssm_lm, vlm
from .config import ModelConfig

__all__ = ["get_family", "FAMILIES", "init_paged_cache_fn",
           "set_block_table", "copy_pages_fn", "spec_state_fn",
           "spec_restore_fn"]

FAMILIES = {
    "lm": lm,
    "encdec": encdec,
    "ssm": ssm_lm,
    "hybrid": hybrid,
    "vlm": vlm,
}


def get_family(cfg: ModelConfig) -> ModuleType:
    try:
        return FAMILIES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown family {cfg.family!r} "
                       f"(have {sorted(FAMILIES)})") from None


def loss_fn(params, batch, cfg: ModelConfig, ctx):
    """Family-dispatched training loss."""
    fam = get_family(cfg)
    return fam.loss(params, batch, cfg, ctx)


def prefill_fn(params, batch, cache, cfg: ModelConfig, ctx, *,
               pos=None, full_logits: bool = False):
    """Family-dispatched prefill.

    ``pos``: optional (B,) start positions — the chunked-prefill regime
    (each call ingests one prompt chunk; the KV cache continues from
    ``pos`` instead of 0).  ``full_logits=True`` returns logits for every
    chunk position instead of only the last one, so a serving engine can
    read each sequence's true last-token logits when prompts end
    mid-chunk.
    """
    fam = get_family(cfg)
    if cfg.family in ("encdec", "vlm"):
        return fam.prefill(params, batch, cache, cfg, ctx, pos=pos,
                           full_logits=full_logits)
    return fam.prefill(params, batch["tokens"], cache, cfg, ctx, pos=pos,
                       full_logits=full_logits)


def decode_fn(params, tokens, cache, pos, cfg: ModelConfig, ctx):
    """Family-dispatched single decode step.

    Pure in (cache, pos) with a shape/dtype-stable cache pytree, so it
    can be threaded as a ``lax.scan`` carry — the contract
    ``build_decode_loop`` relies on for the fused multi-token decode.
    """
    return get_family(cfg).decode_step(params, tokens, cache, pos, cfg, ctx)


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Families whose cache continues across prefill calls (attention
    KV); recurrent-state families rebuild from one call's tokens."""
    return cfg.family == "lm"


def init_paged_cache_fn(cfg: ModelConfig, batch: int, num_pages: int,
                        page_size: int, table_width: int, dtype):
    """Family-dispatched paged serving cache (see each family's
    ``init_paged_cache``): KV leaves become shared page pools +
    layer-tiled block tables; recurrent state stays dense."""
    fam = get_family(cfg)
    if not hasattr(fam, "init_paged_cache"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no paged serving cache; serve it "
            f"with a dense engine (paged=False)")
    return fam.init_paged_cache(cfg, batch, num_pages, page_size,
                                table_width, dtype)


def _is_paged(cache) -> bool:
    """A serving cache is paged iff any subtree carries a block table."""
    import jax
    return any(
        getattr(p[-1], "key", None) == "block_table"
        for p, _ in jax.tree_util.tree_flatten_with_path(cache)[0])


def set_block_table(cache, bt):
    """Write the engine's (B, NP) block table into every paged subtree.

    Page *assignment* is a host-side decision (the free-list allocator);
    this is the one channel by which it reaches the device: each
    ``block_table`` leaf (layer- or group-tiled to (L, B, NP)) is
    replaced by a broadcast of the new table.  Pages themselves are
    never touched — retiring a slot is just this table edit plus a
    host-side free-list append, O(pages) instead of O(max_len) zeroing.
    """
    import jax
    import jax.numpy as jnp
    bt = jnp.asarray(bt, jnp.int32)

    def repl(path, leaf):
        if getattr(path[-1], "key", None) == "block_table":
            return jnp.broadcast_to(bt, leaf.shape)
        return leaf

    return jax.tree_util.tree_map_with_path(repl, cache)


def copy_pages_fn(cache, src, dst):
    """Copy physical pages ``src`` -> ``dst`` in every page-pool leaf.

    The copy-on-write primitive for prefix caching: a slot about to
    write into a page other consumers still reference gets its own
    physical copy first.  Every page-pool leaf carries the page axis at
    position 1 — (layers_or_groups, num_pages+1, ...) — for KV and int8
    scale leaves alike, so one gather/scatter covers all of them; block
    tables and recurrent state are untouched (re-targeting the table is
    the caller's host-side edit).  ``src``/``dst`` may be scalars or
    equal-length id vectors.
    """
    import jax
    import jax.numpy as jnp
    src = jnp.atleast_1d(jnp.asarray(src, jnp.int32))
    dst = jnp.atleast_1d(jnp.asarray(dst, jnp.int32))

    def cp(path, leaf):
        if any(getattr(k, "key", None) == "pages" for k in path):
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf

    return jax.tree_util.tree_map_with_path(cp, cache)


def invalidate_fn(cache, slot, cfg: ModelConfig):
    """Zero one slot's serving state (KV rows / recurrent state) so a
    recycled slot can never observe its previous occupant.

    The shared implementation zeroes batch-axis 1 — the (layers, B, ...)
    layout every uniform cache uses (lm KV stacks, ssm state stacks).
    A family whose cache mixes batch axes overrides via its own
    ``invalidate_slot`` hook (hybrid: grouped ssm states are
    (G, k, B, ...)).  A fully paged cache (lm) is returned unchanged:
    its KV pages carry no batch axis, and the retired slot's pages are
    unreachable once the engine resets its block table row.
    """
    fam = get_family(cfg)
    if hasattr(fam, "invalidate_slot"):
        return fam.invalidate_slot(cache, slot)
    if _is_paged(cache):
        return cache
    import jax
    return jax.tree_util.tree_map(lambda c: c.at[:, slot].set(0), cache)


def spec_state_fn(cache, cfg: ModelConfig):
    """The *recurrent* part of a serving cache, batch axis leading.

    Speculative decoding's multi-token advance runs the target over a
    drafted block and then rewinds to the accepted prefix.  KV rows
    rewind for free — a scalar ``pos`` edit makes the rejected rows
    unreachable (write-before-attend: the next block overwrites them
    before any query can attend them).  Recurrent state cannot rewind:
    it already *consumed* the rejected tokens.  This hook returns the
    subtree that must be checkpointed per block position (None for
    pure-KV families), with every leaf transposed batch-first so the
    per-slot checkpoint gather after verification is one uniform
    ``t[n_advance - 1, arange(B)]`` regardless of each family's native
    batch axis.  :func:`spec_restore_fn` is its inverse.
    """
    fam = get_family(cfg)
    if hasattr(fam, "spec_state"):
        return fam.spec_state(cache)
    return None                       # lm: KV-only, pos rewind suffices


def spec_restore_fn(cache, state, cfg: ModelConfig):
    """Write a batch-leading recurrent checkpoint back into ``cache``.

    ``state`` is a (possibly per-slot-gathered) pytree in the layout
    :func:`spec_state_fn` produced; families that checkpoint nothing
    return the cache unchanged.
    """
    fam = get_family(cfg)
    if hasattr(fam, "spec_restore"):
        return fam.spec_restore(cache, state)
    return cache


def merge_slot_fn(new_cache, old_cache, slot, cfg: ModelConfig):
    """``old_cache`` with only ``slot``'s lane taken from ``new_cache``.

    The per-slot prefill isolation primitive: the looped prefill runs
    full-batch decode calls, which advance EVERY lane's state on
    recurrent families (even for pad-token inputs) — restoring the
    other lanes afterwards keeps a slot's prefill exactly equivalent to
    a solo prefill and leaves mid-generation neighbours untouched.
    Batch-axis dispatch as in :func:`invalidate_fn`.
    """
    fam = get_family(cfg)
    if hasattr(fam, "merge_slot"):
        return fam.merge_slot(new_cache, old_cache, slot)
    import jax
    return jax.tree_util.tree_map(
        lambda n, o: o.at[:, slot].set(n[:, slot]), new_cache, old_cache)
