"""Model families assembled from the nn substrate."""

from .config import ModelConfig, MoEConfig

__all__ = ["ModelConfig", "MoEConfig"]
