"""Unified architecture configuration (the ``--arch`` contract).

One frozen dataclass covers all five families (lm / encdec / ssm / hybrid /
vlm); family-specific sections are optional sub-configs.  Every assigned
architecture in ``repro.configs`` instantiates exactly one of these, and
``smoke()`` derives the reduced-width variant used by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..nn.attention import AttnDims, MLADims
from ..nn.ssm import SSMDims

__all__ = ["MoEConfig", "ModelConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # deepseek: always-on shared experts
    first_k_dense: int = 0       # deepseek: leading dense layers
    renormalize: bool = True
    capacity_factor: float = 1.25
    routed_scale: float = 1.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # lm | encdec | ssm | hybrid | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention (unused for pure ssm)
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    # dense FFN
    d_ff: int = 0
    mlp_act: str = "silu"        # silu | gelu (gated) | gelu_plain (fc1/fc2)
    mlp_gated: bool = True
    # block structure
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    norm_eps: float = 1e-6
    norm_plus_one: bool = False  # gemma (1 + scale)
    parallel_block: bool = False # command-r
    qkv_bias: bool = False       # glm4
    # embeddings / positions
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma √d scaling
    pos_type: str = "rope"       # rope | learned | sinusoidal
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0   # glm4: 0.5
    max_position: int = 1 << 19  # learned-pos table size / rope max
    # family sections
    attn_kind: str = "gqa"       # gqa | mla
    mla: Optional[MLADims] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMDims] = None
    # hybrid (zamba2): one shared transformer block applied every k layers
    shared_attn_every: int = 0
    # vlm (llama-vision): cross-attn block every k layers; stub image tokens
    cross_attn_every: int = 0
    n_img_tokens: int = 1024
    # encdec (whisper)
    enc_layers: int = 0
    enc_len_cap: int = 4096      # stub frontend: frames per example cap
    # training
    remat: str = "full"          # none | dots | full
    scan_layers: bool = True

    # ---- derived ----------------------------------------------------------
    def attn_dims(self, *, causal: bool = True, use_rope: bool = True
                  ) -> AttnDims:
        return AttnDims(d_model=self.d_model, n_heads=self.n_heads,
                        n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
                        rope_theta=self.rope_theta,
                        rope_fraction=self.rope_fraction,
                        use_rope=use_rope and self.pos_type == "rope",
                        qkv_bias=self.qkv_bias, causal=causal)

    def n_params(self) -> int:
        """Analytic parameter count (drives 6·N·D in the roofline)."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.pos_type == "learned":
            total += self.max_position * d

        def dense_ffn(ff):
            return d * ff * (3 if self.mlp_gated else 2)

        def attn_params():
            if self.attn_kind == "mla":
                m = self.mla
                return (d * m.q_lora_rank + m.q_lora_rank * self.n_heads * m.qk_dim
                        + d * (m.kv_lora_rank + m.qk_rope_dim)
                        + m.kv_lora_rank * self.n_heads *
                        (m.qk_nope_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            hq = self.n_heads * self.head_dim
            hkv = self.n_kv_heads * self.head_dim
            return d * hq + 2 * d * hkv + hq * d

        if self.family == "ssm" or self.family == "hybrid":
            s = self.ssm
            per = (d * (2 * s.d_inner + 2 * s.n_groups * s.d_state + s.n_heads)
                   + s.d_conv * s.conv_dim + s.d_inner * d + 3 * s.n_heads
                   + s.d_inner)
            total += self.n_layers * per
            if self.family == "hybrid" and self.shared_attn_every:
                total += attn_params() + dense_ffn(self.d_ff)
            return total

        per_dense = attn_params() + dense_ffn(self.d_ff)
        if self.moe is not None:
            m = self.moe
            per_moe = (attn_params() + d * m.n_experts
                       + m.n_experts * 3 * d * m.d_ff_expert
                       + (3 * d * m.d_ff_expert * m.n_shared))
            n_moe = self.n_layers - m.first_k_dense
            total += m.first_k_dense * per_dense + n_moe * per_moe
        else:
            total += self.n_layers * per_dense
        if self.family == "encdec":
            total += self.enc_layers * per_dense
            total += self.n_layers * (2 * d * self.n_kv_heads * self.head_dim
                                      + 2 * d * self.n_heads * self.head_dim)
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            n_self = self.n_layers - n_cross
            total = (v * d + n_self * per_dense
                     + n_cross * (attn_params() + dense_ffn(self.d_ff)))
        return total

    def active_params(self) -> int:
        """Activated parameters per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        d = self.d_model
        full = self.n_params()
        n_moe = self.n_layers - m.first_k_dense
        inactive = n_moe * (m.n_experts - m.top_k) * 3 * d * m.d_ff_expert
        return full - inactive

    def flop_params(self) -> int:
        """Active params that participate in matmuls (drives 6·N·D).

        Input-embedding tables are gathers, not matmuls — excluded.  A
        *tied* table still does the unembed matmul, so it counts once
        (i.e. n_params already counts it once and we keep it).  Learned
        position tables are gathers — excluded.
        """
        n = self.active_params()
        if not self.tie_embeddings:
            n -= self.vocab * self.d_model      # gather-only input table
        if self.pos_type == "learned":
            n -= self.max_position * self.d_model
        return n

    def smoke(self) -> "ModelConfig":
        """Reduced config of the same family for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2),
            d_model=128,
            vocab=512,
            d_ff=256 if self.d_ff else 0,
            max_position=4096,
            enc_layers=min(self.enc_layers, 2),
            n_img_tokens=16,
            enc_len_cap=64,
            remat="none",
        )
        if self.n_heads:
            kw.update(n_heads=4, n_kv_heads=max(1, 4 * self.n_kv_heads
                                                // max(self.n_heads, 1)),
                      head_dim=32)
        if self.mla is not None:
            kw["mla"] = MLADims(d_model=128, n_heads=4, q_lora_rank=64,
                                kv_lora_rank=32, qk_nope_dim=32,
                                qk_rope_dim=16, v_head_dim=32)
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                d_ff_expert=64, n_shared=min(self.moe.n_shared, 1),
                first_k_dense=min(self.moe.first_k_dense, 1))
        if self.ssm is not None:
            kw["ssm"] = SSMDims(d_model=128, d_state=16, head_dim=32,
                                expand=2, n_groups=1, d_conv=4, chunk=16)
        if self.shared_attn_every:
            kw["n_layers"] = 4
            kw["shared_attn_every"] = 2
        if self.cross_attn_every:
            kw["n_layers"] = 4
            kw["cross_attn_every"] = 2
        return dataclasses.replace(self, **kw)
