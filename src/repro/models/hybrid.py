"""Hybrid SSM + shared-attention model (zamba2-1.2b).

Zamba2's signature: a *single* shared transformer block (attention + MLP)
whose parameters are re-applied every ``shared_attn_every`` Mamba-2 layers.
The stack is therefore grouped: ``G`` groups of (scan over k mamba layers →
shared block), plus trailing mamba layers.  Each shared-block *application
point* gets its own KV cache during decode (weights shared, state not).

Deviation from upstream (documented DESIGN.md): zamba2 concatenates the
original embedding to the shared-block input and uses per-application LoRA
deltas; we use a plain residual stream and exact weight sharing.

Paged serving note: only the shared-block KV caches page (the engine's
block table is broadcast across the G application points); mamba state
stays dense per slot.  On the kernel path each application point's
attention therefore runs the same split-KV flash-decoding as the lm
family — ``ctx.kv_split``/``ctx.pages_per_step`` thread through
``gqa_apply`` unchanged, G times per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..nn.attention import gqa_cache_spec, gqa_paged_cache_spec
from ..nn.blocks import (dense_block_apply, dense_block_init,
                         mamba_block_apply, mamba_block_init, norm_apply,
                         norm_init, scan_apply, stack_init)
from ..nn.context import DEFAULT_CTX, QuantContext
from ..nn.embedding import embed, embedding_init, unembed
from ..nn.ssm import mamba2_state_spec
from .common import cross_entropy
from .config import ModelConfig

__all__ = ["init", "forward", "loss", "init_cache", "init_paged_cache",
           "prefill", "decode_step", "invalidate_slot", "merge_slot",
           "spec_state", "spec_restore"]


def _group_structure(cfg: ModelConfig):
    """(n_groups, group_size, n_tail) over the mamba layers."""
    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    return n_groups, k, cfg.n_layers - n_groups * k


def init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    n_groups, k, tail = _group_structure(cfg)
    params = {
        "embed": embedding_init(ks[0], cfg.vocab, cfg.d_model, dtype=dtype),
        "groups": stack_init(
            ks[1], n_groups,
            lambda kk: stack_init(kk, k,
                                  lambda k2: mamba_block_init(k2, cfg,
                                                              dtype=dtype))),
        "shared": dense_block_init(ks[2], cfg, dtype=dtype),
        "final_norm": norm_init(cfg),
    }
    if tail:
        params["tail"] = stack_init(
            ks[3], tail, lambda kk: mamba_block_init(kk, cfg, dtype=dtype))
    return params


def _mamba_body(cfg, ctx, decode):
    def body(p_l, x, state_l):
        x2, new_s = mamba_block_apply(p_l, x, cfg, ctx, state=state_l,
                                      decode=decode)
        return x2, new_s, jnp.zeros(())
    return body


def forward(params, tokens, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX,
            *, cache=None, cache_pos=None, decode: bool = False):
    """cache = {"ssm": {"groups": (G,k,...), "tail": ...},
    "attn": stacked (G, ...) KV caches for the shared-block applications}."""
    n_groups, k, tail = _group_structure(cfg)
    x = embed(params["embed"], tokens, ctx)
    remat = cfg.remat if not decode else "none"
    body = _mamba_body(cfg, ctx, decode)

    new_ssm_groups, new_attn = [], []
    for g in range(n_groups):
        p_g = jax.tree_util.tree_map(lambda t: t[g], params["groups"])
        s_g = (jax.tree_util.tree_map(lambda t: t[g], cache["ssm"]["groups"])
               if cache is not None else None)
        x, ns, _ = scan_apply(p_g, x, body, remat=remat,
                              unroll=ctx.scan_unroll, per_layer=s_g)
        new_ssm_groups.append(ns)
        c_g = (jax.tree_util.tree_map(lambda t: t[g], cache["attn"])
               if cache is not None else None)
        x, nc = dense_block_apply(params["shared"], x, cfg, ctx, cache=c_g,
                                  cache_pos=cache_pos)
        new_attn.append(nc)
    new_tail = None
    if tail:
        s_t = cache["ssm"]["tail"] if cache is not None else None
        x, new_tail, _ = scan_apply(params["tail"], x, body, remat=remat,
                                    unroll=ctx.scan_unroll, per_layer=s_t)

    x = norm_apply(cfg, params["final_norm"], x)
    from ..dist.constrain import constrain
    logits = constrain(unembed(params["embed"], x, ctx), "dp", None, "tp")
    new_cache = None
    if cache is not None:
        stack = lambda ts: jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *ts)
        new_cache = {"ssm": {"groups": stack(new_ssm_groups),
                             "tail": new_tail},
                     "attn": stack(new_attn)}
    return logits, new_cache


def loss(params, batch, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX):
    logits, _ = forward(params, batch["tokens"], cfg, ctx)
    ce, metrics = cross_entropy(logits, batch["labels"])
    metrics["loss"] = ce
    return ce, metrics


# -- serving -------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    n_groups, k, tail = _group_structure(cfg)
    one_ssm = lambda _: mamba2_state_spec(cfg.ssm, batch, jnp.float32)
    groups = jax.vmap(lambda _: jax.vmap(one_ssm)(jnp.arange(k)))(
        jnp.arange(n_groups))
    attn = jax.vmap(lambda _: gqa_cache_spec(cfg.attn_dims(), batch, max_len,
                                             dtype))(jnp.arange(n_groups))
    return {"ssm": {"groups": groups,
                    "tail": (jax.vmap(one_ssm)(jnp.arange(tail))
                             if tail else None)},
            "attn": attn}


def init_paged_cache(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int, table_width: int, dtype=jnp.bfloat16):
    """Only the KV axis pages: the shared-block attention caches become
    per-group page pools + block tables, while the O(1) recurrent SSM
    states keep their dense (…, B, …) lanes — there is nothing
    length-proportional in them to page."""
    n_groups, k, tail = _group_structure(cfg)
    one_ssm = lambda _: mamba2_state_spec(cfg.ssm, batch, jnp.float32)
    groups = jax.vmap(lambda _: jax.vmap(one_ssm)(jnp.arange(k)))(
        jnp.arange(n_groups))
    attn = jax.vmap(lambda _: gqa_paged_cache_spec(
        cfg.attn_dims(), batch, num_pages, page_size, table_width,
        dtype))(jnp.arange(n_groups))
    return {"ssm": {"groups": groups,
                    "tail": (jax.vmap(one_ssm)(jnp.arange(tail))
                             if tail else None)},
            "attn": attn}


def invalidate_slot(cache, slot):
    """Zero slot's serving state.  The batch axis is NOT uniform here:
    grouped SSM states are (G, k, B, ...) — batch at axis 2 — while tail
    states (layers, B, ...) and the shared-block KV caches
    (G, B, Hkv, S, Dh) carry it at axis 1.  Paged attention caches are
    left untouched: their pages carry no batch axis, and the retired
    slot's pages become unreachable when the engine resets its block
    table (only the recurrent lanes need zeroing)."""
    zero_ax1 = lambda c: jax.tree_util.tree_map(
        lambda t: t.at[:, slot].set(0), c)
    zero_ax2 = lambda c: jax.tree_util.tree_map(
        lambda t: t.at[:, :, slot].set(0), c)
    attn = cache["attn"]
    return {"ssm": {"groups": zero_ax2(cache["ssm"]["groups"]),
                    "tail": (zero_ax1(cache["ssm"]["tail"])
                             if cache["ssm"]["tail"] is not None else None)},
            "attn": attn if "pages" in attn else zero_ax1(attn)}


def merge_slot(new_cache, old_cache, slot):
    """``old_cache`` with only ``slot``'s lane taken from ``new_cache``;
    batch axes as in :func:`invalidate_slot`.  Paged attention caches
    keep the NEW pages wholesale: each lane's writes went through its
    own block table, so a neighbour's in-flight garbage rows sit at its
    current position and are overwritten by its next real write before
    they can be attended (the write-before-attend invariant) — only the
    recurrent lanes need the restore."""
    take_ax1 = lambda n, o: jax.tree_util.tree_map(
        lambda a, b: b.at[:, slot].set(a[:, slot]), n, o)
    take_ax2 = lambda n, o: jax.tree_util.tree_map(
        lambda a, b: b.at[:, :, slot].set(a[:, :, slot]), n, o)
    attn = (new_cache["attn"] if "pages" in new_cache["attn"]
            else take_ax1(new_cache["attn"], old_cache["attn"]))
    return {"ssm": {"groups": take_ax2(new_cache["ssm"]["groups"],
                                       old_cache["ssm"]["groups"]),
                    "tail": (take_ax1(new_cache["ssm"]["tail"],
                                      old_cache["ssm"]["tail"])
                             if old_cache["ssm"]["tail"] is not None
                             else None)},
            "attn": attn}


def spec_state(cache):
    """Only the SSM lanes need speculative checkpoints: the shared-block
    KV caches rewind by position like any attention cache.  Leaves go
    batch-first — grouped states (G, k, B, ...) → (B, G, k, ...), tail
    states (L, B, ...) → (B, L, ...) — so the per-slot checkpoint
    gather in the spec loop is axis-uniform."""
    return {"groups": jax.tree_util.tree_map(
                lambda t: jnp.moveaxis(t, 2, 0), cache["ssm"]["groups"]),
            "tail": (jax.tree_util.tree_map(
                lambda t: jnp.moveaxis(t, 1, 0), cache["ssm"]["tail"])
                if cache["ssm"]["tail"] is not None else None)}


def spec_restore(cache, state):
    return {"ssm": {"groups": jax.tree_util.tree_map(
                        lambda t: jnp.moveaxis(t, 0, 2), state["groups"]),
                    "tail": (jax.tree_util.tree_map(
                        lambda t: jnp.moveaxis(t, 0, 1), state["tail"])
                        if state["tail"] is not None else None)},
            "attn": cache["attn"]}


def prefill(params, tokens, cache, cfg: ModelConfig,
            ctx: QuantContext = DEFAULT_CTX, *, pos=None,
            full_logits: bool = False):
    """NOTE: ``pos`` offsets only the attention caches; the SSM states
    are rebuilt from this call's tokens, so hybrid prefill must ingest
    the whole prompt in one call (no cross-call chunking)."""
    b = tokens.shape[0]
    start = jnp.zeros((b,), jnp.int32) if pos is None else pos
    logits, new_cache = forward(params, tokens, cfg, ctx, cache=cache,
                                cache_pos=start, decode=False)
    return (logits if full_logits else logits[:, -1:]), new_cache


def decode_step(params, tokens, cache, pos, cfg: ModelConfig,
                ctx: QuantContext = DEFAULT_CTX):
    logits, new_cache = forward(params, tokens, cfg, ctx, cache=cache,
                                cache_pos=pos, decode=True)
    return logits, new_cache
