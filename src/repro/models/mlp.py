"""The paper's canonical workload: hls4ml's 3-hidden-layer jet-tagging MLP
(16 → 64 → 32 → 32 → 5, ReLU + softmax).

This is the model the quantization-accuracy and LUT-softmax benchmarks run
on; it trains in seconds on CPU and exercises the full paper pipeline:
train fp32 → PTQ to ``ac_fixed``/minifloat → measure accuracy delta →
deploy with table-based softmax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..nn.activations import act_fn, softmax
from ..nn.context import DEFAULT_CTX, QuantContext
from ..nn.linear import linear, linear_init

__all__ = ["init", "forward", "loss", "predict"]


def init(rng, *, n_features: int = 16, hidden=(64, 32, 32),
         n_classes: int = 5, dtype=jnp.float32):
    dims = (n_features,) + tuple(hidden) + (n_classes,)
    ks = jax.random.split(rng, len(dims) - 1)
    return {f"fc{i}": linear_init(ks[i], dims[i], dims[i + 1], bias=True,
                                  dtype=dtype)
            for i in range(len(dims) - 1)}


def forward(params, x: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX):
    """x: (B, n_features) → logits (B, n_classes)."""
    n = len(params)
    for i in range(n):
        x = linear(params[f"fc{i}"], x, ctx, path=f"fc{i}")
        if i < n - 1:
            x = act_fn("relu", x, ctx, path=f"fc{i}/act")
    return x


def predict(params, x: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX):
    """Class probabilities — softmax goes through the paper's tables when
    ``ctx.use_lut`` (including the 1024×18-bit override)."""
    return softmax(forward(params, x, ctx), ctx, axis=-1)


def loss(params, batch, ctx: QuantContext = DEFAULT_CTX):
    logits = forward(params, batch["x"], ctx).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    l = jnp.mean(lse - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return l, {"loss": l, "accuracy": acc}
