"""Shared model utilities: losses, position tables."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["cross_entropy", "sinusoidal_table"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  *, z_loss: float = 1e-4):
    """Token-level CE with optional z-loss.  labels < 0 are masked.

    logits: (B, S, V) — V may be sharded over the model axis: the label
    log-prob is extracted with a one-hot contraction (shards cleanly as a
    masked reduce + psum) instead of ``take_along_axis``, whose gather
    forces GSPMD to all-gather the full vocab axis.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), lf.shape[-1],
                            dtype=jnp.float32)
    from ..dist.constrain import constrain
    onehot = constrain(onehot, "dp", None, "tp")
    ll = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    if z_loss:
        loss = loss + z_loss * jnp.sum(jnp.square(lse) * mask) / denom
    acc = jnp.sum((jnp.argmax(lf, -1) == labels) * mask) / denom
    return loss, {"nll": jnp.sum(nll * mask) / denom, "accuracy": acc,
                  "tokens": jnp.sum(mask)}


@functools.lru_cache(maxsize=16)
def sinusoidal_table(length: int, d: int) -> np.ndarray:
    """Trace-time constant sinusoidal position table (whisper encoder)."""
    pos = np.arange(length, dtype=np.float64)[:, None]
    dim = np.arange(0, d, 2, dtype=np.float64)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / d)
    tbl = np.zeros((length, d), np.float32)
    tbl[:, 0::2] = np.sin(pos * inv)
    tbl[:, 1::2] = np.cos(pos * inv)
    return tbl
