"""Encoder–decoder backbone (whisper-base).

Per the brief the audio frontend is a STUB: ``batch["enc_input"]`` carries
precomputed frame embeddings (B, S_enc, D) — the conv1d feature extractor
is outside scope.  The encoder adds a sinusoidal position table (trace-time
constant) and runs non-causal self-attention; the decoder uses learned
positions, causal self-attention and per-layer cross-attention.

Serving: prefill computes cross K/V once per layer (cached); decode scans
self-cache + cross-cache alongside the stacked decoder params.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..nn.attention import (gqa_apply, gqa_cache_spec, gqa_init,
                            gqa_project_kv)
from ..nn.blocks import (dense_block_apply, dense_block_init, mlp_apply,
                         mlp_init, norm_apply, norm_init, scan_apply,
                         stack_init)
from ..nn.context import DEFAULT_CTX, QuantContext
from ..nn.embedding import embed, embedding_init, unembed
from .common import cross_entropy, sinusoidal_table
from .config import ModelConfig

__all__ = ["init", "forward", "loss", "init_cache", "prefill", "decode_step"]


def _dec_block_init(rng, cfg: ModelConfig, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "ln1": norm_init(cfg), "ln_x": norm_init(cfg), "ln2": norm_init(cfg),
        "self": gqa_init(ks[0], cfg.attn_dims(causal=True), dtype=dtype),
        "cross": gqa_init(ks[1], cfg.attn_dims(causal=False), dtype=dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated,
                        dtype=dtype),
    }


def _dec_block_apply(p, x, enc, cfg: ModelConfig, ctx, *, cache=None,
                     cache_pos=None, cross_kv=None):
    a, new_c = gqa_apply(p["self"], norm_apply(cfg, p["ln1"], x),
                         cfg.attn_dims(causal=True), ctx, cache=cache,
                         cache_pos=cache_pos, path="dec/self")
    x = x + a
    c, _ = gqa_apply(p["cross"], norm_apply(cfg, p["ln_x"], x),
                     cfg.attn_dims(causal=False), ctx,
                     kv_input=enc if cross_kv is None else None,
                     cached_kv=cross_kv, path="dec/cross")
    x = x + c
    m = mlp_apply(p["mlp"], norm_apply(cfg, p["ln2"], x), cfg.mlp_act, ctx,
                  path="dec/mlp")
    return x + m, new_c


def init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    return {
        "embed": embedding_init(ks[0], cfg.vocab, cfg.d_model, dtype=dtype),
        "pos": (jax.random.normal(ks[1], (cfg.max_position, cfg.d_model),
                                  jnp.float32) * 0.01).astype(dtype),
        "encoder": stack_init(ks[2], cfg.enc_layers,
                              lambda k: dense_block_init(k, cfg, causal=False,
                                                         dtype=dtype)),
        "enc_norm": norm_init(cfg),
        "decoder": stack_init(ks[3], cfg.n_layers,
                              lambda k: _dec_block_init(k, cfg, dtype)),
        "dec_norm": norm_init(cfg),
    }


def encode(params, enc_input: jnp.ndarray, cfg: ModelConfig,
           ctx: QuantContext = DEFAULT_CTX):
    s = enc_input.shape[1]
    pos = jnp.asarray(sinusoidal_table(s, cfg.d_model))
    x = enc_input.astype(ctx.compute_dtype) + pos.astype(ctx.compute_dtype)

    def body(p_l, x, _):
        x2, _ = dense_block_apply(p_l, x, cfg, ctx, causal=False)
        return x2, jnp.zeros(()), jnp.zeros(())

    x, _, _ = scan_apply(params["encoder"], x, body, remat=cfg.remat,
                         unroll=ctx.scan_unroll)
    return norm_apply(cfg, params["enc_norm"], x)


def _decode(params, tokens, enc, cfg, ctx, *, cache=None, cache_pos=None,
            cross_kv=None):
    b, s = tokens.shape
    start = cache_pos if cache_pos is not None else jnp.zeros((b,), jnp.int32)
    pos_ids = start[:, None] + jnp.arange(s)[None, :]
    x = embed(params["embed"], tokens, ctx)
    x = x + jnp.take(params["pos"].astype(x.dtype),
                     jnp.minimum(pos_ids, cfg.max_position - 1), axis=0)

    def body(p_l, x, extras):
        cache_l, ckv_l = extras
        x2, new_c = _dec_block_apply(p_l, x, enc, cfg, ctx, cache=cache_l,
                                     cache_pos=cache_pos, cross_kv=ckv_l)
        return x2, new_c, jnp.zeros(())

    per_layer = (cache, cross_kv)
    x, new_cache, _ = scan_apply(params["decoder"], x, body,
                                 remat=cfg.remat if cache is None else "none",
                                 unroll=ctx.scan_unroll, per_layer=per_layer)
    x = norm_apply(cfg, params["dec_norm"], x)
    from ..dist.constrain import constrain
    logits = constrain(unembed(params["embed"], x, ctx), "dp", None, "tp")
    return logits, new_cache


def forward(params, batch, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX):
    enc = encode(params, batch["enc_input"], cfg, ctx)
    logits, _ = _decode(params, batch["tokens"], enc, cfg, ctx)
    return logits


def loss(params, batch, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX):
    logits = forward(params, batch, cfg, ctx)
    ce, metrics = cross_entropy(logits, batch["labels"])
    metrics["loss"] = ce
    return ce, metrics


# -- serving -------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    dims = cfg.attn_dims()
    enc_len = min(cfg.enc_len_cap, max_len)

    def one(_):
        return {"self": gqa_cache_spec(dims, batch, max_len, dtype),
                "cross_kv": (jnp.zeros((batch, dims.n_kv_heads, enc_len,
                                        dims.head_dim), dtype),) * 2}

    c = jax.vmap(one)(jnp.arange(cfg.n_layers))
    return {"layers": {"self": c["self"]},
            "cross_kv": c["cross_kv"]}


def prefill(params, batch, cache, cfg: ModelConfig,
            ctx: QuantContext = DEFAULT_CTX, *, pos=None,
            full_logits: bool = False):
    enc = encode(params, batch["enc_input"], cfg, ctx)
    dims = cfg.attn_dims(causal=False)

    def proj(p_l):
        return gqa_project_kv(p_l["cross"], enc, dims, ctx)

    kv = jax.vmap(proj)(params["decoder"])              # (L, B, Hkv, Se, Dh)
    kv = tuple(t.astype(cache["cross_kv"][0].dtype) for t in kv)
    b = batch["tokens"].shape[0]
    start = jnp.zeros((b,), jnp.int32) if pos is None else pos
    logits, new_self = _decode(params, batch["tokens"], None, cfg, ctx,
                               cache=cache["layers"]["self"],
                               cache_pos=start,
                               cross_kv=kv)
    out = logits if full_logits else logits[:, -1:]
    return out, {"layers": {"self": new_self}, "cross_kv": kv}


def decode_step(params, tokens, cache, pos, cfg: ModelConfig,
                ctx: QuantContext = DEFAULT_CTX):
    logits, new_self = _decode(params, tokens, None, cfg, ctx,
                               cache=cache["layers"]["self"], cache_pos=pos,
                               cross_kv=cache["cross_kv"])
    return logits, {"layers": {"self": new_self},
                    "cross_kv": cache["cross_kv"]}
