"""Training runtime: step builders, microbatching, state management."""

from .step import (build_prefill_step, build_serve_step, build_train_step,
                   init_state)

__all__ = ["build_prefill_step", "build_serve_step", "build_train_step",
           "init_state"]
