"""Train/serve step builders: microbatched gradient accumulation, remat,
optimizer fusion, optional compressed cross-pod gradient reduction.

``build_train_step`` returns a pure function ``(state, batch) -> (state,
metrics)`` ready for ``jax.jit`` with the sharding pytrees from
``repro.dist.sharding``.  Gradient accumulation is a ``lax.scan`` over
microbatch slices — the standard memory lever for the big train shapes
(live activations scale with B/microbatches, while the scan keeps HLO size
constant); XLA overlaps each microbatch's backward collectives with the
next microbatch's compute (latency hiding — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..core.qtypes import FixedPointType
from ..models.api import loss_fn
from ..models.config import ModelConfig
from ..nn.context import QuantContext
from ..optim import OptConfig, adamw_init, adamw_update

__all__ = ["init_state", "build_train_step", "build_serve_step",
           "build_prefill_step", "build_decode_loop",
           "build_spec_decode_loop", "LOOP_BUILDS"]

#: fused-loop build telemetry: every call of a loop *builder* is one
#: trace-and-compile when the result is jitted, so re-jit bugs (e.g. an
#: adaptive knob thrashing the spec loop cache) show up here long before
#: they show up in walltime.  Tests assert the count stays bounded by
#: the number of distinct (block, k) keys; reset by assigning zeros.
LOOP_BUILDS = {"decode": 0, "spec": 0}


def init_state(rng, cfg: ModelConfig, *, dtype=jnp.float32,
               opt_cfg: OptConfig = OptConfig()):
    from ..models.api import get_family
    params = get_family(cfg).init(rng, cfg, dtype=dtype)
    return {"params": params, "opt": adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def _tree_scale(a, s):
    return jax.tree_util.tree_map(lambda t: t * s, a)


def build_train_step(cfg: ModelConfig, ctx: QuantContext, *,
                     lr_fn: Callable, opt_cfg: OptConfig = OptConfig(),
                     microbatches: int = 1,
                     grad_specs=None) -> Callable:
    """(state, batch) -> (state, metrics).

    ``batch`` leaves have leading dim B; with ``microbatches`` > 1 they are
    reshaped to (M, B/M, …) and scanned, accumulating f32 gradients.

    ``grad_specs``: optional PartitionSpec pytree matching the params.
    Under the ``grad_specs`` perf flag (§Perf H1), per-microbatch gradients
    and the accumulator are constrained to the parameter sharding, so the
    cross-data reduction lowers as a reduce-scatter into sharded
    accumulators instead of a full-gradient all-reduce every microbatch.
    """
    grad_of = jax.value_and_grad(lambda p, mb: loss_fn(p, mb, cfg, ctx),
                                 has_aux=True)

    def _pin(grads):
        from ..dist.constrain import current_mesh
        from ..dist.options import flags
        mesh = current_mesh()
        if grad_specs is None or mesh is None or not flags().grad_specs:
            return grads
        from jax.sharding import NamedSharding
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, s)), grads, grad_specs)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_of(params, batch)
            return _pin(grads), metrics

        def split(t):
            return t.reshape(microbatches, t.shape[0] // microbatches,
                             *t.shape[1:])

        mbatch = jax.tree_util.tree_map(split, batch)
        g0 = _pin(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        m0 = {"loss": jnp.zeros((), jnp.float32),
              "accuracy": jnp.zeros((), jnp.float32)}

        def body(carry, mb):
            gacc, macc = carry
            (loss, metrics), grads = grad_of(params, mb)
            gacc = _pin(_tree_add(gacc, _pin(jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads))))
            macc = {"loss": macc["loss"] + metrics["loss"],
                    "accuracy": macc["accuracy"] + metrics["accuracy"]}
            return (gacc, macc), None

        (gsum, msum), _ = jax.lax.scan(body, (g0, m0), mbatch)
        inv = 1.0 / microbatches
        return _tree_scale(gsum, inv), _tree_scale(msum, inv)

    def train_step(state, batch):
        grads, metrics = compute_grads(state["params"], batch)
        lr = lr_fn(state["step"])
        new_params, new_opt, om = adamw_update(grads, state["opt"],
                                               state["params"], lr, opt_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["lr"] = lr
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def build_serve_step(cfg: ModelConfig, ctx: QuantContext) -> Callable:
    """(params, cache, tokens (B,1), pos (B,)) -> (logits, new_cache)."""
    from ..models.api import decode_fn

    def serve_step(params, cache, tokens, pos):
        return decode_fn(params, tokens, cache, pos, cfg, ctx)

    return serve_step


def build_decode_loop(cfg: ModelConfig, ctx: QuantContext,
                      steps: int) -> Callable:
    """Device-resident decode: ``steps`` serve steps in ONE ``lax.scan``.

    The per-token serving loop pays a host↔device round trip per
    generated token (jit dispatch + blocking argmax readback + Python
    slot bookkeeping).  This builder fuses N steps into a single jitted
    call: the model step, the sampling draw, the per-slot position
    advance, and the EOS/length stopping decision all stay on device;
    the host syncs once per N-token block.

    Returned callable::

        decode_loop(params, cache, tokens, pos, live, stop_pos,
                    sample_params, key, step0, eos_id)
            -> (cache, tokens, pos, live, block_tokens, block_live, fault)

    * ``tokens`` (B, 1) i32 — each slot's next input token.
    * ``pos`` (B,) i32 — current cache position per slot.  With a
      *paged* cache the carry additionally threads the block-table
      leaves unchanged: page *assignment* is a host decision made at
      admission (the engine allocates a request's whole token budget up
      front), so the device loop never calls back into the allocator —
      each step's KV write resolves ``pos`` through the table it was
      launched with, and dead lanes resolve to the trash page.  The
      split-KV knob (``ctx.kv_split``/``ctx.pages_per_step``) is
      *static* loop configuration the same way: the builder closes
      over ``ctx``, so every scanned step runs the kernel at the
      engine-resolved split — no per-step re-dispatch, one compiled
      loop per (block size, split) point.
    * ``live`` (B,) bool — slots that are generating; dead slots are
      frozen (token/pos held, emissions masked) exactly as the per-token
      engine freezes them, so a block is bit-equivalent to N single
      steps.
    * ``stop_pos`` (B,) i32 — a slot's ``live`` drops once its position
      reaches this bound (prompt_len + gen budget).
    * ``sample_params`` — {"temperature": (B,) f32, "top_k": (B,) i32};
      temperature <= 0 is greedy (see repro.kernels.sampling).
    * ``key``/``step0`` — PRNG base and global step offset; step ``i``
      draws with ``fold_in(key, step0 + i)``, so any split of a
      generation into blocks consumes identical randomness
      (``step_many(2); step_many(3)`` == ``step_many(5)``).  ``key``
      may be None when every slot is greedy: sampling then skips the
      top-k sorts and noise generation entirely (greedy consumes no
      PRNG state, so switching between the two compiled variants never
      shifts the stream).
    * ``eos_id`` i32 scalar — sampling it kills the slot (-1 disables).

    ``block_tokens``/``block_live`` (steps, B): the token each slot
    *emitted* at each step (its input token, matching ``Engine.step``'s
    append-then-advance order) and whether the slot was live then.

    ``fault`` (B,) bool is the abort/status lane: a live slot whose
    logits come back non-finite (poisoned cache, kernel NaN) is frozen
    *on device* — its faulted step commits nothing (position/token held,
    emission masked) and the flag rides back with the block, so the host
    engine learns of device-side corruption from the block result itself
    and can restore-and-replay or fail the slot with its valid prefix.
    For finite logits the lane is identically False and the emitted
    stream is unchanged.
    """
    from ..kernels.ops import sample_tokens
    from ..models.api import decode_fn

    LOOP_BUILDS["decode"] += 1

    def decode_loop(params, cache, tokens, pos, live, stop_pos,
                    sample_params, key, step0, eos_id):
        temperature = sample_params["temperature"]
        top_k = sample_params["top_k"]

        def body(carry, i):
            cache, tok, pos, live, fault = carry
            logits, new_cache = decode_fn(params, tok, cache, pos, cfg, ctx)
            last = logits[:, -1].astype(jnp.float32)
            bad = live & ~jnp.all(jnp.isfinite(last), axis=-1)
            ok = live & ~bad
            step_key = (None if key is None
                        else jax.random.fold_in(key, step0 + i))
            nxt = sample_tokens(last, temperature, top_k, step_key,
                                backend=ctx.backend)
            emitted, emit_live = tok[:, 0], ok
            new_pos = jnp.where(ok, pos + 1, pos)
            new_tok = jnp.where(ok, nxt, tok[:, 0])[:, None]
            new_live = ok & (nxt != eos_id) & (new_pos < stop_pos)
            return (new_cache, new_tok, new_pos, new_live, fault | bad), \
                (emitted, emit_live)

        fault0 = jnp.zeros_like(live)
        (cache, tokens, pos, live, fault), (block_tokens, block_live) = \
            jax.lax.scan(body, (cache, tokens, pos, live, fault0),
                         jnp.arange(steps, dtype=jnp.int32))
        return cache, tokens, pos, live, block_tokens, block_live, fault

    return decode_loop


def build_spec_decode_loop(cfg: ModelConfig, ctx: QuantContext, steps: int,
                           k: int, *, drafter="ngram", ngram: int = 2,
                           draft_cfg: Optional[ModelConfig] = None,
                           draft_ctx: Optional[QuantContext] = None
                           ) -> Callable:
    """Speculative decode: ``steps`` draft→verify rounds in ONE scan.

    Each round proposes ``k`` tokens per slot, runs the target model
    ONCE over all k + 1 block positions (the de-specialization payoff:
    verification *is* a k+1-token chunked-prefill call — the dense
    einsum path or ``paged_attention`` handle S > 1 natively, so no
    bespoke verify forward exists; on the kernel path that call runs at
    the same ``ctx.kv_split``/``ctx.pages_per_step`` split-KV point as
    plain decode, closed over from the builder's ``ctx``), accepts the
    longest agreeing prefix via the
    :func:`repro.kernels.ops.verify_tokens` op, and advances each slot
    by its accepted length.  Greedy slots emit the target's
    exact argmax stream (byte-identical to the non-speculative engine);
    sampled slots preserve the temperature/top-k distribution through
    point-mass rejection sampling.

    Rollback is family-aware (:func:`repro.models.api.spec_state_fn`):

    * KV families (lm, dense or paged) rewind by the scalar ``pos``
      edit alone — rejected rows are overwritten by the next block's
      writes before any query can attend them (write-before-attend),
      and pages were allocated for the full token budget at admission,
      so the allocator and block tables are untouched.
    * Recurrent families (ssm, hybrid's mamba lanes) cannot un-consume
      a token: their verification runs as a k+1-step inner scan that
      checkpoints the recurrent leaves per position, and the committed
      checkpoint is gathered per slot after verification.

    ``drafter`` selects the proposal source:

    * ``"ngram"`` — prompt-lookup self-speculation (default; no second
      model).  The loop threads a ``hist`` (B, H) committed-token
      buffer and drafts by copying the continuation of the most recent
      match of the trailing ``ngram`` tokens.
    * ``(draft_cfg, draft_ctx)`` via the keyword args with
      ``drafter="model"`` — a second (smaller) model drafts greedily;
      any ``configs/*`` model sharing the target's vocab works.  Its
      cache is threaded through the carry and rolled back with the
      same family-aware machinery (it runs k + 1 draft steps so a
      fully-accepted round leaves it exactly one token behind the new
      input, like the target).
    * a callable ``(hist, tok, pos) -> (B, k) drafts`` — test hook for
      adversarial/custom proposal sources.

    Signature (ngram/callable)::

        spec_loop(params, cache, tokens, pos, live, stop_pos,
                  sample_params, key, step0, eos_id, hist)
            -> (cache, tokens, pos, live, hist,
                block_tokens, block_live, accepted, fault)

    ``fault`` (B,) is the same abort/status lane as the plain decode
    loop: a round whose verify logits are non-finite commits nothing
    for the affected slot (position/token held, emissions and history
    writes masked, accepted = 0) and flags it for the host.

    model drafter replaces the trailing ``hist`` with
    ``(draft_params, draft_cache)`` and returns the advanced (rolled
    back) ``draft_cache`` in ``hist``'s slot.

    ``block_tokens``/``block_live`` are (steps * (k+1), B) in
    chronological order — each round contributes its k + 1 block slots,
    masked down to the committed prefix of live lanes.  ``accepted``
    (steps, B) counts the drafts that survived each round (0..k, 0 for
    dead lanes); committed tokens per live round = accepted + 1, so a
    fully-rejecting drafter still advances every slot — speculation
    degrades to plain decode, never below it.

    PRNG: round ``i`` folds ``step0 + i`` exactly like the plain decode
    loop, so block splits consume identical randomness
    (``step_many(2); step_many(3)`` == ``step_many(5)``); greedy-only
    batches pass ``key=None`` and consume none.  The sampled *stream*
    intentionally differs from the non-speculative engine's (different
    randomness consumption per emitted token) — only its distribution
    is preserved.
    """
    from ..kernels.ops import verify_tokens
    from ..kernels.speculative import draft_ngram
    from ..models.api import (decode_fn, get_family, spec_restore_fn,
                              spec_state_fn)

    LOOP_BUILDS["spec"] += 1
    s_blk = k + 1
    has_rec = hasattr(get_family(cfg), "spec_state")
    model_draft = drafter == "model"
    if model_draft:
        assert draft_cfg is not None, "model drafter needs draft_cfg"
        draft_ctx = draft_ctx or ctx
        draft_has_rec = hasattr(get_family(draft_cfg), "spec_state")

    def spec_forward(params, seq, cache, pos):
        """Target logits over the block + cache with rollback handles.

        Returns (logits (B, S, V), new_cache, ckpts): ``ckpts`` is None
        for pure-KV families (chunked call, pos rewind) or the stacked
        (S, B, ...) recurrent checkpoints (per-token inner scan).
        """
        if not has_rec:
            logits, new_cache = decode_fn(params, seq, cache, pos, cfg, ctx)
            return logits, new_cache, None

        def body(c, j):
            tok_j = jax.lax.dynamic_slice_in_dim(seq, j, 1, axis=1)
            lg, nc = decode_fn(params, tok_j, c, pos + j, cfg, ctx)
            return nc, (lg[:, 0], spec_state_fn(nc, cfg))

        new_cache, (lgs, ckpts) = jax.lax.scan(body, cache,
                                               jnp.arange(s_blk))
        return jnp.moveaxis(lgs, 0, 1), new_cache, ckpts

    def draft_with_model(draft_params, dcache, tok, pos):
        """k+1 greedy draft steps (the extra step keeps the drafter's
        consumed-token count able to cover a fully-accepted round)."""
        def body(carry, j):
            dc, t = carry
            lg, dc = decode_fn(draft_params, t, dc, pos + j,
                               draft_cfg, draft_ctx)
            nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
            ck = spec_state_fn(dc, draft_cfg) if draft_has_rec else None
            return (dc, nxt), (nxt[:, 0], ck)

        (dc_fin, _), (toks, dckpts) = jax.lax.scan(
            body, (dcache, tok), jnp.arange(s_blk))
        return jnp.moveaxis(toks, 0, 1)[:, :k], dc_fin, dckpts

    def spec_loop(params, cache, tokens, pos, live, stop_pos,
                  sample_params, key, step0, eos_id, *aux):
        temperature = sample_params["temperature"]
        top_k = sample_params["top_k"]
        if model_draft:
            draft_params, draft_cache = aux
            carry_aux = draft_cache
        else:
            (carry_aux,) = aux                      # hist (B, H)
        b = tokens.shape[0]
        lane = jnp.arange(b)
        jdraft = jnp.arange(k)

        def body(carry, i):
            cache, tok, pos, live, aux, fault = carry
            # -- draft ------------------------------------------------
            if model_draft:
                drafts, aux, dckpts = draft_with_model(draft_params, aux,
                                                       tok, pos)
            elif callable(drafter):
                aux = aux.at[lane, pos].set(tok[:, 0])
                drafts = drafter(aux, tok, pos).astype(jnp.int32)
            else:
                drafts, aux = draft_ngram(aux, tok, pos, k, ngram)
            # -- verify: ONE target pass over the whole block ---------
            seq = jnp.concatenate([tok, drafts], axis=1)     # (B, k+1)
            logits, new_cache, ckpts = spec_forward(params, seq, cache,
                                                    pos)
            bad = live & ~jnp.all(jnp.isfinite(logits.astype(jnp.float32)),
                                  axis=(1, 2))
            ok = live & ~bad
            step_key = (None if key is None
                        else jax.random.fold_in(key, step0 + i))
            next_tok, n_adv = verify_tokens(
                logits.astype(jnp.float32), drafts, temperature, top_k,
                step_key, backend=ctx.backend)
            # -- truncate: a committed EOS draft or the slot's token
            # budget ends the round early; the held token then matches
            # what sequential decode would hold (the first uncommitted
            # chain token, which IS the corresponding draft)
            any_eos = jnp.any(drafts == eos_id, axis=1)
            first_eos = jnp.argmax(drafts == eos_id, axis=1)     # (B,)
            limit = jnp.where(any_eos, first_eos + 1, s_blk + 1)
            # n_fin >= 1 (the clip floor) makes `pos` monotonically
            # NONDECREASING across rounds: "rewind" only discards the
            # speculative tail [pos + n_fin, pos + k + 1), never a row
            # below the committed watermark.  Prefix caching leans on
            # exactly this — a page the engine published to the prefix
            # index because `(depth+1)*page_size <= pos` held can never
            # be un-committed by a later rejection, so shared pages stay
            # immutable for every slot that maps them.
            n_fin = jnp.clip(jnp.minimum(jnp.minimum(n_adv, limit),
                                         stop_pos - pos), 1, s_blk)
            next_tok = jnp.where(n_fin < n_adv,
                                 drafts[lane, n_fin - 1], next_tok)
            # -- family-aware rollback of recurrent state -------------
            if ckpts is not None:
                sel = jax.tree_util.tree_map(lambda t: t[n_fin - 1, lane],
                                             ckpts)
                new_cache = spec_restore_fn(new_cache, sel, cfg)
            if model_draft and draft_has_rec:
                dsel = jax.tree_util.tree_map(lambda t: t[n_fin - 1, lane],
                                              dckpts)
                aux = spec_restore_fn(aux, dsel, draft_cfg)
            # -- commit: accepted drafts join the history buffer ------
            if not model_draft:
                widx = jnp.clip(pos[:, None] + 1 + jdraft[None, :],
                                0, aux.shape[1] - 1)
                held = jnp.take_along_axis(aux, widx, axis=1)
                wmask = ok[:, None] & (jdraft[None, :]
                                       < n_fin[:, None] - 1)
                aux = aux.at[lane[:, None], widx].set(
                    jnp.where(wmask, drafts, held))
            committed = jnp.arange(s_blk)[None, :] < n_fin[:, None]
            emit_live = ok[:, None] & committed              # (B, k+1)
            new_pos = jnp.where(ok, pos + n_fin, pos)
            new_tok = jnp.where(ok, next_tok, tok[:, 0])[:, None]
            new_live = ok & (next_tok != eos_id) & (new_pos < stop_pos)
            accepted = jnp.where(ok, n_fin - 1, 0)
            return (new_cache, new_tok, new_pos, new_live, aux,
                    fault | bad), (seq, emit_live, accepted)

        fault0 = jnp.zeros_like(live)
        (cache, tokens, pos, live, carry_aux, fault), \
            (toks, emits, accepted) = \
            jax.lax.scan(body, (cache, tokens, pos, live, carry_aux,
                                fault0),
                         jnp.arange(steps, dtype=jnp.int32))
        # (steps, B, k+1) -> chronological (steps*(k+1), B)
        block_tokens = toks.transpose(0, 2, 1).reshape(steps * s_blk, -1)
        block_live = emits.transpose(0, 2, 1).reshape(steps * s_blk, -1)
        return (cache, tokens, pos, live, carry_aux,
                block_tokens, block_live, accepted, fault)

    return spec_loop


def build_prefill_step(cfg: ModelConfig, ctx: QuantContext) -> Callable:
    """(params, batch, cache, pos) -> (chunk_logits, cache).

    The chunked-prefill step used by the serving engine: ``pos`` gives
    each slot's current cache position and the returned logits cover
    every chunk position (so ragged prompt ends can be read per slot).
    Pass ``pos=None`` for a whole-prompt prefill from position 0.

    Cache-layout agnostic: with a paged cache the chunk's K/V scatter
    through each slot's block table instead of a dense row range, and
    writes past a slot's allocation (the dense layout's margin rows)
    land on the shared trash page.  Same step function, same jit — the
    layout is carried entirely by the cache pytree.
    """
    from ..models.api import prefill_fn

    def prefill_step(params, batch, cache, pos=None):
        return prefill_fn(params, batch, cache, cfg, ctx, pos=pos,
                          full_logits=True)

    return prefill_step
