"""Train/serve step builders: microbatched gradient accumulation, remat,
optimizer fusion, optional compressed cross-pod gradient reduction.

``build_train_step`` returns a pure function ``(state, batch) -> (state,
metrics)`` ready for ``jax.jit`` with the sharding pytrees from
``repro.dist.sharding``.  Gradient accumulation is a ``lax.scan`` over
microbatch slices — the standard memory lever for the big train shapes
(live activations scale with B/microbatches, while the scan keeps HLO size
constant); XLA overlaps each microbatch's backward collectives with the
next microbatch's compute (latency hiding — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..core.qtypes import FixedPointType
from ..models.api import loss_fn
from ..models.config import ModelConfig
from ..nn.context import QuantContext
from ..optim import OptConfig, adamw_init, adamw_update

__all__ = ["init_state", "build_train_step", "build_serve_step",
           "build_prefill_step", "build_decode_loop"]


def init_state(rng, cfg: ModelConfig, *, dtype=jnp.float32,
               opt_cfg: OptConfig = OptConfig()):
    from ..models.api import get_family
    params = get_family(cfg).init(rng, cfg, dtype=dtype)
    return {"params": params, "opt": adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def _tree_scale(a, s):
    return jax.tree_util.tree_map(lambda t: t * s, a)


def build_train_step(cfg: ModelConfig, ctx: QuantContext, *,
                     lr_fn: Callable, opt_cfg: OptConfig = OptConfig(),
                     microbatches: int = 1,
                     grad_specs=None) -> Callable:
    """(state, batch) -> (state, metrics).

    ``batch`` leaves have leading dim B; with ``microbatches`` > 1 they are
    reshaped to (M, B/M, …) and scanned, accumulating f32 gradients.

    ``grad_specs``: optional PartitionSpec pytree matching the params.
    Under the ``grad_specs`` perf flag (§Perf H1), per-microbatch gradients
    and the accumulator are constrained to the parameter sharding, so the
    cross-data reduction lowers as a reduce-scatter into sharded
    accumulators instead of a full-gradient all-reduce every microbatch.
    """
    grad_of = jax.value_and_grad(lambda p, mb: loss_fn(p, mb, cfg, ctx),
                                 has_aux=True)

    def _pin(grads):
        from ..dist.constrain import current_mesh
        from ..dist.options import flags
        mesh = current_mesh()
        if grad_specs is None or mesh is None or not flags().grad_specs:
            return grads
        from jax.sharding import NamedSharding
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, s)), grads, grad_specs)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_of(params, batch)
            return _pin(grads), metrics

        def split(t):
            return t.reshape(microbatches, t.shape[0] // microbatches,
                             *t.shape[1:])

        mbatch = jax.tree_util.tree_map(split, batch)
        g0 = _pin(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))
        m0 = {"loss": jnp.zeros((), jnp.float32),
              "accuracy": jnp.zeros((), jnp.float32)}

        def body(carry, mb):
            gacc, macc = carry
            (loss, metrics), grads = grad_of(params, mb)
            gacc = _pin(_tree_add(gacc, _pin(jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads))))
            macc = {"loss": macc["loss"] + metrics["loss"],
                    "accuracy": macc["accuracy"] + metrics["accuracy"]}
            return (gacc, macc), None

        (gsum, msum), _ = jax.lax.scan(body, (g0, m0), mbatch)
        inv = 1.0 / microbatches
        return _tree_scale(gsum, inv), _tree_scale(msum, inv)

    def train_step(state, batch):
        grads, metrics = compute_grads(state["params"], batch)
        lr = lr_fn(state["step"])
        new_params, new_opt, om = adamw_update(grads, state["opt"],
                                               state["params"], lr, opt_cfg)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["lr"] = lr
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def build_serve_step(cfg: ModelConfig, ctx: QuantContext) -> Callable:
    """(params, cache, tokens (B,1), pos (B,)) -> (logits, new_cache)."""
    from ..models.api import decode_fn

    def serve_step(params, cache, tokens, pos):
        return decode_fn(params, tokens, cache, pos, cfg, ctx)

    return serve_step


def build_decode_loop(cfg: ModelConfig, ctx: QuantContext,
                      steps: int) -> Callable:
    """Device-resident decode: ``steps`` serve steps in ONE ``lax.scan``.

    The per-token serving loop pays a host↔device round trip per
    generated token (jit dispatch + blocking argmax readback + Python
    slot bookkeeping).  This builder fuses N steps into a single jitted
    call: the model step, the sampling draw, the per-slot position
    advance, and the EOS/length stopping decision all stay on device;
    the host syncs once per N-token block.

    Returned callable::

        decode_loop(params, cache, tokens, pos, live, stop_pos,
                    sample_params, key, step0, eos_id)
            -> (cache, tokens, pos, live, block_tokens, block_live)

    * ``tokens`` (B, 1) i32 — each slot's next input token.
    * ``pos`` (B,) i32 — current cache position per slot.  With a
      *paged* cache the carry additionally threads the block-table
      leaves unchanged: page *assignment* is a host decision made at
      admission (the engine allocates a request's whole token budget up
      front), so the device loop never calls back into the allocator —
      each step's KV write resolves ``pos`` through the table it was
      launched with, and dead lanes resolve to the trash page.
    * ``live`` (B,) bool — slots that are generating; dead slots are
      frozen (token/pos held, emissions masked) exactly as the per-token
      engine freezes them, so a block is bit-equivalent to N single
      steps.
    * ``stop_pos`` (B,) i32 — a slot's ``live`` drops once its position
      reaches this bound (prompt_len + gen budget).
    * ``sample_params`` — {"temperature": (B,) f32, "top_k": (B,) i32};
      temperature <= 0 is greedy (see repro.kernels.sampling).
    * ``key``/``step0`` — PRNG base and global step offset; step ``i``
      draws with ``fold_in(key, step0 + i)``, so any split of a
      generation into blocks consumes identical randomness
      (``step_many(2); step_many(3)`` == ``step_many(5)``).  ``key``
      may be None when every slot is greedy: sampling then skips the
      top-k sorts and noise generation entirely (greedy consumes no
      PRNG state, so switching between the two compiled variants never
      shifts the stream).
    * ``eos_id`` i32 scalar — sampling it kills the slot (-1 disables).

    ``block_tokens``/``block_live`` (steps, B): the token each slot
    *emitted* at each step (its input token, matching ``Engine.step``'s
    append-then-advance order) and whether the slot was live then.
    """
    from ..kernels.ops import sample_tokens
    from ..models.api import decode_fn

    def decode_loop(params, cache, tokens, pos, live, stop_pos,
                    sample_params, key, step0, eos_id):
        temperature = sample_params["temperature"]
        top_k = sample_params["top_k"]

        def body(carry, i):
            cache, tok, pos, live = carry
            logits, new_cache = decode_fn(params, tok, cache, pos, cfg, ctx)
            step_key = (None if key is None
                        else jax.random.fold_in(key, step0 + i))
            nxt = sample_tokens(logits[:, -1].astype(jnp.float32),
                                temperature, top_k, step_key,
                                backend=ctx.backend)
            emitted, emit_live = tok[:, 0], live
            new_pos = jnp.where(live, pos + 1, pos)
            new_tok = jnp.where(live, nxt, tok[:, 0])[:, None]
            new_live = live & (nxt != eos_id) & (new_pos < stop_pos)
            return (new_cache, new_tok, new_pos, new_live), \
                (emitted, emit_live)

        (cache, tokens, pos, live), (block_tokens, block_live) = \
            jax.lax.scan(body, (cache, tokens, pos, live),
                         jnp.arange(steps, dtype=jnp.int32))
        return cache, tokens, pos, live, block_tokens, block_live

    return decode_loop


def build_prefill_step(cfg: ModelConfig, ctx: QuantContext) -> Callable:
    """(params, batch, cache, pos) -> (chunk_logits, cache).

    The chunked-prefill step used by the serving engine: ``pos`` gives
    each slot's current cache position and the returned logits cover
    every chunk position (so ragged prompt ends can be read per slot).
    Pass ``pos=None`` for a whole-prompt prefill from position 0.

    Cache-layout agnostic: with a paged cache the chunk's K/V scatter
    through each slot's block table instead of a dense row range, and
    writes past a slot's allocation (the dense layout's margin rows)
    land on the shared trash page.  Same step function, same jit — the
    layout is carried entirely by the cache pytree.
    """
    from ..models.api import prefill_fn

    def prefill_step(params, batch, cache, pos=None):
        return prefill_fn(params, batch, cache, cfg, ctx, pos=pos,
                          full_logits=True)

    return prefill_step
