"""Synthetic token pipeline: deterministic, shard-aware, prefetched.

Real pretraining feeds sharded token files; for a self-contained framework
the pipeline synthesizes a *learnable* stream instead of uniform noise: a
first-order Markov chain over the vocabulary (fixed per-seed transition
structure), so examples/train drivers show genuinely decreasing loss.

Determinism contract: ``batch(step)`` is a pure function of (seed, step,
shape) — restart/elastic-resume replays the exact stream from any step
(the checkpoint stores only the step counter).  ``Prefetcher`` overlaps
host-side generation with device compute by one step (double buffering).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig

__all__ = ["SyntheticLM", "Prefetcher", "make_batch", "batch_struct"]


class SyntheticLM:
    """Markov-chain token source.

    Each vocabulary symbol ``v`` prefers a small successor set derived from
    an affine map (v*a + c + noise-free choice among k) — enough structure
    for a model to reach low loss quickly, cheap enough to synthesize at
    pipeline speed.
    """

    def __init__(self, vocab: int, *, seed: int = 0, branching: int = 4):
        self.vocab = int(vocab)
        self.seed = seed
        self.k = branching
        rng = np.random.RandomState(seed)
        self._succ = rng.randint(0, self.vocab,
                                 size=(min(self.vocab, 4096), branching))

    def tokens(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        out = np.empty((batch, seq + 1), np.int64)
        cur = rng.randint(0, self.vocab, size=batch)
        out[:, 0] = cur
        choice = rng.randint(0, self.k, size=(batch, seq))
        for t in range(seq):
            row = self._succ[cur % self._succ.shape[0], choice[:, t]]
            cur = row % self.vocab
            out[:, t + 1] = cur
        return out

    def batch(self, step: int, batch: int, seq: int) -> Dict[str, np.ndarray]:
        toks = self.tokens(step, batch, seq)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_batch(cfg: ModelConfig, step: int, batch: int, seq: int, *,
               seed: int = 0, dtype=np.float32) -> Dict[str, np.ndarray]:
    """Family-aware batch: adds stub modality inputs where required."""
    src = SyntheticLM(cfg.vocab, seed=seed)
    b = src.batch(step, batch, seq)
    rng = np.random.RandomState((seed * 7 + step) % 2**31)
    if cfg.family == "encdec":
        enc_len = min(seq, cfg.enc_len_cap)
        b["enc_input"] = rng.randn(batch, enc_len,
                                   cfg.d_model).astype(dtype) * 0.02
    if cfg.family == "vlm":
        b["img_embed"] = rng.randn(batch, cfg.n_img_tokens,
                                   cfg.d_model).astype(dtype) * 0.02
    return b


def batch_struct(cfg: ModelConfig, batch: int, seq: int,
                 dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins matching :func:`make_batch` (dry-run)."""
    s = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
         "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        s["enc_input"] = jax.ShapeDtypeStruct(
            (batch, min(seq, cfg.enc_len_cap), cfg.d_model), dtype)
    if cfg.family == "vlm":
        s["img_embed"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_img_tokens, cfg.d_model), dtype)
    return s


class Prefetcher:
    """One-step-ahead background batch producer."""

    def __init__(self, fn, start_step: int = 0, depth: int = 2):
        self._fn = fn
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            item = self._fn(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, item), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
