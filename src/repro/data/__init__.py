"""Deterministic synthetic data pipeline with background prefetch."""

from .pipeline import SyntheticLM, Prefetcher, make_batch, batch_struct

__all__ = ["SyntheticLM", "Prefetcher", "make_batch", "batch_struct"]
