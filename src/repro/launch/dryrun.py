import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the
# device count at first initialization).

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell: build the production
mesh from placeholder host devices, lower the jitted step with
ShapeDtypeStruct inputs and explicit in_shardings, ``.compile()`` it (the
SPMD partitioner must succeed), and record ``memory_analysis()`` /
``cost_analysis()`` / the parsed collective schedule as a JSON artifact
for EXPERIMENTS.md §Dry-run and §Roofline.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all \
        --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback


def dataclasses_asdict(x):
    return dataclasses.asdict(x)

import jax
import jax.numpy as jnp

from ..configs import get_config, list_archs
from ..dist.sharding import batch_specs, cache_specs, named, param_specs
from ..models.config import ModelConfig
from ..nn.context import QuantContext
from ..optim import cosine_warmup
from .mesh import make_production_mesh
from .roofline import roofline
from .specs import (SHAPES, applicable, input_specs, microbatches_for,
                    state_struct)


def _ctx(cfg: ModelConfig, overrides=None) -> QuantContext:
    kw = dict(compute_dtype=jnp.bfloat16)
    if overrides:
        kw.update(overrides)
    return QuantContext(**kw)


def build_lowerable(cfg: ModelConfig, shape: str, mesh, *,
                    ctx_overrides=None, microbatches=None, kv8=False):
    """Returns (jitted_fn, example_args_structs) for one cell."""
    from ..train.step import build_serve_step, build_train_step
    from ..models.api import get_family, prefill_fn

    plan = SHAPES[shape]
    ctx = _ctx(cfg, ctx_overrides)
    specs = input_specs(cfg, shape,
                        dtype=jnp.int8 if kv8 else jnp.bfloat16)

    if plan.kind == "train":
        dp = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                dp *= mesh.shape[a]
        mb = microbatches if microbatches is not None else \
            microbatches_for(cfg, shape, dp)
        st = state_struct(cfg)
        specs_all = param_specs(st, mesh)
        step = build_train_step(
            cfg, ctx, lr_fn=lambda s: cosine_warmup(
                s, peak=3e-4, warmup=2000, total=100_000),
            microbatches=mb, grad_specs=specs_all["params"])
        st_sh = named(specs_all, mesh)
        b_sh = named(batch_specs(specs["batch"], mesh), mesh)
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, rep), donate_argnums=(0,))
        return fn, (st, specs["batch"])

    params = jax.eval_shape(
        lambda: get_family(cfg).init(jax.random.PRNGKey(0), cfg,
                                     dtype=jnp.bfloat16))
    p_sh = named(param_specs(params, mesh), mesh)
    c_sh = named(cache_specs(specs["cache"], mesh), mesh)

    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if plan.kind == "prefill":
        def prefill_step(p, batch, cache):
            return prefill_fn(p, batch, cache, cfg, ctx)
        b_sh = named(batch_specs(specs["batch"], mesh), mesh)
        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(rep, c_sh), donate_argnums=(2,))
        return fn, (params, specs["batch"], specs["cache"])

    serve = build_serve_step(cfg, ctx)
    t_sh = named(batch_specs(specs["tokens"], mesh), mesh)
    pos_sh = named(batch_specs(specs["pos"], mesh), mesh)
    fn = jax.jit(serve, in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                 out_shardings=(rep, c_sh), donate_argnums=(1,))
    return fn, (params, specs["cache"], specs["tokens"], specs["pos"])


def model_flops_for(cfg: ModelConfig, shape: str) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference, with N the
    *matmul-active* params (embedding gathers excluded — see
    ModelConfig.flop_params)."""
    plan = SHAPES[shape]
    n = cfg.flop_params()
    if plan.kind == "train":
        tokens = plan.batch * plan.seq
        return 6.0 * n * tokens
    if plan.kind == "prefill":
        return 2.0 * n * plan.batch * plan.seq
    return 2.0 * n * plan.batch  # decode: one token per sequence


def run_cell(arch: str, shape: str, mesh_kind: str, *, ctx_overrides=None,
             microbatches=None, verbose=True, tag="", kv8=False):
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    from ..dist.constrain import use_mesh
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    t0 = time.time()
    with use_mesh(mesh):
        fn, args = build_lowerable(cfg, shape, mesh,
                                   ctx_overrides=ctx_overrides,
                                   microbatches=microbatches, kv8=kv8)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    mem_d = {k: int(getattr(mem, k)) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")
             if hasattr(mem, k)}
    cost = dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    if os.environ.get("DRYRUN_SAVE_HLO"):
        os.makedirs("artifacts/hlo", exist_ok=True)
        with open(f"artifacts/hlo/{arch}__{shape}__{mesh_kind}.hlo.txt",
                  "w") as f:
            f.write(hlo)
    rep = roofline(arch=arch, shape=shape, mesh=mesh_kind, chips=chips,
                   cost=cost, hlo_text=hlo,
                   model_flops=model_flops_for(cfg, shape),
                   memory_analysis=mem_d)
    out = rep.to_json()
    from ..dist.options import flags as _flags
    out.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1), tag=tag,
               opt_flags=dataclasses_asdict(_flags()),
               microbatches=(microbatches if microbatches is not None
                             else microbatches_for(cfg, shape,
                                                   512 // 16 if mesh_kind == "multi" else 16)))
    if verbose:
        print(f"[{arch} × {shape} × {mesh_kind}] chips={chips} "
              f"compute={rep.compute_s*1e3:.2f}ms "
              f"memory={rep.memory_s*1e3:.2f}ms "
              f"collective={rep.collective_s*1e3:.2f}ms "
              f"bottleneck={rep.bottleneck} mfu={rep.mfu:.3f}")
        print("  memory_analysis:", mem_d)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) for --mesh")
    ap.add_argument("--out", default=None, help="artifact directory")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--opt", default=None,
                    help="'all' or comma list of perf flags "
                         "(grad_specs,sp_attn,seq_kv)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache for decode/prefill cells")
    args = ap.parse_args()

    if args.opt:
        from ..dist.options import PerfFlags, set_flags
        if args.opt == "all":
            set_flags(PerfFlags.all_on())
        else:
            names = set(args.opt.split(","))
            set_flags(PerfFlags(**{n: True for n in names}))

    cells = []
    if args.all:
        archs = [a for a in list_archs() if a != "jet-mlp"]
        for a in archs:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        try:
            r = run_cell(arch, shape, args.mesh,
                         microbatches=args.microbatches, tag=args.tag,
                         kv8=args.kv8)
        except Exception as e:  # a failed cell is a bug — surface it
            traceback.print_exc()
            r = {"arch": arch, "shape": shape, "mesh": args.mesh,
                 "status": "error", "error": repr(e)}
        results.append(r)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            suffix = f"__{args.tag}" if args.tag else ""
            fn = os.path.join(args.out,
                              f"{arch}__{shape}__{args.mesh}{suffix}.json")
            with open(fn, "w") as f:
                json.dump(r, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary [{args.mesh}]: {n_ok} ok, {n_skip} skipped, "
          f"{n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
