"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run JSON artifacts.

Usage:  PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
"""

import argparse
import glob
import json
import os
from collections import defaultdict


def load(dirname):
    cells = []
    for fn in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        cells.append(json.load(open(fn)))
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x * 1e3:.1f}ms"


def roofline_table(cells, mesh="single", tag=""):
    rows = ["| arch | shape | compute | memory | collective | bottleneck "
            "| MODEL/HLO flops | MFU* | per-chip HBM |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if c.get("mesh") != mesh or c.get("tag", "") != tag:
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                        f"skipped | — | — | — |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | | | |")
            continue
        mem = c.get("memory_analysis") or {}
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)) / 1e9
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(c['compute_s'])} | "
            f"{fmt_s(c['memory_s'])} | {fmt_s(c['collective_s'])} | "
            f"**{c['bottleneck']}** | {c['useful_flops_fraction']:.2f} | "
            f"{c['mfu']:.4f} | {hbm:.1f} GB |")
    return "\n".join(rows)


def collective_detail(cells, picks):
    out = []
    for c in cells:
        key = (c["arch"], c["shape"], c.get("mesh"))
        if key not in picks or c["status"] != "ok":
            continue
        out.append(f"**{c['arch']} × {c['shape']} × {c['mesh']}** "
                   f"(wire {c['wire_bytes_per_chip'] / 1e9:.1f} GB/chip):")
        for col in c.get("collectives", [])[:5]:
            out.append(f"  - {col['kind']}: n={col['count']:.0f}, "
                       f"tensor {col['tensor_bytes'] / 1e9:.2f} GB, "
                       f"wire {col['wire_bytes'] / 1e9:.2f} GB")
    return "\n".join(out)


def summary(cells):
    ok = [c for c in cells if c["status"] == "ok"]
    sk = [c for c in cells if c["status"] == "skipped"]
    er = [c for c in cells if c["status"] == "error"]
    by_bn = defaultdict(int)
    for c in ok:
        by_bn[c["bottleneck"]] += 1
    return (f"{len(ok)} compiled, {len(sk)} skipped (documented), "
            f"{len(er)} errors; bottlenecks: {dict(by_bn)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    cells = load(args.dir)
    cells = [c for c in cells if c.get("tag", "") == args.tag]
    print(summary(cells))
    print()
    print(roofline_table(cells, args.mesh, args.tag))


if __name__ == "__main__":
    main()
