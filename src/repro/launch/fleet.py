"""Fleet: N engine replicas behind one submit/step/results surface.

One :class:`~repro.launch.serve.Engine` is a single failure domain: a
process death loses everything past the last durable snapshot unless a
cold :meth:`Engine.recover` replays the journal.  The fleet layer turns
that single-engine durability story into a *serving* availability story
with three pillars:

* **Journal-shipped hot standby.**  The primary journals every
  lifecycle transition (PR 9's fsync'd write-ahead log); a warm standby
  engine tails that journal through :meth:`BlobLog.follow` and applies
  each record through the same ``_replay_event`` path recovery uses,
  staying within ``max_standby_lag`` records of the primary.  When the
  primary dies — *detected* by its step raising under the fleet, never
  announced — :meth:`Fleet.promote` finishes the tail replay and
  installs the standby as the new primary.  Because "block" records are
  write-ahead and greedy decode is deterministic, every in-flight
  stream resumes byte-identical to the uninterrupted run; promotion is
  a warm restart without the cold rebuild.

* **SLO-aware routing with failure detection.**  ``submit`` routes each
  request to the replica with the least class-aware pressure (queued
  depth at or above the request's class, lane and page occupancy, TTFT
  risk against the class's SLO target).  A per-replica
  :class:`~repro.ft.straggler.ReplicaHeartbeat` fed by block progress
  plus the existing :class:`~repro.ft.straggler.StragglerMonitor`
  escalates a stalled replica alive → suspect → dead with hysteresis;
  routing avoids suspects while their in-flight work stays put, and a
  death re-dispatches the replica's journaled-but-unfinished requests
  to survivors exactly once — the ledger built at submit time is the
  dedup record, so no stream is lost or duplicated.

* **Class isolation end to end.**  Page-pool class quotas
  (:func:`~repro.launch.lifecycle.normalize_class_quotas`, enforced by
  the allocator and the prefix index) keep a BATCH flood from evicting
  the REALTIME working set on every replica, and re-dispatch after a
  death resumes REALTIME victims first.

The fleet is deliberately in-process: replicas are engine objects, the
"network" between them is the journal file, and death is an exception
out of a replica's step.  That keeps every conformance property —
promotion byte-identity, exactly-once re-dispatch, bounded lag —
assertable in CI with deterministic chaos schedules
(:class:`~repro.ft.serving.FleetFaultInjector`), per the source
brief's validate-under-perturbation method.
"""

from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional

import numpy as np

from ..checkpoint.store import BlobLog
from ..ft.serving import InjectedCrash
from ..ft.straggler import ReplicaHeartbeat, StragglerMonitor
from .lifecycle import coerce_priority

__all__ = ["Fleet"]


class Fleet:
    """N replicas, one serving surface, supervised failure handling.

    ``make_engine`` is a zero-argument factory (keyword overrides
    allowed) building one fresh engine; the fleet owns replica
    construction so a promotion can mint the standby's successor the
    same way.  With ``standby_dir`` set, replica 0 (the primary)
    journals under it and a hot standby tails that journal; without it
    a primary death is handled like any secondary's — survivors absorb
    the re-dispatched work.

    ``max_standby_lag`` bounds how many journal records the standby
    may trail the primary by before the fleet forces a catch-up drain
    (an injected lag spike may defer *one* sync, never the bound).
    The heartbeat thresholds mirror :class:`ReplicaHeartbeat`.
    """

    def __init__(self, make_engine: Callable, n_replicas: int, *,
                 standby_dir: Optional[str] = None,
                 max_standby_lag: int = 64,
                 suspect_after: int = 2, dead_after: int = 4,
                 recover_after: int = 2,
                 fault_injector=None, clock=None):
        if int(n_replicas) <= 0:
            raise ValueError(
                f"n_replicas must be positive (got {n_replicas}): a fleet "
                f"with no replicas can serve nothing")
        if int(max_standby_lag) < 0:
            raise ValueError(
                f"max_standby_lag must be >= 0 (got {max_standby_lag}): "
                f"the standby can never be ahead of the journal, so a "
                f"negative lag bound is unsatisfiable")
        self.n_replicas = int(n_replicas)
        self.max_standby_lag = int(max_standby_lag)
        self._standby_dir = None if standby_dir is None else str(standby_dir)
        self._make_engine = make_engine
        self.fault_injector = fault_injector
        self._hb_kw = dict(suspect_after=suspect_after,
                           dead_after=dead_after,
                           recover_after=recover_after)

        self.replicas: List = []
        for r in range(self.n_replicas):
            if r == 0 and self._standby_dir is not None:
                self.replicas.append(make_engine(
                    durable_dir=self._standby_dir))
            else:
                self.replicas.append(make_engine())
        self.clock = clock if clock is not None else self.replicas[0].clock

        self.standby = None
        self._follower = None
        self._journal_path = None
        if self._standby_dir is not None:
            # the standby replays the primary's journal, so it must be
            # built identically — same factory, no durable_dir (its
            # journal handle arrives at promotion, exactly like a cold
            # Engine.recover)
            self.standby = make_engine()
            self._journal_path = os.path.join(self._standby_dir,
                                              "journal.log")
            if getattr(self.replicas[0], "_journal", None) is None:
                raise RuntimeError(
                    "standby_dir set but the primary is not journaling: "
                    "make_engine must thread durable_dir through to the "
                    "engine")
            self._follower = self.replicas[0]._journal.follow()

        # validate the heartbeat thresholds once, loudly, before any
        # replica depends on them
        self.state = ["alive"] * self.n_replicas
        self.heartbeats = [ReplicaHeartbeat(**self._hb_kw)
                           for _ in range(self.n_replicas)]
        self.monitors = [StragglerMonitor(window=16, patience=1)
                         for _ in range(self.n_replicas)]
        self._dead_handled = set()
        self._round = 0
        self._lag_pending = False

        #: fleet id -> routing ledger entry: where the request went,
        #: its local id there, the full re-submittable spec, and
        #: whether it was already re-dispatched (exactly-once guard)
        self._ledger: Dict[int, dict] = {}
        self._by_local: Dict[tuple, int] = {}
        self._next_fid = 0
        #: fleet id -> terminal {"status", "tokens"} (harvested)
        self.results: Dict[int, dict] = {}
        self.counters = {"routed": 0, "deaths": 0, "promotions": 0,
                         "redispatched": 0, "suspects": 0,
                         "time_to_promote_s": None,
                         "journal_lag_records": 0}

    # -- routing -------------------------------------------------------------
    def _routable(self) -> List[int]:
        """Replicas submit may target: alive first, suspects only when
        nothing is alive (a suspect is avoided, not abandoned)."""
        alive = [r for r in range(self.n_replicas)
                 if self.state[r] == "alive"]
        if alive:
            return alive
        suspect = [r for r in range(self.n_replicas)
                   if self.state[r] == "suspect"]
        if suspect:
            return suspect
        raise RuntimeError("no live replicas: the whole fleet is dead")

    def _pressure(self, r: int, cls) -> tuple:
        """Class-aware pressure score for replica ``r`` (lower routes
        first).  Components mirror :meth:`Engine.stats`: queued work at
        or above the request's class, lane occupancy, page-pool
        occupancy, and TTFT risk against the class's SLO target."""
        eng = self.replicas[r]
        ahead = sum(1 for q in eng.waiting
                    if coerce_priority(q.get("priority")) <= cls)
        running = int(np.asarray(eng.live).sum())
        lanes = running / max(1, eng.batch)
        pool = 0.0
        if eng.paged:
            a = eng.allocator
            pool = 1.0 - a.free_pages / max(1, a.num_pages)
        risk = 0.0
        tgt = (eng.slo_targets or {}).get(cls, {}).get("ttft_s")
        if tgt:
            now = self.clock()
            waits = [now - q["t_submit"] for q in eng.waiting
                     if coerce_priority(q.get("priority")) == cls
                     and q.get("t_submit") is not None]
            if waits:
                risk = max(waits) / float(tgt)
        # suspects score after every alive replica at equal pressure;
        # the replica index breaks exact ties deterministically
        return (ahead + running + lanes + pool + risk,
                0 if self.state[r] == "alive" else 1, r)

    def submit(self, prompt, *, gen_len=None, temperature: float = 0.0,
               top_k: int = 0, deadline_s=None, priority=None) -> int:
        """Route one request to the least-pressure live replica;
        returns a *fleet* id (stable across re-dispatch and promotion —
        the per-replica id is an implementation detail)."""
        cls = coerce_priority(priority)
        r = min(self._routable(), key=lambda i: self._pressure(i, cls))
        local = self.replicas[r].submit(
            prompt, gen_len=gen_len, temperature=temperature,
            top_k=top_k, deadline_s=deadline_s, priority=priority)
        fid = self._next_fid
        self._next_fid += 1
        self._ledger[fid] = {
            "replica": r, "local_id": local, "priority": cls,
            "spec": {"prompt": np.array(prompt, np.int32, copy=True),
                     "gen_len": gen_len, "temperature": temperature,
                     "top_k": top_k, "deadline_s": deadline_s,
                     "priority": priority},
            "redispatched": False}
        self._by_local[(r, local)] = fid
        self.counters["routed"] += 1
        return fid

    def status(self, fid: int):
        """Terminal status if harvested, else the owning replica's
        live status (None = unknown fleet id)."""
        if fid in self.results:
            return self.results[fid]["status"]
        ent = self._ledger.get(fid)
        if ent is None:
            return None
        if self.state[ent["replica"]] == "dead":
            return None
        return self.replicas[ent["replica"]].status(ent["local_id"])

    def try_admit(self) -> int:
        n = 0
        for r in range(self.n_replicas):
            if self.state[r] != "dead":
                n += self.replicas[r].try_admit()
        # the admission sweep journals on the primary even when idle;
        # sync here too or a drive loop that admits after stepping
        # leaves the standby perpetually one record behind (and
        # ``busy()`` never clears).  An injected lag spike from the
        # current round still defers, same as in step_many.
        self._sync_standby(lag_fault=self._lag_pending)
        return n

    # -- the supervised step loop -------------------------------------------
    def step_many(self, n: int) -> None:
        """One fleet round: every non-dead replica runs one ``n``-token
        block under supervision (chaos hooks, straggler timing, death
        detection, harvest, heartbeat), then the standby syncs."""
        self._round += 1
        inj = self.fault_injector
        lag_fault = inj.lag_injected(self._round) if inj else False
        self._lag_pending = lag_fault
        try:
            for r in range(self.n_replicas):
                if self.state[r] == "dead":
                    continue
                eng = self.replicas[r]
                before = int(eng.counters["gen_tokens"])
                had_work = bool(np.asarray(eng.live).any()) or bool(
                    eng.waiting)
                stalled = False
                t0 = self.clock()
                try:
                    kinds = (inj.before_step(self._round, r, eng)
                             if inj else ())
                    if "stall" in kinds:
                        # a hung worker: no step, no progress, and the
                        # round still charges it a full block of time
                        stalled = True
                    else:
                        eng.step_many(n)
                        eng.retire_finished()
                except InjectedCrash:
                    self._on_death(r)
                    continue
                duration = (self.clock() - t0) + (1.0 if stalled else 0.0)
                flagged = self.monitors[r].record(self._round, duration)
                progressed = (not stalled and (
                    int(eng.counters["gen_tokens"]) > before
                    or not had_work))
                # harvest BEFORE the beat: a replica's last good block
                # must land even if this beat kills it
                self._harvest(r)
                self._beat(r, healthy=progressed and not flagged)
        finally:
            self._sync_standby(lag_fault=lag_fault)

    def _beat(self, r: int, healthy: bool) -> None:
        if self.state[r] == "dead":
            return
        prev = self.state[r]
        state = self.heartbeats[r].beat(healthy)
        self.state[r] = state
        if state == "suspect" and prev == "alive":
            self.counters["suspects"] += 1
        if state == "dead":
            self._on_death(r)

    def _harvest(self, r: int) -> None:
        """Copy newly terminal results from replica ``r`` into the
        fleet's result map, keyed by fleet id."""
        eng = self.replicas[r]
        for local, res in eng.results.items():
            fid = self._by_local.get((r, local))
            if fid is None or fid in self.results:
                continue
            if self._ledger[fid]["replica"] != r:
                # stale mapping from before a re-dispatch — the entry
                # now lives elsewhere; only the current owner reports
                continue
            self.results[fid] = {"status": res["status"],
                                 "tokens": list(res["tokens"])}

    # -- death, promotion, re-dispatch --------------------------------------
    def _on_death(self, r: int) -> None:
        """A replica died under us (its step raised, or the heartbeat
        escalated it to dead).  Idempotent."""
        if r in self._dead_handled:
            return
        self._dead_handled.add(r)
        self.state[r] = "dead"
        self.heartbeats[r].state = "dead"
        self.counters["deaths"] += 1
        j = getattr(self.replicas[r], "_journal", None)
        if j is not None:
            j.close()
        if r == 0 and self.standby is not None:
            self.promote()
        else:
            self._redispatch(r)

    def promote(self) -> dict:
        """Finish the standby's tail replay and install it as the new
        primary, resuming every in-flight stream byte-identically.

        The journal is the whole story: the dead primary's snapshot
        directory is untouched, the standby replays every record the
        follower had not yet applied (write-ahead "block" records mean
        a death *mid-block* still replays that block), then reopens
        the journal for append — torn tail truncated — and takes over
        journaling.  Exactly-once for routed requests falls out of
        submit being journaled before it returns: anything the ledger
        knows about is in the journal, so the standby already has it.
        """
        if self.standby is None:
            raise RuntimeError(
                "promote() without a standby: construct the Fleet with "
                "standby_dir to run one")
        t0 = self.clock()
        self._apply_tail()
        sb, self.standby, self._follower = self.standby, None, None
        log = BlobLog(self._journal_path)    # reopen for append
        sb._journal = log
        sb._durable_dir = self._standby_dir
        sb._blocks_since_snap = 0
        sb.counters["recoveries"] += 1
        sb.journal_lag_records = 0
        self.replicas[0] = sb
        self.state[0] = "alive"
        self._dead_handled.discard(0)
        self.heartbeats[0] = ReplicaHeartbeat(**self._hb_kw)
        self.monitors[0] = StragglerMonitor(window=16, patience=1)
        self.counters["promotions"] += 1
        self.counters["time_to_promote_s"] = float(self.clock() - t0)
        # belt and braces: anything routed to the primary that the
        # journal somehow does not know about (it should not exist —
        # submit journals before returning) re-dispatches like a
        # secondary's loss, exactly once
        for fid, ent in sorted(self._ledger.items(),
                               key=lambda kv: (int(kv[1]["priority"]),
                                               kv[0])):
            if (ent["replica"] == 0 and fid not in self.results
                    and not ent["redispatched"]
                    and sb.status(ent["local_id"]) is None):
                self._redispatch_one(fid)
        self._harvest(0)
        return {"time_to_promote_s": self.counters["time_to_promote_s"]}

    def _redispatch(self, r: int) -> None:
        """Re-dispatch every un-harvested request that was routed to
        the dead replica ``r`` — REALTIME victims first, FIFO within a
        class — to the surviving least-pressure replicas."""
        victims = sorted(
            (fid for fid, ent in self._ledger.items()
             if ent["replica"] == r and fid not in self.results),
            key=lambda fid: (int(self._ledger[fid]["priority"]), fid))
        for fid in victims:
            self._redispatch_one(fid)

    def _redispatch_one(self, fid: int) -> None:
        ent = self._ledger[fid]
        if ent["redispatched"]:
            raise RuntimeError(
                f"request {fid} re-dispatched twice — the exactly-once "
                f"ledger is broken")
        spec = ent["spec"]
        cls = ent["priority"]
        r = min(self._routable(), key=lambda i: self._pressure(i, cls))
        local = self.replicas[r].submit(
            spec["prompt"], gen_len=spec["gen_len"],
            temperature=spec["temperature"], top_k=spec["top_k"],
            deadline_s=spec["deadline_s"], priority=spec["priority"])
        ent["replica"], ent["local_id"] = r, local
        ent["redispatched"] = True
        self._by_local[(r, local)] = fid
        self.counters["redispatched"] += 1

    # -- standby sync --------------------------------------------------------
    def _sync_standby(self, lag_fault: bool = False) -> int:
        """Tail the primary's journal into the standby.  An injected
        lag spike may skip one sync — unless skipping would breach
        ``max_standby_lag``, in which case the bound wins and the
        standby drains anyway."""
        if self._follower is None:
            return 0
        primary = self.replicas[0]
        j = getattr(primary, "_journal", None)
        total = j.count if j is not None else self._follower.count
        if lag_fault:
            lag = total - self._follower.count
            if lag <= self.max_standby_lag:
                self.counters["journal_lag_records"] = lag
                if self.state[0] != "dead":
                    primary.journal_lag_records = lag
                return 0
        applied = self._apply_tail()
        lag = total - self._follower.count
        self.counters["journal_lag_records"] = lag
        if self.state[0] != "dead":
            primary.journal_lag_records = lag
        return applied

    def _apply_tail(self) -> int:
        """Apply every complete journal record the standby has not yet
        seen, through the same muted replay path recovery uses."""
        sb = self.standby
        recs = self._follower.poll()
        if not recs:
            return 0
        sb._jmute += 1
        try:
            for rec in recs:
                sb._replay_event(rec)
        finally:
            sb._jmute -= 1
        return len(recs)

    # -- drive helpers -------------------------------------------------------
    def busy(self) -> bool:
        """Any non-dead replica with queued or running work, or a
        standby still behind the journal."""
        for r in range(self.n_replicas):
            if self.state[r] == "dead":
                continue
            eng = self.replicas[r]
            if bool(np.asarray(eng.live).any()) or eng.waiting:
                return True
        if self._follower is not None:
            j = getattr(self.replicas[0], "_journal", None)
            if j is not None and self._follower.count < j.count:
                return True
        return False

    def drain(self, block: int = 4, max_rounds: int = 10_000) -> None:
        """Step until every routed request is terminal (the serve CLI's
        fleet loop).  ``max_rounds`` is a runaway guard — hitting it
        means a request can neither run nor finish, which is a bug."""
        self.try_admit()
        rounds = 0
        while self.busy() or len(self.results) < len(self._ledger):
            self.step_many(block)
            self.try_admit()
            rounds += 1
            if rounds >= max_rounds:
                raise RuntimeError(
                    f"fleet failed to drain in {max_rounds} rounds: "
                    f"{len(self.results)}/{len(self._ledger)} terminal")

    def stats(self) -> dict:
        """Fleet-level telemetry plus each replica's engine stats
        (None for dead replicas — their engines are gone)."""
        out = dict(self.counters)
        out["replicas"] = self.n_replicas
        out["states"] = list(self.state)
        out["round"] = self._round
        out["results"] = len(self.results)
        out["routed_open"] = len(self._ledger) - len(self.results)
        out["standby"] = self.standby is not None
        out["per_replica"] = [
            self.replicas[r].stats() if self.state[r] != "dead" else None
            for r in range(self.n_replicas)]
        return out
