"""Roofline term derivation from compiled dry-run artifacts.

TPU v5e hardware model (per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI                ~50 GB/s per link

Terms (seconds, per step, per chip — the SPMD module is per-device, so
``cost_analysis`` flops/bytes are already per-chip):

    compute    = flops / peak
    memory     = bytes_accessed / hbm_bw
    collective = wire_bytes / (links × link_bw)

``wire_bytes`` comes from parsing the post-optimization HLO: for each
collective op we take the tensor bytes ``T`` (result shape; operands for
reduce-scatter) and apply the standard ring cost on the participating
group of size n: all-reduce 2·T·(n-1)/n, all-gather/reduce-scatter
T·(n-1)/n, all-to-all T·(n-1)/n, collective-permute T.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

__all__ = ["HW", "parse_collectives", "roofline", "RooflineReport"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # bytes/s / chip
    link_bw: float = 50e9            # bytes/s / link
    links: int = 4                   # ICI links per chip engaged


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUP_RE2.search(line)
    if m:  # iota format [n_groups, group_size]
        return int(m.group(2))
    m = _GROUP_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([t for t in first.split(",") if t.strip() != ""])
    return total_devices


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def parse_collectives(hlo_text: str, total_devices: int) -> List[Dict]:
    """Extract every collective op: kind, tensor bytes, group size, wire
    bytes under the ring model.

    The result type sits between '=' and the op name; tuple-typed
    collectives (variadic all-reduce/all-to-all) sum all member shapes.
    Async pairs are counted once at the ``-start`` (or the sync form); the
    ``-done`` is skipped.
    """
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        shapes = _SHAPE_RE.findall(type_str)
        if not shapes:
            continue
        t_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        n = max(_group_size(line, total_devices), 1)
        ring = (n - 1) / n if n > 1 else 0.0
        factor = {"all-reduce": 2 * ring, "all-gather": ring,
                  "reduce-scatter": ring, "all-to-all": ring,
                  "collective-permute": 1.0}[kind]
        out.append({"kind": kind, "tensor_bytes": t_bytes, "group": n,
                    "wire_bytes": t_bytes * factor})
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float        # fusion-aware (see hlo_analysis)
    wire_bytes_per_chip: float
    bytes_all_per_chip: float    # pessimistic no-fusion upper bound
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # 6·N·D or 2·N·D (global)
    collectives: List[Dict] = dataclasses.field(default_factory=list)
    memory_analysis: Optional[Dict] = None
    raw_cost_analysis: Optional[Dict] = None

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline step time."""
        hw = HW()
        denom = self.step_time * self.chips * hw.peak_flops
        return self.model_flops / denom if denom else 0.0

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(bottleneck=self.bottleneck, step_time=self.step_time,
                 useful_flops_fraction=self.useful_flops_fraction,
                 mfu=self.mfu)
        return d


def roofline(*, arch: str, shape: str, mesh: str, chips: int,
             cost: Dict, hlo_text: str, model_flops: float,
             memory_analysis: Optional[Dict] = None,
             hw: HW = HW()) -> RooflineReport:
    """Roofline terms from the loop-corrected HLO analysis.

    ``cost`` (raw ``compiled.cost_analysis()``) is recorded alongside for
    reference, but the terms use :mod:`repro.launch.hlo_analysis`, which
    scales while-loop bodies by their trip counts — XLA's cost analysis
    counts scan bodies once, which would undercount our scan-heavy
    programs by 1–2 orders of magnitude.
    """
    from .hlo_analysis import analyze_hlo

    a = analyze_hlo(hlo_text, chips)
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops_per_chip=a.flops, bytes_per_chip=a.bytes,
        wire_bytes_per_chip=a.wire_bytes, bytes_all_per_chip=a.bytes_all,
        compute_s=a.flops / hw.peak_flops,
        memory_s=a.bytes / hw.hbm_bw,
        collective_s=a.wire_bytes / (hw.links * hw.link_bw),
        model_flops=model_flops,
        collectives=a.collectives,
        memory_analysis=memory_analysis,
    )
    rep.raw_cost_analysis = {"flops": float(cost.get("flops", 0.0)),
                             "bytes_accessed":
                                 float(cost.get("bytes accessed", 0.0))}
    return rep
