"""Unified serving autotuner: fitted knob resolution + adaptive spec_k.

The source paper's whole move is replacing per-layer hand-tuned pragmas
with one de-specialized library whose knob (the reuse factor) is
resolved systematically; rule4ml and HLSEstimatorML go one step further
and *fit* latency estimators from measured designs instead of trusting
an analytic model.  This module is that step for the serving engine.
The engine's knob surface —

* ``kv_split``          parallel flash-decoding partitions per slot,
* ``pages_per_step``    KV pages DMA'd per grid step (tile height),
* ``decode_block``      fused decode steps per host sync,
* ``spec_k``            drafted tokens per speculative verify round —

is resolved as ONE vector per workload shape at Engine construction,
by minimizing a latency estimator over the knob grid.  The estimator
comes in two interchangeable flavours sharing one feature basis:

* **analytic** — the hand-set constants ``choose_kv_split`` has always
  used, re-expressed as weights over the fitted basis (the zero-data
  fallback: with no measurements the resolver reproduces exactly the
  legacy ``auto_pages_per_step`` + ``choose_kv_split`` decision), and
* **fitted** — least-squares weights over the same features, trained
  on measured ``paged_attention`` latencies (``benchmarks/
  bench_calibrate.py`` sweeps the knob grid and the rows accumulate in
  ``BENCH_calibrate.json``; the fit is committed as ``AUTOTUNE.json``).

On top of the static resolution, :class:`SpecKAdapter` re-ranks
``spec_k`` *online* from the engine's measured ``draft_accepted /
verify_steps`` telemetry — acceptance is a property of the traffic, not
the geometry, so no offline fit can know it.  The adapter is
deliberately conservative: a windowed acceptance estimate, a hysteresis
band so ranking noise cannot thrash the jit cache, and a cooldown
between switches (every switch is one re-trace of the fused spec loop).

Greedy streams are invariant under every knob this module touches:
``kv_split``/``pages_per_step`` change float association only within
the kernel's documented tolerance, ``decode_block`` changes host sync
granularity, and the spec verifier commits exactly the longest
argmax-matching prefix for ANY k — so the autotuner can never change
committed tokens, only how fast they arrive.
"""

from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["WorkloadShape", "KnobVector", "LatencyEstimator",
           "analytic_estimator", "fit_rows", "feature_vector",
           "resolve", "rank_spec_k", "SpecKAdapter",
           "load_estimator", "save_artifact", "load_artifact",
           "ARTIFACT_NAME", "DECODE_BLOCKS"]

#: repo root (``src/repro/launch/autotune.py`` -> three parents up) —
#: where the bench trajectory (BENCH_*.json) and the fitted-constants
#: artifact live, mirroring ``benchmarks.run``'s convention.
_REPO_ROOT = Path(__file__).resolve().parents[3]
ARTIFACT_NAME = "AUTOTUNE.json"

#: decode-block candidates: powers of two between "per-token host sync"
#: (pointless — that is what the fused loop exists to avoid) and "one
#: sync per request" (deadlines/admission only sweep at block
#: boundaries, so an unbounded block starves the scheduler).
DECODE_BLOCKS = (4, 8, 16, 32)

#: analytic per-block overheads for the decode_block model, in the same
#: relative units as the split cost model: one host↔device round trip
#: (dispatch + readback + slot bookkeeping) vs one fused decode step.
_DISPATCH_COST = 8.0
_STEP_COST = 1.0
#: scheduler-granularity penalty per step of block size: a freed lane
#: waits up to one block for re-admission and deadlines are only swept
#: at boundaries, so bigger blocks trade throughput for responsiveness.
_SWEEP_COST = 0.25

#: speculative round economics for the k ranker: drafting one token
#: (prompt-lookup is a device-side gather, nearly free next to ONE
#: k+1-position verify pass of the target model).
_DRAFT_COST = 0.07
_VERIFY_COST = 1.0
#: zero-data prior for the per-draft acceptance probability; with the
#: default costs it ranks k=4 best — the engine's historical default.
_ACCEPT_PRIOR = 0.6

#: feature basis shared by the analytic and fitted estimators (order
#: matters — weights are stored as a plain list in the artifact).
FEATURES = ("chain", "chain_rows", "split", "lanes", "work", "one")


# ---------------------------------------------------------------------------
# shapes and knob vectors


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """What the resolver needs to know about a serving geometry.

    ``pages`` is the block-table width (pages per slot) — 0 for a dense
    cache, which skips the kv knobs.  ``gen_len`` is the expected
    generation budget per request (the decode_block amortization term);
    engines that do not know it pass their cache bound as a proxy.
    """

    pages: int
    page_size: int
    hkv: int
    batch: int
    gen_len: int = 64
    spec: bool = False


@dataclasses.dataclass(frozen=True)
class KnobVector:
    """One resolved point on the engine's knob surface."""

    kv_split: int
    pages_per_step: int
    decode_block: int
    spec_k: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# latency estimator: one feature basis, two weight sources


def feature_vector(pages: int, page_size: int, hkv: int, batch: int,
                   kv_split: int, pages_per_step: int) -> np.ndarray:
    """Analytic cost-model features of one knob point (see FEATURES).

    * ``chain``      — serial tile-chain length ``ceil(tiles / split)``
                       (the latency-critical path of the split kernel),
    * ``chain_rows`` — chain × KV rows per tile (DMA/compute volume on
                       that path; separates tall tiles from many tiles),
    * ``split``      — combine count (log-sum-exp merge traffic),
    * ``lanes``      — ``batch * hkv`` parallel grid lanes,
    * ``work``       — chain × split × rows × lanes, the total KV
                       volume the schedule touches.  Nearly constant
                       across the knob grid of ONE shape (splitting
                       re-orders work, it does not add much) but it
                       spans orders of magnitude BETWEEN shapes — it
                       absorbs the cross-shape scale so the chain/split
                       weights are identified by within-shape variation,
                       which is what the resolver actually ranks,
    * ``one``        — intercept (fixed dispatch overhead).
    """
    t = max(1, int(pages_per_step))
    split = max(1, int(kv_split))
    tiles = max(1, -(-max(1, int(pages)) // t))
    chain = -(-tiles // split)
    rows = t * max(1, int(page_size))
    lanes = max(1, int(batch)) * max(1, int(hkv))
    return np.array([chain, chain * rows, split, lanes,
                     chain * split * rows * lanes / 1024.0, 1.0],
                    np.float64)


@dataclasses.dataclass(frozen=True)
class LatencyEstimator:
    """Linear latency model over :func:`feature_vector`.

    ``source`` is provenance ("analytic", "fit", "artifact") — it rides
    into ``Engine.stats()`` so a run always says which model picked its
    knobs.  ``n_rows``/``residual`` describe the fit (0/0 analytic).
    """

    weights: tuple
    source: str = "analytic"
    n_rows: int = 0
    residual: float = 0.0

    def predict(self, pages: int, page_size: int, hkv: int, batch: int,
                kv_split: int, pages_per_step: int) -> float:
        f = feature_vector(pages, page_size, hkv, batch,
                           kv_split, pages_per_step)
        return float(f @ np.asarray(self.weights, np.float64))

    def cost_constants(self) -> dict:
        """Project the weights onto ``choose_kv_split``'s two scalars.

        The legacy ranker charges a flat TILE per serial chain step;
        this model's marginal chain-step cost is ``w_chain +
        w_chain_rows * rows + w_work * rows * lanes / 1024`` — taken at
        the canonical operating point (the 128-row MXU-target tile,
        one partition, lanes=4, i.e. the smoke engine's geometry), the
        same point at which the analytic weights round-trip to exactly
        TILE=4.0.  Clamped positive — a degenerate fit (tiny sweep,
        collinear columns) must never flip the ranking's sign.
        """
        w = np.asarray(self.weights, np.float64)
        rows, lanes = 128.0, 4.0
        tile = w[0] + w[1] * rows + w[4] * rows * lanes / 1024.0
        combine = w[2]
        return {"tile_cost": max(1e-6, float(tile)),
                "combine_cost": max(1e-6, float(combine))}

    def to_json(self) -> dict:
        return {"features": list(FEATURES),
                "weights": [float(w) for w in self.weights],
                "source": self.source, "n_rows": int(self.n_rows),
                "residual": float(self.residual),
                "constants": self.cost_constants()}

    @classmethod
    def from_json(cls, d: dict) -> "LatencyEstimator":
        if list(d.get("features", FEATURES)) != list(FEATURES):
            raise ValueError(
                f"estimator feature basis {d.get('features')} does not "
                f"match this build's {list(FEATURES)}; refit with "
                f"bench_calibrate instead of reinterpreting weights")
        return cls(weights=tuple(float(w) for w in d["weights"]),
                   source=str(d.get("source", "artifact")),
                   n_rows=int(d.get("n_rows", 0)),
                   residual=float(d.get("residual", 0.0)))


def analytic_estimator() -> LatencyEstimator:
    """The hand-set constants as weights over the fitted basis.

    ``choose_kv_split``'s flat TILE=4.0 is split into a fixed half and
    a per-row half anchored at the 128-row MXU-target tile, so tile
    height participates in the ranking (a flat per-tile charge would
    make ever-taller tiles look free) while the cost of the canonical
    tile — and therefore every legacy split decision — is unchanged.
    """
    from ..kernels.flash_attention import _ANALYTIC_COST_CONSTANTS as C
    tile, comb = C["tile_cost"], C["combine_cost"]
    return LatencyEstimator(
        weights=(tile / 2.0, tile / 2.0 / 128.0, comb, 0.0, 0.0, 0.0),
        source="analytic")


def _nonneg_lstsq(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Least squares with every weight clamped nonnegative.

    Clamp-and-refit active set: solve, drop any feature whose weight
    went negative, re-solve on the survivors.  Converges in at most
    one pass per feature and is deterministic for a given row set.
    Unconstrained lstsq is the wrong tool here: the features are
    collinear across shapes, and a *negative* weight on a work term
    lets the solver trade cross-shape scale against within-shape
    ranking — exactly the ranking the resolver exists to get right
    (a negative chain_rows weight makes LONGER serial chains predict
    cheaper, inverting every split decision).
    """
    idx = list(range(X.shape[1]))
    for _ in range(X.shape[1]):
        sol, *_ = np.linalg.lstsq(X[:, idx], y, rcond=None)
        neg = [i for j, i in enumerate(idx) if sol[j] < 0.0]
        if not neg:
            break
        idx = [i for i in idx if i not in neg]
    w = np.zeros(X.shape[1])
    for j, i in enumerate(idx):
        w[i] = max(0.0, float(sol[j]))
    return w


def fit_rows(rows: Sequence[dict]) -> LatencyEstimator:
    """Least-squares fit of the latency model from measured rows.

    Each row needs the shape/knob fields of :func:`feature_vector` plus
    ``us_per_call`` (the rows ``bench_calibrate`` emits).  rule4ml's
    lesson applies: the model only has to *rank* knob points, so a
    small constrained ``lstsq`` over the sweep rows is enough — no
    regularizer, deterministic for a given row set.  Two constraints
    keep the ranking honest where plain lstsq fails: weights are
    nonnegative (each feature is a unit of schedule work; see
    :func:`_nonneg_lstsq`) and rows are scaled to per-shape relative
    latency (see the inline note).
    """
    rows = [r for r in rows if r.get("us_per_call") is not None]
    if len(rows) < len(FEATURES):
        raise ValueError(
            f"need >= {len(FEATURES)} calibration rows to fit "
            f"{len(FEATURES)} weights (got {len(rows)}); run "
            f"benchmarks/bench_calibrate.py first")
    X = np.stack([feature_vector(r["pages"], r["page_size"], r["hkv"],
                                 r["batch"], r["kv_split"],
                                 r["pages_per_step"]) for r in rows])
    y = np.asarray([float(r["us_per_call"]) for r in rows], np.float64)
    # per-shape scale weighting: divide each row (features AND target)
    # by the shape's mean latency before solving.  The resolver only
    # ever compares candidates WITHIN one shape, but shapes differ in
    # absolute scale by orders of magnitude — unweighted lstsq spends
    # the whole loss budget on the slowest shape's offset and misranks
    # the fast ones.  Normalizing makes every shape's ranking worth the
    # same loss; the weights keep latency units at the average scale.
    key = lambda r: (r["pages"], r["page_size"], r["hkv"], r["batch"])
    by_shape = {}
    for r in rows:
        by_shape.setdefault(key(r), []).append(float(r["us_per_call"]))
    scale = np.asarray([max(np.mean(by_shape[key(r)]), 1e-12)
                        for r in rows], np.float64)
    Xn, yn = X / scale[:, None], y / scale
    w = _nonneg_lstsq(Xn, yn)
    pred = Xn @ w
    # residual in the normalized space the fit optimizes: 1 - R^2 over
    # relative-latency targets, i.e. how much of the *ranking-relevant*
    # variance the basis explains
    denom = float(np.sum((yn - yn.mean()) ** 2)) or 1.0
    residual = float(np.sum((yn - pred) ** 2) / denom)
    return LatencyEstimator(weights=tuple(float(v) for v in w),
                            source="fit", n_rows=len(rows),
                            residual=residual)


# ---------------------------------------------------------------------------
# artifact plumbing


def _artifact_path(path=None) -> Path:
    if path is not None:
        return Path(path)
    env = os.environ.get("REPRO_AUTOTUNE")
    return Path(env) if env else _REPO_ROOT / ARTIFACT_NAME


def save_artifact(est: LatencyEstimator, path=None) -> Path:
    """Commit the fit next to the BENCH_*.json trajectory it came from."""
    p = _artifact_path(path)
    p.write_text(json.dumps(est.to_json(), indent=1, sort_keys=True)
                 + "\n")
    return p


def load_artifact(path=None) -> Optional[LatencyEstimator]:
    p = _artifact_path(path)
    if not p.exists():
        return None
    est = LatencyEstimator.from_json(json.loads(p.read_text()))
    return dataclasses.replace(est, source="artifact")


def load_estimator(mode: str, path=None) -> LatencyEstimator:
    """The estimator a given ``--autotune`` mode runs with.

    ``fitted`` loads the committed artifact, falling back to fitting
    ``BENCH_calibrate.json`` rows in place, falling back to the
    analytic weights (zero-data fallback — ``source`` says which one
    actually happened).  ``analytic`` (and ``off``, for callers that
    want the default display) is always the hand-set weights.
    """
    if mode == "fitted":
        est = load_artifact(path)
        if est is not None:
            return est
        bench = _REPO_ROOT / "BENCH_calibrate.json"
        if bench.exists():
            try:
                return fit_rows(json.loads(bench.read_text()))
            except (ValueError, KeyError):
                pass
        return dataclasses.replace(analytic_estimator(),
                                   source="analytic-fallback")
    return analytic_estimator()


def install(est: LatencyEstimator) -> dict:
    """Install the fit into ``choose_kv_split``'s global constants.

    This rewires every *legacy* auto-split decision (direct kernel
    calls, engines running ``autotune="off"``) to the fitted ranking;
    engines in ``analytic``/``fitted`` mode resolve through the
    estimator directly and do not need it.  Returns the constants now
    in effect; ``install(analytic_estimator())`` restores the defaults.
    """
    from ..kernels.flash_attention import set_cost_constants
    c = est.cost_constants()
    if est.source == "analytic":
        return set_cost_constants()
    return set_cost_constants(tile_cost=c["tile_cost"],
                              combine_cost=c["combine_cost"])


# ---------------------------------------------------------------------------
# the resolver


def _pow2_upto(n: int) -> List[int]:
    out, v = [], 1
    while v <= n:
        out.append(v)
        v *= 2
    return out


def kv_candidates(shape: WorkloadShape) -> List[tuple]:
    """The (pages_per_step, kv_split) grid the resolver scores.

    Tiles are powers of two up to the ~128-row MXU operand (taller
    tiles buy nothing per systolic pass — the same cap
    ``auto_pages_per_step`` applies), splits are powers of two up to
    the tile count, subject to the occupancy guard: a split is
    admissible while its *predecessor* leaves lanes unsaturated (the
    boundary candidate is in, exactly as the fixed ``choose_kv_split``
    costs it).  ``target_lanes`` stays the analytic constant — lane
    capacity is a hardware property, fit it from a TPU run, not a CPU
    sweep (ROADMAP follow-on).
    """
    from ..kernels.flash_attention import get_cost_constants
    target = get_cost_constants()["target_lanes"]
    cap = max(1, min(128 // max(1, shape.page_size), shape.pages))
    # tallest tiles first: at equal predicted cost the resolver keeps
    # the first candidate scanned, and the legacy default is the
    # MXU-target tile — tie behaviour must match it
    t_grid = sorted(set(_pow2_upto(cap) + [cap]), reverse=True)
    lanes = max(1, shape.batch) * max(1, shape.hkv)
    out = []
    for t in t_grid:
        tiles = -(-shape.pages // t)
        for split in _pow2_upto(tiles):
            out.append((t, split))      # boundary candidate included
            if split > 1 and lanes * (split // 2) >= target:
                break                   # deeper splits: saturated
    return out


def _resolve_kv(shape: WorkloadShape, est: LatencyEstimator) -> tuple:
    best, best_cost = (1, 1), None
    for t, split in kv_candidates(shape):
        cost = est.predict(shape.pages, shape.page_size, shape.hkv,
                           shape.batch, split, t)
        if best_cost is None or cost < best_cost - 1e-12:
            best, best_cost = (t, split), cost
    return best


def _resolve_decode_block(gen_len: int) -> int:
    """Amortize the host↔device round trip against tail waste and
    scheduler granularity: a request generating G tokens pays
    ``ceil(G/n)`` dispatches of ``n`` steps each, plus a per-step
    responsiveness penalty growing with n."""
    g = max(1, int(gen_len))
    best, best_cost = DECODE_BLOCKS[0], None
    for n in DECODE_BLOCKS:
        blocks = -(-g // n)
        cost = (blocks * (_DISPATCH_COST + n * _STEP_COST)
                + n * _SWEEP_COST) / g
        if best_cost is None or cost < best_cost - 1e-12:
            best, best_cost = n, cost
    return min(best, max(1, g))


def rank_spec_k(p: float, k_max: int, *, draft_cost: float = _DRAFT_COST,
                verify_cost: float = _VERIFY_COST) -> int:
    """Best ``spec_k`` for per-draft acceptance probability ``p``.

    A round with k drafts commits ``1 + sum_{i=1..k} p^i`` expected
    tokens (the verifier always advances one token even on total
    rejection) and costs one verify pass plus k draft steps; rank k by
    expected committed tokens per unit cost.  Deterministic argmax with
    ties to the smaller k (fewer wasted drafts at equal throughput).
    """
    p = min(max(float(p), 0.0), 0.999)
    best, best_score = 1, None
    for k in range(1, max(1, int(k_max)) + 1):
        committed = 1.0 + sum(p ** i for i in range(1, k + 1))
        score = committed / (verify_cost + k * draft_cost)
        if best_score is None or score > best_score + 1e-12:
            best, best_score = k, score
    return best


def resolve(shape: WorkloadShape,
            est: Optional[LatencyEstimator] = None) -> KnobVector:
    """Resolve the whole knob vector for one workload shape.

    Deterministic per (shape, estimator weights): the grids are fixed,
    ties break to the first candidate in a sorted scan.  Explicit
    engine kwargs always override individual components — the resolver
    only fills what the caller left on "auto".
    """
    est = est or analytic_estimator()
    if shape.pages > 0:
        t, split = _resolve_kv(shape, est)
    else:
        t = split = 1                    # dense cache: no kv knobs
    return KnobVector(kv_split=split, pages_per_step=t,
                      decode_block=_resolve_decode_block(shape.gen_len),
                      spec_k=rank_spec_k(_ACCEPT_PRIOR, 8))


# ---------------------------------------------------------------------------
# online spec_k adaptation


def _invert_acceptance(a_bar: float, k: int) -> float:
    """Per-draft acceptance p from mean accepted drafts per round.

    ``E[accepted | k drafts] = sum_{i=1..k} p^i`` (a draft is accepted
    iff every draft before it was) — monotone in p, inverted by
    bisection.  Clamped to [0, 0.999]: observing k/k accepted means
    "p as high as this window can measure", not p = 1.
    """
    k = max(1, int(k))
    a_bar = float(a_bar)
    if a_bar <= 0.0:
        return 0.0
    if a_bar >= k - 1e-9:
        return 0.999
    lo, hi = 0.0, 0.999
    for _ in range(40):
        mid = (lo + hi) / 2.0
        if sum(mid ** i for i in range(1, k + 1)) < a_bar:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2.0


class SpecKAdapter:
    """Acceptance-adaptive ``spec_k`` with hysteresis and cooldown.

    The engine feeds it the per-block acceptance telemetry it already
    keeps (``verify_steps``/``draft_accepted`` deltas); ``propose``
    re-ranks k over a sliding window of recent rounds and switches only
    when the projected throughput gain clears the hysteresis band, at
    most once per cooldown — every switch re-traces the fused spec
    loop, so the bound on distinct proposed k values IS the bound on
    re-jits.  ``k_max`` must not exceed the engine's construction-time
    ``spec_k``: the KV margin and drafting history are sized for it.
    """

    def __init__(self, k_init: int, *, k_min: int = 1,
                 k_max: Optional[int] = None, window: int = 64,
                 min_rounds: int = 16, hysteresis: float = 0.10,
                 cooldown: int = 4, draft_cost: float = _DRAFT_COST,
                 verify_cost: float = _VERIFY_COST):
        self.k = max(1, int(k_init))
        self.k_min = max(1, int(k_min))
        self.k_max = max(self.k_min, int(k_max if k_max is not None
                                         else k_init))
        self.window = max(1, int(window))
        self.min_rounds = max(1, int(min_rounds))
        self.hysteresis = float(hysteresis)
        self.cooldown = max(1, int(cooldown))
        self.draft_cost = float(draft_cost)
        self.verify_cost = float(verify_cost)
        #: (rounds, accepted, k) per observed block, newest last
        self._obs: List[tuple] = []
        self._blocks_since_switch = self.cooldown    # free first switch
        self.switches = 0

    def observe(self, rounds: int, accepted: int) -> None:
        """Record one decode block's verify telemetry (deltas, not
        cumulative counters)."""
        if rounds > 0:
            self._obs.append((int(rounds), int(accepted), self.k))
            total = sum(r for r, _, _ in self._obs)
            while self._obs and total - self._obs[0][0] >= self.window:
                total -= self._obs[0][0]
                self._obs.pop(0)
        self._blocks_since_switch += 1

    def acceptance(self) -> Optional[float]:
        """Windowed per-draft acceptance probability (None = no data)."""
        rounds = sum(r for r, _, _ in self._obs)
        if rounds < self.min_rounds:
            return None
        # rounds may span different k values right after a switch;
        # invert each segment at its own k and round-weight the result
        num = den = 0.0
        for r, a, k in self._obs:
            num += r * _invert_acceptance(a / r, k)
            den += r
        return num / den

    def _score(self, k: int, p: float) -> float:
        committed = 1.0 + sum(p ** i for i in range(1, k + 1))
        return committed / (self.verify_cost + k * self.draft_cost)

    def propose(self) -> int:
        """Current best k (== current k unless a switch is warranted)."""
        p = self.acceptance()
        if p is None or self._blocks_since_switch < self.cooldown:
            return self.k
        best = self.k
        best_score = self._score(self.k, p)
        for k in range(self.k_min, self.k_max + 1):
            s = self._score(k, p)
            if s > best_score * (1.0 + self.hysteresis):
                best, best_score = k, s
        if best != self.k:
            self.k = best
            self.switches += 1
            self._blocks_since_switch = 0
        return self.k
