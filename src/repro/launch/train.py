"""Training entrypoint: config → mesh → data → resilient loop.

Usage (CPU-scale example; the same driver runs on a real pod by picking a
different mesh)::

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --smoke --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Features exercised: sharded state (FSDP×TP), microbatched gradient
accumulation, deterministic data replay, async checkpoints, fault
injection + restore, straggler monitoring, quantization context flags
(--quant fake --lut).
"""

from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config
from ..core.qtypes import FixedPointType
from ..core.precision import LayerPrecision, PrecisionPolicy
from ..data.pipeline import make_batch
from ..dist.constrain import use_mesh
from ..dist.sharding import batch_specs, named, param_specs
from ..ft import FaultInjector, ResilientLoop, StragglerMonitor
from ..nn.context import QuantContext
from ..optim import cosine_warmup
from ..train.step import build_train_step, init_state
from .mesh import make_local_mesh


def build_ctx(args) -> QuantContext:
    policy = PrecisionPolicy()
    if args.quant != "none":
        qt = FixedPointType(args.qbits, max(args.qbits // 2, 2))
        policy = PrecisionPolicy.uniform(qt)
    return QuantContext(mode=args.quant, policy=policy, use_lut=args.lut,
                        compute_dtype=jnp.float32 if args.f32 else jnp.bfloat16,
                        reuse_factor=args.reuse_factor)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quant", default="none",
                    choices=["none", "fake", "int8"])
    ap.add_argument("--qbits", type=int, default=8)
    ap.add_argument("--lut", action="store_true")
    ap.add_argument("--f32", action="store_true")
    ap.add_argument("--reuse-factor", type=int, default=1)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject faults at these steps (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    ctx = build_ctx(args)
    mesh = make_local_mesh(model=args.model_parallel)

    step_fn = build_train_step(
        cfg, ctx,
        lr_fn=lambda s: cosine_warmup(s, peak=args.lr,
                                      warmup=max(args.steps // 20, 1),
                                      total=args.steps),
        microbatches=args.microbatches)

    with use_mesh(mesh):
        state = init_state(jax.random.PRNGKey(args.seed), cfg)
        st_sh = named(param_specs(state, mesh), mesh)
        state = jax.device_put(state, st_sh)
        rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

        def batch_fn(step):
            b = make_batch(cfg, step, args.batch, args.seq, seed=args.seed)
            b_sh = named(batch_specs(b, mesh), mesh)
            return jax.device_put(b, b_sh)

        b0 = batch_fn(0)
        b_sh = named(batch_specs(b0, mesh), mesh)
        jstep = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                        out_shardings=(st_sh, rep), donate_argnums=(0,))

        manager = CheckpointManager(args.ckpt_dir, keep=3)
        restored, ckpt_step = manager.restore_latest(
            jax.tree_util.tree_map(np.asarray, state), shardings=st_sh)
        start = 0
        if restored is not None:
            state, start = restored, ckpt_step
            print(f"resumed from checkpoint step {start}")

        loop = ResilientLoop(
            jstep, batch_fn, manager, checkpoint_every=args.ckpt_every,
            fault_injector=FaultInjector(args.fail_at) if args.fail_at else None,
            straggler=StragglerMonitor())
        out = loop.run(state, start_step=start, num_steps=args.steps,
                       shardings=st_sh, log_every=args.log_every)
        print(f"done: step={out['step']} loss={float(out['metrics']['loss']):.4f} "
              f"restores={out['restores']}")
    return out


if __name__ == "__main__":
    main()
