"""Mesh construction (functions only — importing this module never touches
jax device state; the dry-run driver sets the host-device count before any
jax initialization)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "dp_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi-pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """(data, model) mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
