"""Serving entrypoint: batched chunked prefill + decode with continuous
batching.

The paper's deployment scenario — a *quantized inference accelerator* —
realized at framework level, as a fused quantized dense pipeline:

* **Weights are quantized once, at engine construction** — ``--quant
  int8`` runs :func:`repro.core.quantize.ptq_params` over the parameter
  tree before it is device_put, so every serving step consumes
  :class:`~repro.core.qtypes.QTensor` weights directly.  Zero
  ``calibrate_scale``/``round`` ops on weights per token (the hls4ml
  model-conversion contract; only activations are quantized per step).
* **Fused kernel epilogue** — with ``--lut``, linear + bias + LUT
  activation execute as one ``qmatmul`` Pallas launch (see
  :mod:`repro.kernels.qmatmul`), one HBM pass instead of three.
* **Batched chunked prefill** — prompt ingestion runs through
  ``build_prefill_step``: all fresh slots advance together, one
  full-batch model call per ``prefill_chunk`` tokens, i.e.
  O(prompt_len / chunk) steps total instead of O(prompt_len) decode
  steps *per slot*.  Slots mid-generation are untouched: their chunk
  writes land in a reserved cache margin (see ``Engine``) and their
  positions do not advance.
* **Continuous batching** — a finished sequence's slot is refilled by
  the next queued request without draining the batch; freed slots are
  refilled *together* so their prompts share prefill batches too.

Usage (CPU-scale)::

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 16 --quant int8
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.pipeline import SyntheticLM
from ..dist.constrain import use_mesh
from ..dist.sharding import cache_specs, named, param_specs
from ..models.api import get_family
from ..nn.context import QuantContext
from ..train.step import build_prefill_step, build_serve_step
from .mesh import make_local_mesh
from .train import build_ctx


class Engine:
    """Slot-based continuous batching engine over prefill/decode steps.

    Cache layout note: the KV cache is allocated with ``prefill_chunk``
    margin rows beyond ``max_len``.  During a mid-flight refill the
    chunked prefill runs full-batch, so slots that are still generating
    receive (ignored) writes at their current position; the margin
    guarantees those writes can never clamp back into valid rows, and
    the per-slot visibility mask (`kvpos <= qpos`) keeps them invisible
    until decode overwrites them.
    """

    def __init__(self, cfg, ctx, params, mesh, *, batch: int, max_len: int,
                 kv_bits=None, prefill_chunk: int = 16):
        self.cfg, self.ctx, self.mesh = cfg, ctx, mesh
        self.batch, self.max_len = batch, max_len
        self.prefill_chunk = max(1, prefill_chunk)
        # chunked prefill needs per-call cache continuation; only the
        # attention-cache families support that (SSM state is rebuilt
        # from the tokens of one call).
        self.chunked = cfg.family == "lm"
        fam = get_family(cfg)
        self.params = params
        cache_dtype = jnp.int8 if kv_bits == 8 else jnp.float32
        margin = self.prefill_chunk if self.chunked else 0
        self.cache = fam.init_cache(cfg, batch, max_len + margin,
                                    cache_dtype)
        c_sh = named(cache_specs(self.cache, mesh), mesh)
        self.cache = jax.device_put(self.cache, c_sh)
        self.decode = jax.jit(build_serve_step(cfg, ctx))
        self.prefill = jax.jit(build_prefill_step(cfg, ctx))
        # donated so XLA updates the cache in place — invalidating a slot
        # on finish() must not copy the whole KV cache per request
        self._invalidate = jax.jit(
            lambda cache, slot: jax.tree_util.tree_map(
                lambda c: c.at[:, slot].set(0), cache),
            donate_argnums=(0,))
        self.pos = np.zeros((batch,), np.int32)
        self.live = np.zeros((batch,), bool)
        self.tokens = np.zeros((batch, 1), np.int32)
        self.outputs: List[Optional[list]] = [None] * batch
        self.done: List[list] = []

    # -- request admission --------------------------------------------------
    def add_request(self, slot: int, prompt: np.ndarray):
        """Prefill one request into ``slot``."""
        self.add_requests({slot: prompt})

    def add_requests(self, requests: Dict[int, np.ndarray]):
        """Prefill several fresh slots together (batched chunked prefill).

        Prompts are ingested in full-batch chunks of ``prefill_chunk``
        tokens — O(max_prompt_len / chunk) model calls for the whole
        group.  An empty prompt is treated as a single pad/BOS token
        (id 0) so the first generated token is always defined.
        """
        reqs = {int(s): np.asarray(p, np.int32).reshape(-1)
                for s, p in requests.items()}
        for s, p in reqs.items():
            if p.size == 0:
                reqs[s] = np.zeros((1,), np.int32)
        if not reqs:
            return
        if self.chunked:
            first = self._prefill_chunked(reqs)
        else:
            first = self._prefill_looped(reqs)
        for s, p in reqs.items():
            self.pos[s] = p.shape[0]
            self.live[s] = True
            self.outputs[s] = []
            self.tokens[s, 0] = first[s]

    def _prefill_chunked(self, reqs) -> Dict[int, int]:
        chunk = self.prefill_chunk
        plen = max(p.shape[0] for p in reqs.values())
        padded = -(-plen // chunk) * chunk      # one compile per chunk width
        toks = np.zeros((self.batch, padded), np.int32)
        for s, p in reqs.items():
            toks[s, :p.shape[0]] = p
        fresh = np.fromiter(sorted(reqs), np.int64)
        first: Dict[int, int] = {}
        for c0 in range(0, padded, chunk):
            if c0 >= plen:
                break
            # live slots keep their own position: their (ignored) writes
            # land at [pos, pos+chunk) inside the margin, never clamped.
            cur = self.pos.copy()
            cur[fresh] = c0
            logits, self.cache = self.prefill(
                self.params, {"tokens": jnp.array(toks[:, c0:c0 + chunk])},
                self.cache, jnp.array(cur))
            logits = np.asarray(logits)
            for s, p in reqs.items():
                t_last = p.shape[0] - 1
                if c0 <= t_last < c0 + chunk:
                    first[s] = int(np.argmax(logits[s, t_last - c0]))
        return first

    def _prefill_looped(self, reqs) -> Dict[int, int]:
        """Per-token fallback for families without chunkable prefill."""
        first: Dict[int, int] = {}
        for s, p in reqs.items():
            logits = None
            for t in range(p.shape[0]):
                tok = np.zeros((self.batch, 1), np.int32)
                tok[s, 0] = p[t]
                logits, self.cache = self.decode(
                    self.params, self.cache, jnp.array(tok),
                    jnp.array(self.pos))
                self.pos[s] += 1
            first[s] = int(jnp.argmax(logits[s, -1]))
            # keep pos at prompt length: later slots' loops must not write
            # into this slot's freshly-filled rows (add_requests re-asserts
            # the same value afterwards)
        return first

    # -- decode / retire -----------------------------------------------------
    # NOTE: engine state crosses the jit boundary via ``jnp.array`` (an
    # explicit copy), never ``jnp.asarray``: on CPU, asarray may zero-copy
    # an aligned numpy buffer, and self.pos/self.tokens are mutated in
    # place right after the async dispatch — an alias would race with the
    # still-running computation.
    def step(self):
        logits, self.cache = self.decode(
            self.params, self.cache, jnp.array(self.tokens),
            jnp.array(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for s in range(self.batch):
            if self.live[s]:
                self.outputs[s].append(int(self.tokens[s, 0]))
                self.tokens[s, 0] = nxt[s]
                self.pos[s] += 1

    def finish(self, slot: int):
        self.done.append(self.outputs[slot])
        self.outputs[slot] = None
        self.live[slot] = False
        self.pos[slot] = 0
        if self.chunked:
            # invalidate the retired request's KV rows so a recycled slot
            # can never attend to a previous occupant's cache (defense in
            # depth on top of the visibility mask; in-place via donation).
            self.cache = self._invalidate(self.cache,
                                          jnp.int32(slot))


def quantize_for_serving(params, ctx: QuantContext):
    """PTQ the parameter tree once, at engine construction.

    Weight matrices become QTensor (per-out-channel scales) per the
    context's precision policy; ``linear()`` then consumes them with
    zero per-forward weight-quantization work.
    """
    from ..core.quantize import ptq_params
    return ptq_params(params, ctx.policy)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--quant", default="none",
                    choices=["none", "fake", "int8"])
    ap.add_argument("--qbits", type=int, default=8)
    ap.add_argument("--lut", action="store_true")
    ap.add_argument("--f32", action="store_true")
    ap.add_argument("--reuse-factor", type=int, default=1)
    ap.add_argument("--kv-bits", type=int, default=None, choices=[8],
                    help="int8 KV cache (per-token scales)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="tokens per batched prefill step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    ctx = build_ctx(args)
    mesh = make_local_mesh(model=args.model_parallel)
    fam = get_family(cfg)

    with use_mesh(mesh):
        params = fam.init(jax.random.PRNGKey(args.seed), cfg)
        if args.quant == "int8":
            # the fused pipeline's first leg: weights quantized ONCE here
            params = quantize_for_serving(params, ctx)
        p_sh = named(param_specs(params, mesh), mesh)
        params = jax.device_put(params, p_sh)
        max_len = args.prompt_len + args.gen_len + 1
        eng = Engine(cfg, ctx, params, mesh, batch=args.batch,
                     max_len=max_len, kv_bits=args.kv_bits,
                     prefill_chunk=args.prefill_chunk)

        src = SyntheticLM(cfg.vocab, seed=args.seed)
        prompts = [src.tokens(i, 1, args.prompt_len)[0, :-1]
                   for i in range(args.requests)]
        queue = list(range(args.requests))
        t0 = time.perf_counter()
        gen_tokens = 0
        # continuous batching: fill all slots at once (their prompts share
        # prefill batches), refill freed slots together as they finish
        eng.add_requests({s: prompts[queue.pop(0)]
                          for s in range(min(args.batch, len(queue)))})
        while eng.live.any():
            eng.step()
            gen_tokens += int(eng.live.sum())
            refills = {}
            for s in range(args.batch):
                if eng.live[s] and len(eng.outputs[s]) >= args.gen_len:
                    eng.finish(s)
                    if queue:
                        refills[s] = prompts[queue.pop(0)]
            if refills:
                eng.add_requests(refills)
        dt = time.perf_counter() - t0
        print(f"served {len(eng.done)} requests, {gen_tokens} tokens in "
              f"{dt:.2f}s ({gen_tokens / dt:.1f} tok/s), "
              f"quant={args.quant} lut={args.lut} kv_bits={args.kv_bits}")
    return eng.done


if __name__ == "__main__":
    main()
