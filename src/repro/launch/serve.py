"""Serving entrypoint: batched chunked prefill + device-resident decode
with continuous batching.

The paper's deployment scenario — a *quantized inference accelerator* —
realized at framework level, as a fused quantized dense pipeline:

* **Weights are quantized once, at engine construction** — ``--quant
  int8`` runs :func:`repro.core.quantize.ptq_params` over the parameter
  tree before it is device_put, so every serving step consumes
  :class:`~repro.core.qtypes.QTensor` weights directly.  Zero
  ``calibrate_scale``/``round`` ops on weights per token (the hls4ml
  model-conversion contract; only activations are quantized per step).
* **Fused kernel epilogue** — with ``--lut``, linear + bias + LUT
  activation execute as one ``qmatmul`` Pallas launch (see
  :mod:`repro.kernels.qmatmul`), one HBM pass instead of three.
* **Batched chunked prefill** — prompt ingestion runs through
  ``build_prefill_step``: all fresh slots advance together, one
  full-batch model call per ``prefill_chunk`` tokens, i.e.
  O(prompt_len / chunk) steps total instead of O(prompt_len) decode
  steps *per slot*.  Slots mid-generation are untouched: their chunk
  writes land in a reserved cache margin (see ``Engine``) and their
  positions do not advance.
* **Device-resident decode loop** — generation runs through
  ``build_decode_loop``: ``step_many(n)`` executes n decode steps inside
  ONE ``lax.scan`` jit call — model step, per-slot sampling (greedy /
  temperature / top-k, see :mod:`repro.kernels.sampling`), per-slot
  position advance, and EOS/length stopping all stay on device.  The
  host syncs once per n-token block (to retire finished slots and refill
  them) instead of once per token: 1/n jit dispatches and host round
  trips per generated token vs ``step()``.
* **Continuous batching** — a finished sequence's slot is refilled by
  the next queued request without draining the batch; freed slots are
  refilled *together* so their prompts share prefill batches too.
* **Paged KV cache** (``--paged``) — the de-specialization step applied
  to serving memory: instead of every slot owning a dense ``max_len``
  KV allocation, K/V rows live in a shared pool of fixed-size pages
  (``--page-size`` tokens each, ``--num-pages`` total) and each request
  holds exactly the pages its token budget needs, addressed through a
  per-slot block table.  Admission is metered by *used* tokens, not
  worst-case ones: ``submit()`` queues a request, and ``step_many``
  admits waiting requests the moment a freed lane plus freed pages
  cover them — ``finish()`` returns pages to the free list in O(pages)
  (a block-table edit) instead of zeroing ``max_len`` cache rows.
  Dense mode still wins at tiny batches (no gather/table indirection,
  one request never fragments); paged mode wins the moment mixed-length
  traffic leaves dense slots half empty.
* **Split-KV flash decoding** (``--kv-split`` / ``--pages-per-step``) —
  the reuse-factor knob applied to the last serial hot path: on the
  kernel path each slot's page chain is cut into ``kv_split`` parallel
  online-softmax partitions (merged by a log-sum-exp combine) and each
  grid step DMAs a ``pages_per_step``-page tile, double-buffered —
  long-context decode latency stops scaling with the page chain.
  ``auto`` (default) picks both from a cached rule4ml-style cost model
  (:func:`repro.kernels.flash_attention.choose_kv_split`); the resolved
  pair is reported in ``Engine.stats()``.  ``--kv-split 1
  --pages-per-step 1`` is byte-identical to the pre-split kernel.
* **Speculative decoding** (``--spec``) — the draft→verify pipeline on
  top of the de-specialized attention path: a drafter proposes
  ``--spec-k`` tokens per live slot (prompt-lookup self-speculation by
  default; ``--spec-draft <arch>`` drafts with a second model) and the
  target model verifies ALL of them with one forward pass —
  verification is just a k+1-token chunked-prefill call, dense einsum
  or ``paged_attention``, the same op either way.  Acceptance runs
  device-resident (:func:`repro.kernels.ops.verify_tokens` inside the
  fused scan): greedy streams are byte-identical to the
  non-speculative engine, sampled streams keep their exact
  temperature/top-k distribution via point-mass rejection sampling.
  Rewind on rejection is a scalar ``pos`` edit for KV families (pages
  were allocated for the full budget at admission — allocator and
  block tables untouched); recurrent families checkpoint-and-restore
  their state per block position (see ``models.api.spec_state_fn``).
  The speculation depth ``k`` is the serving-side reuse factor:
  deeper speculation = fewer target passes on predictable streams,
  more wasted verify positions on incompressible ones.

Usage (CPU-scale)::

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 16 \
        --quant int8 --decode-block 8 --paged --page-size 16 --spec
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import os
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.pipeline import SyntheticLM
from ..dist.constrain import use_mesh
from ..dist.sharding import cache_specs, named, param_specs
from ..ft import StragglerMonitor
from ..models.api import (copy_pages_fn, get_family, init_paged_cache_fn,
                          invalidate_fn, merge_slot_fn, set_block_table,
                          spec_restore_fn, spec_state_fn,
                          supports_chunked_prefill)
from ..nn.context import QuantContext
from ..train.step import (build_decode_loop, build_prefill_step,
                          build_serve_step, build_spec_decode_loop)
from .lifecycle import (PriorityClass, RequestStatus, coerce_priority,
                        normalize_class_quotas, normalize_slo_targets,
                        request_row, validate_request)
from .lifecycle import now as _now
from .mesh import make_local_mesh
from .paging import PageAllocator
from .prefix import PREFIX_OWNER, ROOT, PrefixIndex
from .train import build_ctx


def _snap(a: np.ndarray) -> jnp.ndarray:
    """Host→device snapshot of engine-mutable numpy state.

    The engine mutates ``pos``/``tokens``/``live`` in place right after
    dispatching a step.  Handing the numpy buffer itself to jax races
    the *asynchronous* host copy — ``jnp.array``'s copy=True is not a
    synchronous defensive copy on the CPU backend, so under load the
    transfer can read the buffer AFTER the host mutated it (observed:
    the per-token prefill loop nondeterministically produced garbage
    first tokens).  A fresh ``.copy()`` that nothing ever mutates is
    safe regardless of whether jax aliases or copies it.
    """
    return jnp.asarray(a.copy())


class DeviceFault(RuntimeError):
    """The fused block's fault lane flagged slots (non-finite logits on
    device — poisoned cache, kernel NaN).  Raised inside ``step_many``
    so the recovery loop can restore-and-replay; without a recovery
    path the flagged slots are failed with their valid prefix."""

    def __init__(self, slots):
        slots = tuple(int(s) for s in slots)
        super().__init__(f"device fault lane flagged slots {list(slots)}")
        self.slots = slots


def _copy_record(r: dict) -> dict:
    """Queue-record copy for snapshots: the mutable ``outputs`` list is
    deep-copied; spilled page payloads / recurrent lanes are immutable
    after the spill and ride by reference."""
    r2 = dict(r)
    if r2.get("outputs"):
        r2["outputs"] = list(r2["outputs"])
    return r2


class Engine:
    """Slot-based continuous batching engine over prefill/decode steps.

    Decoding is device-resident: ``step_many(n)`` runs n fused decode
    steps (one jit call, one host sync); ``step()`` is the n=1 special
    case, kept as the per-token baseline.  Per-slot sampling parameters
    (``temperature``/``top_k``), generation budgets (``stop_pos``) and
    the EOS id live in the engine and are threaded through the loop, so
    greedy and sampled requests share one batch.

    Cache layout note: the KV cache is allocated with ``prefill_chunk``
    margin rows beyond ``max_len``.  During a mid-flight refill the
    chunked prefill runs full-batch, so slots that are still generating
    receive (ignored) writes at their current position; the margin
    guarantees those writes can never clamp back into valid rows, and
    the per-slot visibility mask (`kvpos <= qpos`) keeps them invisible
    until decode overwrites them.
    """

    def __init__(self, cfg, ctx, params, mesh, *, batch: int, max_len: int,
                 kv_bits=None, prefill_chunk: int = 16, eos_id: int = -1,
                 seed: int = 0, paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None, kv_split="auto",
                 pages_per_step="auto", prefix_cache: bool = False,
                 autotune: str = "off", spec: bool = False,
                 spec_k: int = 4, spec_draft=None, spec_ngram: int = 2,
                 drafter_fn=None, preempt: bool = False,
                 preempt_after: int = 2, shed_threshold=None,
                 slo_targets=None, class_quotas=None, fault_injector=None,
                 recover=None, max_replays: int = 8, straggler=None,
                 clock=None, durable_dir=None, snapshot_every: int = 8):
        self.cfg, self.ctx, self.mesh = cfg, ctx, mesh
        self.batch, self.max_len = batch, max_len
        self.prefill_chunk = max(1, prefill_chunk)
        # chunked prefill needs per-call cache continuation; only the
        # attention-cache families support that (SSM state is rebuilt
        # from the tokens of one call).
        self.chunked = supports_chunked_prefill(cfg)
        fam = get_family(cfg)
        self.params = params
        cache_dtype = jnp.int8 if kv_bits == 8 else jnp.float32
        margin = self.prefill_chunk if self.chunked else 0
        # speculative decoding: the verification block writes k+1 KV
        # rows starting at a (possibly held, up to max_len) position, so
        # the margin must absorb spec_k + 1 rows beyond the cache bound
        # exactly as it absorbs chunked-prefill overshoot
        self.spec, self.spec_k = bool(spec), max(1, int(spec_k))
        self.spec_ngram = max(1, int(spec_ngram))
        # -- unified autotuner (rule4ml for the engine) ----------------
        # "off" is the legacy path bit for bit: explicit kwarg > ctx >
        # the analytic cost model, no decode-block resolution, no
        # online spec_k adaptation.  "analytic"/"fitted" resolve the
        # whole knob vector through launch/autotune.py — same grid,
        # hand-set vs least-squares-fitted weights — and adapt spec_k
        # from measured acceptance.  Knobs the caller pins explicitly
        # always win over the resolver.
        self.autotune = str(autotune)
        if self.autotune not in ("off", "analytic", "fitted"):
            raise ValueError(
                f"autotune={autotune!r}: expected 'off' (legacy "
                f"defaults), 'analytic' (resolver on hand-set "
                f"constants) or 'fitted' (resolver on measured fit)")
        self._autotune_est = None
        self._spec_adapter = None
        self._spec_k_init = self.spec_k
        self._last_spec_obs = (0, 0)
        self.decode_block: Optional[int] = None
        if self.autotune != "off":
            from .autotune import (SpecKAdapter, WorkloadShape,
                                   load_estimator, resolve)
            self._autotune_est = load_estimator(self.autotune)
            self._autotune_resolve = resolve
            self._autotune_shape_cls = WorkloadShape
            if self.spec:
                # adapt within [1, construction spec_k]: the KV margin
                # and drafting history are sized for the initial k, so
                # it is the cap — pass a generous --spec-k and let the
                # adapter find the efficient depth under it
                self._spec_adapter = SpecKAdapter(k_init=self.spec_k,
                                                  k_max=self.spec_k)
        self.drafter_fn = drafter_fn            # test hook (custom drafts)
        if not self.spec and (spec_draft is not None
                              or drafter_fn is not None):
            raise ValueError(
                "spec_draft/drafter_fn were given but spec=False — a "
                "drafter without speculation would silently never run; "
                "pass spec=True")
        if self.spec:
            margin = max(margin, self.spec_k + 2)
        self.paged = bool(paged)
        if class_quotas and not paged:
            raise ValueError(
                "class_quotas need the paged cache: quotas partition the "
                "page pool, and dense slots have no pool to partition")
        if self.paged:
            ps = max(1, int(page_size))
            if num_pages is None:
                # dense-equivalent HBM budget by default; the win comes
                # from passing a smaller pool (or a bigger batch)
                num_pages = -(-(batch * max_len) // ps)
            self.allocator = PageAllocator(num_pages, ps,
                                           class_quotas=class_quotas)
            self._trash = num_pages          # reserved garbage page id
            # table width covers every reachable write position: decode
            # holds a dead lane at pos <= max_len, chunked prefill's
            # margin writes reach max_len + margin - 1
            width = -(-(max_len + max(margin, 1)) // ps)
            self.block_tables = np.full((batch, width), self._trash,
                                        np.int32)
            self._slot_pages: Dict[int, List[int]] = {}
            #: host table edited but not yet written into the cache —
            #: finish() defers the device write so a retire sweep costs
            #: ONE table upload, flushed by the next consumer
            self._bt_dirty = False
            self.cache = init_paged_cache_fn(cfg, batch, num_pages, ps,
                                             width, cache_dtype)
        else:
            self.cache = fam.init_cache(cfg, batch, max_len + margin,
                                        cache_dtype)
        # -- prefix caching (the reuse-factor move on cache CONTENTS):
        # committed, page-aligned prompt pages are published to a hash
        # index and mapped read-only into later requests that share the
        # prefix — admission of a hit allocates only the suffix's pages
        # and prefills only the suffix's tokens.  Needs the page pool
        # (sharing is block-table indirection) and chunkable prefill
        # (a recurrent family's state is sequential: nothing can be
        # skipped), so the flag is accepted everywhere but inert for
        # ssm/hybrid — one engine API, no per-family forks.
        self.prefix_cache = False
        if prefix_cache:
            if not self.paged:
                raise ValueError(
                    "prefix_cache=True needs the paged cache: prefix "
                    "reuse IS page sharing (dense lanes have no pages "
                    "to share)")
            self.prefix_cache = self.chunked
        if self.prefix_cache:
            self.prefix_index = PrefixIndex(self.allocator.page_size)
            #: slot -> index pages mapped read-only into its table
            #: (the slot holds one refcount on each; table layout is
            #: shared entries first, then the slot's private pages)
            self._slot_shared: Dict[int, List[int]] = {}
            #: slot -> (chunks published/matched so far, chain key of
            #: the last one) — where _publish_committed resumes
            self._pub: Dict[int, tuple] = {}
            # donated like _invalidate: a CoW copy edits pages in place,
            # it must not materialize a second full pool
            self._copy_page = jax.jit(copy_pages_fn, donate_argnums=(0,))
        # split-KV reuse-factor knob: resolve once per cache geometry
        # (explicit engine kwarg > ctx setting > cached cost model) and
        # thread through the context so the fused decode loop AND the
        # speculative verify pass hand the same split to the kernel.
        # On non-TPU hosts the paged model path is gather+einsum, so
        # the knob is telemetry-only there — but it is resolved
        # identically so `Engine.stats()` reports what a TPU run of
        # this exact geometry would execute.
        self.kv_split = self.pages_per_step = None
        if self.paged:
            from ..kernels.flash_attention import _resolve_knobs
            width = self.block_tables.shape[1]
            req_t = (int(pages_per_step)
                     if pages_per_step not in (None, "auto")
                     else ctx.pages_per_step)
            req_s = (int(kv_split) if kv_split not in (None, "auto")
                     else ctx.kv_split)
            hkv = getattr(cfg, "n_kv_heads", 0) or getattr(
                cfg, "n_heads", 1)
            if self.autotune != "off":
                # construction-time resolution of the whole knob
                # vector: estimator argmin over the (tile, split) grid
                # fills whatever the caller left on auto; explicit
                # kwargs/ctx pins pass through untouched
                kv = self._autotune_resolve(
                    self._autotune_shape_cls(
                        pages=width, page_size=ps, hkv=max(1, hkv),
                        batch=batch, gen_len=max_len, spec=self.spec),
                    self._autotune_est)
                req_t = kv.pages_per_step if req_t is None else req_t
                req_s = kv.kv_split if req_s is None else req_s
                self.decode_block = kv.decode_block
            t, split = _resolve_knobs(width, ps, max(1, hkv), batch,
                                      req_s, req_t)
            self.kv_split, self.pages_per_step = split, t
            ctx = dataclasses.replace(ctx, kv_split=split,
                                      pages_per_step=t)
            self.ctx = ctx
        if self.autotune != "off" and not self.paged:
            # dense cache: no kv knobs, but block size and spec depth
            # are still the resolver's to pick
            kv = self._autotune_resolve(
                self._autotune_shape_cls(pages=0, page_size=1, hkv=1,
                                         batch=batch, gen_len=max_len,
                                         spec=self.spec),
                self._autotune_est)
            self.decode_block = kv.decode_block
        c_sh = named(cache_specs(self.cache, mesh), mesh)
        self.cache = jax.device_put(self.cache, c_sh)
        #: cache sharding, kept for snapshot restore (the fused loops
        #: donate their cache argument, so restore re-device_puts)
        self._cache_sh = c_sh
        self.decode = jax.jit(build_serve_step(cfg, ctx))
        self.prefill = jax.jit(build_prefill_step(cfg, ctx))
        #: per-block-size cache of jitted fused decode loops
        self._loops: Dict[int, callable] = {}
        #: per-block-size cache of jitted speculative draft→verify loops
        self._spec_loops: Dict[int, callable] = {}
        # -- speculative drafting state --------------------------------
        #: committed-token history per slot (prompt + accepted
        #: generations at their absolute positions) — the prompt-lookup
        #: drafter's corpus; threaded through the spec loop carry
        self.hist = np.zeros((batch, max_len + self.spec_k + 2), np.int32)
        self.draft = None
        if self.spec and spec_draft is not None:
            d_cfg, d_params, d_ctx = spec_draft
            if d_cfg.vocab != cfg.vocab:
                raise ValueError(
                    f"draft model vocab {d_cfg.vocab} != target vocab "
                    f"{cfg.vocab}; drafts would be meaningless")
            self.draft = (d_cfg, d_params, d_ctx or ctx)
            self.draft_chunked = supports_chunked_prefill(d_cfg)
            d_margin = max(self.prefill_chunk if self.draft_chunked else 0,
                           self.spec_k + 2)
            # the drafter's cache is always dense: it holds one model's
            # worth of rows and is rolled back by pos/checkpoints, never
            # paged (paging meters the TARGET's admission, not drafts)
            self.draft_cache = get_family(d_cfg).init_cache(
                d_cfg, batch, max_len + d_margin, jnp.float32)
            self._draft_decode = jax.jit(build_serve_step(d_cfg,
                                                          self.draft[2]))
            self._draft_prefill = jax.jit(build_prefill_step(
                d_cfg, self.draft[2]))
            self._draft_invalidate = jax.jit(
                lambda cache, slot: invalidate_fn(cache, slot, d_cfg),
                donate_argnums=(0,))
            self._draft_merge = jax.jit(
                lambda new, old, slot: merge_slot_fn(new, old, slot, d_cfg),
                donate_argnums=(1,))
        # donated so XLA updates the cache in place — invalidating a slot
        # on finish() must not copy the whole KV cache per request
        self._invalidate = jax.jit(
            lambda cache, slot: invalidate_fn(cache, slot, cfg),
            donate_argnums=(0,))
        # old cache donated: the merge result is old with one lane
        # replaced, so XLA updates it in place
        self._merge = jax.jit(
            lambda new, old, slot: merge_slot_fn(new, old, slot, cfg),
            donate_argnums=(1,))
        self.pos = np.zeros((batch,), np.int32)
        self.live = np.zeros((batch,), bool)
        self.tokens = np.zeros((batch, 1), np.int32)
        #: lanes known zeroed since the last decode touched them — a
        #: fresh engine starts all-clean, finish() re-cleans its slot,
        #: any decode block dirties every lane (decode advances dead
        #: lanes' recurrent state too); admission only invalidates
        #: lanes that are actually dirty (deferred refills), not ones
        #: finish() just zeroed.
        self._clean = np.ones((batch,), bool)
        #: per-slot sampling params; temperature <= 0 = greedy,
        #: top_k <= 0 = unrestricted (see repro.kernels.sampling)
        self.temperature = np.zeros((batch,), np.float32)
        self.top_k = np.zeros((batch,), np.int32)
        #: per-slot position bound: live drops when pos reaches it
        self.stop_pos = np.full((batch,), max_len, np.int32)
        self.eos_id = int(eos_id)
        self._key = jax.random.PRNGKey(seed)
        self._gen_step = 0          # global decode-step counter (PRNG)
        self.outputs: List[Optional[list]] = [None] * batch
        self.done: List[list] = []
        #: FIFO admission queue (see submit/try_admit): requests wait
        #: here until a lane AND (paged) enough free pages exist
        self.waiting: deque = deque()
        #: aggregate serving counters (peak concurrency, admissions,
        #: generated tokens, decode walltime, speculation acceptance);
        #: per-request rows land in ``request_log`` — see :meth:`stats`
        self.counters = {"peak_live": 0, "admitted": 0, "gen_tokens": 0,
                         "decode_s": 0.0, "verify_steps": 0,
                         "draft_accepted": 0, "preemptions": 0,
                         "cancellations": 0, "timeouts": 0, "failures": 0,
                         "replays": 0, "spilled_pages": 0,
                         "shed_spec_rounds": 0, "straggler_blocks": 0,
                         "prefix_hits": 0, "prefix_hit_pages": 0,
                         "prefix_tokens_saved": 0, "cow_copies": 0,
                         "spec_k_rejits": 0, "recoveries": 0}
        #: one dict per retired request: ttft_s, gen_tokens, decode_s
        self.request_log: List[dict] = []
        self._req_meta: Dict[int, dict] = {}    # slot -> live request row
        # -- request-lifecycle robustness layer -------------------------
        self.preempt = bool(preempt)
        if self.preempt and not self.paged:
            raise ValueError(
                "preempt=True needs the paged cache: preempt-and-spill "
                "is a page-pool mechanism (dense slots have nothing to "
                "spill — every lane already owns its max_len rows)")
        if self.preempt and self.draft is not None:
            raise ValueError(
                "preempt=True with a model drafter is unsupported: the "
                "draft cache is a dense lane that cannot be spilled "
                "through the page pool (use ngram self-speculation)")
        self.preempt_after = max(1, int(preempt_after))
        self.shed_threshold = (None if shed_threshold is None
                               else float(shed_threshold))
        # -- SLO priority classes ---------------------------------------
        #: per-class targets driving the shed knobs; when set, pressure
        #: is defined by SLO risk (a class behind its TTFT / tok-per-s
        #: target) instead of the fixed pool-occupancy constant
        self.slo_targets = normalize_slo_targets(slo_targets)
        #: per-class lifecycle counters (admissions, terminal exits,
        #: preemptions, shed rounds, straggler attribution) — the
        #: aggregate ``counters`` keep their engine-wide totals
        self.class_counters = {c: self._fresh_class_row()
                               for c in PriorityClass}
        self.fault_injector = fault_injector
        #: restore-and-replay on block faults; defaults on whenever a
        #: fault injector is attached (chaos runs want recovery)
        self._recover = (bool(recover) if recover is not None
                         else fault_injector is not None)
        self.max_replays = int(max_replays)
        self.straggler = (StragglerMonitor() if straggler is None
                          else straggler)
        self.clock = _now if clock is None else clock
        self._t_start = self.clock()        # uptime_s origin
        #: journal records the hot standby has not applied yet; ``None``
        #: until a fleet heartbeat feeds it (standalone engines have no
        #: standby to lag), like ``decode_tok_per_s`` when unmeasurable
        self.journal_lag_records = None
        #: terminal request outcomes: req_id -> {"status", "tokens"}
        self.results: Dict[int, dict] = {}
        self._next_id = 0
        self._round = 0             # decode-block counter (chaos schedule)
        self._injected_slow = False
        self._slow_penalty = 1.0    # synthetic straggler seconds (CI)
        #: per-class (req id, blocked admission sweeps): each class's
        #: blocked head escalates independently — a REALTIME head's
        #: count must not reset because a BATCH record got admitted
        self._head_blocked: Dict[PriorityClass, tuple] = {}
        # -- durable serving state (crash-safe warm restart) ------------
        # With ``durable_dir`` every externally-driven state transition
        # (submit / direct add / explicit admit / decode block / cancel
        # / finish / retire) is journaled write-ahead through a fsync'd
        # BlobLog, and a full snapshot (cache pages, allocator order,
        # prefix index, queue, journal cursor) lands every
        # ``snapshot_every`` blocks.  ``Engine.recover(directory)``
        # rebuilds a killed engine: restore the newest snapshot, then
        # re-execute the journal tail — deterministic replay, so
        # recovered greedy streams are byte-identical to uninterrupted
        # ones.  Constructing WITH durable_dir starts a NEW run
        # (truncates any previous journal); recovering an old run goes
        # through ``recover`` on an engine built without it.
        self._journal = None
        self._jmute = 0             # >0: nested/replayed calls don't log
        self._durable_dir = None
        if int(snapshot_every) < 0:
            raise ValueError(
                f"snapshot_every must be >= 0 (got {snapshot_every}); "
                f"0 disables periodic snapshots, a negative period has "
                f"no meaning")
        self.snapshot_every = int(snapshot_every)
        self._durable_step = 0
        self._blocks_since_snap = 0
        if durable_dir is not None:
            from ..checkpoint.store import BlobLog
            os.makedirs(durable_dir, exist_ok=True)
            self._durable_dir = str(durable_dir)
            self._journal = BlobLog(os.path.join(durable_dir,
                                                 "journal.log"), fresh=True)

    # -- priority / journal plumbing ----------------------------------------
    @staticmethod
    def _fresh_class_row() -> dict:
        return {"admitted": 0, "completed": 0, "preemptions": 0,
                "cancellations": 0, "timeouts": 0, "failures": 0,
                "shed_rounds": 0, "straggler_blocks": 0}

    def _class_count(self, cls, key: str, n: int = 1) -> None:
        self.class_counters[coerce_priority(cls)][key] += n

    @contextlib.contextmanager
    def _journal_scope(self, *record, ahead: bool = False):
        """Journal one externally-driven transition.

        Appends ``record`` only at the OUTERMOST call — transitions a
        journaled call makes internally (step_many's admission sweep,
        retire's finishes, a replayed event) are consequences of the
        recorded one and re-derive deterministically on replay, so
        logging them too would double-apply.

        ``ahead=True`` (decode blocks) appends write-ahead — the block
        mutates donated device state, so a crash mid-block must find
        the commitment already durable and re-execute it.  The default
        appends on *success*: a call that raised at the validation
        boundary never happened, and replaying it would just re-raise
        into :meth:`recover`."""
        log = self._journal is not None and self._jmute == 0
        if log and ahead:
            self._journal.append(record)
        self._jmute += 1
        try:
            yield
        except BaseException:
            log = False
            raise
        finally:
            self._jmute -= 1
            if log and not ahead:
                self._journal.append(record)

    # -- request admission --------------------------------------------------
    def add_request(self, slot: int, prompt: np.ndarray, **kw):
        """Prefill one request into ``slot``."""
        self.add_requests({slot: prompt}, **kw)

    def add_requests(self, requests: Dict[int, np.ndarray], *,
                     gen_len: Optional[int] = None,
                     temperature=None, top_k=None, deadline_s=None,
                     priority=None, _t_submit=None, _ids=None,
                     _deadlines=None, _prefix=None):
        """Prefill several fresh slots together (batched chunked prefill).

        Prompts are ingested in full-batch chunks of ``prefill_chunk``
        tokens — O(max_prompt_len / chunk) model calls for the whole
        group.  An empty prompt is treated as a single pad/BOS token
        (id 0) so the first generated token is always defined.

        ``gen_len`` bounds generation per admitted request (``stop_pos =
        prompt_len + gen_len``; None = run to the cache bound).
        ``temperature``/``top_k``/``gen_len`` set the admitted slots'
        parameters: a scalar applies to all of them, a ``{slot: value}``
        dict sets them per request.

        A prompt longer than ``max_len`` is rejected (ValueError): the
        cache cannot hold it, and clamp-writing its tail into the last
        rows would silently serve a truncated request; every prompt and
        sampling parameter passes :func:`~.lifecycle.validate_request`
        (out-of-vocab / non-integer token ids, negative temperature or
        top_k are caller bugs, rejected at the boundary).  In paged
        mode the request's full token budget (``min(prompt_len +
        gen_len, max_len)`` rows) is allocated here; direct calls raise
        MemoryError when the pool is short — queue through
        :meth:`submit` to wait for pages instead (with ``preempt=True``
        running victims are spilled first and MemoryError is the last
        resort).

        ``deadline_s`` (scalar or ``{slot: v}``) sets a TTL from now;
        the request times out at the first block boundary past it,
        returning its partial output with status TIMED_OUT.

        ``priority`` (scalar or ``{slot: v}``; class enum, name or int
        value — see :class:`~.lifecycle.PriorityClass`) tags each
        admitted request's SLO class for victim selection, per-class
        telemetry and SLO-driven shedding; default STANDARD.
        """
        with self._journal_scope(
                "add", {"requests": {int(s): np.asarray(p)
                                     for s, p in requests.items()},
                        "gen_len": gen_len, "temperature": temperature,
                        "top_k": top_k, "deadline_s": deadline_s,
                        "priority": priority}):
            return self._add_requests(
                requests, gen_len=gen_len, temperature=temperature,
                top_k=top_k, deadline_s=deadline_s, priority=priority,
                _t_submit=_t_submit, _ids=_ids, _deadlines=_deadlines,
                _prefix=_prefix)

    def _add_requests(self, requests: Dict[int, np.ndarray], *,
                      gen_len=None, temperature=None, top_k=None,
                      deadline_s=None, priority=None, _t_submit=None,
                      _ids=None, _deadlines=None, _prefix=None):
        t_call = self.clock()
        reqs = {int(s): validate_request(p, vocab=self.cfg.vocab,
                                         temperature=temperature,
                                         top_k=top_k, priority=priority)
                for s, p in requests.items()}
        if deadline_s is not None:
            # validated as the dict-or-scalar it is: every entry checked
            # on its own (collapsing to min() crashed on mixed None
            # entries and pinned the whole batch to the tightest TTL in
            # the validation error path)
            validate_request([], vocab=self.cfg.vocab,
                             deadline_s=deadline_s)
        for s, p in reqs.items():
            if p.shape[0] > self.max_len:
                raise ValueError(
                    f"prompt of {p.shape[0]} tokens does not fit the cache "
                    f"(max_len={self.max_len}); refusing to clamp-write "
                    f"the tail")
            if p.size == 0:
                reqs[s] = np.zeros((1,), np.int32)
        if not reqs:
            return

        def per_slot(v, s, default):
            if v is None:
                return default
            return v.get(s, default) if isinstance(v, dict) else v

        def stop_of(s, plen):
            return self._token_budget(plen, per_slot(gen_len, s, None))

        prefix_of: Dict[int, dict] = {}
        if self.paged:
            # one page allocation covers the request's whole budget, so
            # the block table is static for its lifetime (the fused
            # decode loop never needs a mid-block allocator callback).
            # Feasibility is checked for the whole group BEFORE touching
            # any allocator state, so a failed admission leaves the
            # engine exactly as it was.
            held: Dict[int, List[int]] = {}
            if self.prefix_cache:
                # match each prompt's longest committed prefix and take
                # a reference on the hit pages IMMEDIATELY (before any
                # eviction/preemption below can run): a held page has
                # refcount >= 2 and is untouchable by the eviction
                # sweep.  try_admit matched+shared at pop time and
                # passes its holds through ``_prefix``; either way this
                # call owns them and must release them on failure.
                for s, p in reqs.items():
                    info = (_prefix or {}).get(s)
                    h = None
                    if info is None:
                        info = self._match_prefix(p)
                        h = info["shared"] + (
                            [info["cow"]] if info["cow"] is not None
                            else [])
                        if h:
                            self.allocator.share(h)
                    prefix_of[s] = info
                    held[s] = h if h is not None else (
                        info["shared"] + ([info["cow"]]
                                          if info["cow"] is not None
                                          else []))
            cls_of = {s: coerce_priority(per_slot(priority, s, None))
                      for s in reqs}
            floor = min(cls_of.values())
            needs = {s: self.allocator.pages_for(stop_of(s, p.shape[0]))
                     - len(prefix_of[s]["shared"] if s in prefix_of else ())
                     for s, p in reqs.items()}
            recyclable = sum(len(self._slot_pages.get(s, ())) for s in reqs)

            def short():
                return (sum(needs.values())
                        - self.allocator.free_pages - recyclable)

            if short() > 0 and self.prefix_cache:
                # cold index entries yield before any running request
                # does — dropping unreferenced cached prefixes is free
                # (class floor: a cached chunk more important than every
                # request being admitted stays)
                self.prefix_index.evict(
                    self.allocator, short(),
                    floor=floor if self.allocator.class_quotas else None)
            if short() > 0 and self.preempt:
                # graceful degradation instead of MemoryError: spill
                # running victims until the admission fits — but only
                # victims at or below the most important class being
                # admitted (a BATCH add must never spill REALTIME work)
                self._preempt_until(sum(needs.values()) - recyclable,
                                    exclude=set(reqs), floor=floor)
            if short() > 0:
                for h in held.values():
                    if h:
                        self.allocator.free(h)      # release the match
                raise MemoryError(
                    f"page pool exhausted: admission needs "
                    f"{sum(needs.values())} pages, free "
                    f"{self.allocator.free_pages} of "
                    f"{self.allocator.num_pages} (queue through submit() "
                    f"to wait for pages)")
            if self.allocator.class_quotas:
                # group quota preflight BEFORE any state moves (same
                # atomicity rule as the pool check above): count the
                # pages the recycle loop below will release as credit
                needs_cls: Dict[PriorityClass, int] = {}
                for s in reqs:
                    needs_cls[cls_of[s]] = (needs_cls.get(cls_of[s], 0)
                                            + needs[s])
                release = [p for s in reqs for p in
                           (self._slot_shared.get(s, [])
                            if self.prefix_cache else [])
                           + self._slot_pages.get(s, [])]
                freed, uncharge = self.allocator.release_credit(release)
                qmsg = self.allocator.quota_violation(
                    needs_cls, freed=freed, uncharge=uncharge)
                if qmsg is not None:
                    for h in held.values():
                        if h:
                            self.allocator.free(h)
                    raise MemoryError(
                        f"class quota exceeded: {qmsg} (queue through "
                        f"submit() to wait)")
            for s in reqs:
                # direct slot-addressed admission over a slot that still
                # holds pages (no finish() in between) recycles them
                if self.prefix_cache:
                    self.allocator.free(self._slot_shared.pop(s, []))
                    self._pub.pop(s, None)
                if s in self._slot_pages:
                    self.allocator.free(self._slot_pages.pop(s))
            for s in reqs:
                info = prefix_of.get(s)
                shared = info["shared"] if info else []
                pages = self.allocator.alloc(needs[s], owner=s,
                                             cls=cls_of[s])
                self._slot_pages[s] = pages
                self.block_tables[s, :] = self._trash
                self.block_tables[s, :len(shared)] = shared
                self.block_tables[s, len(shared):len(shared)
                                  + len(pages)] = pages
                if self.prefix_cache:
                    self._slot_shared[s] = list(shared)
                    self._pub[s] = ((info["depth"], info["key"])
                                    if info else (0, ROOT))
                if info and info["cow"] is not None:
                    # full-prompt hit: the boundary page still receives
                    # this slot's writes (last prompt row + decode), so
                    # it is copy-on-write duplicated into the slot's
                    # first private page before anything runs
                    self.cache = self._copy_page(
                        self.cache, jnp.int32(info["cow"]),
                        jnp.int32(pages[0]))
                    self.allocator.free([info["cow"]])
                    self.counters["cow_copies"] += 1
                if info and (info["shared"] or info["cow"] is not None):
                    self.counters["prefix_hits"] += 1
                    self.counters["prefix_hit_pages"] += (
                        len(shared)
                        + (1 if info["cow"] is not None else 0))
                    self.counters["prefix_tokens_saved"] += info["start"]
            self._flush_block_tables()

        # a recycled slot may have idled for whole blocks since
        # finish(): decode advances dead lanes too (the held pad token
        # drives recurrent state forward), so zero each such lane NOW —
        # prefill must start from clean state, not from whatever
        # accumulated while the slot sat empty.  (Chunked-prefill
        # garbage writes into a clean lane don't dirty it: the
        # visibility mask + decode's write-before-attend keep those
        # rows unobservable, the same invariant as the cache margin.)
        for s in reqs:
            if not self._clean[s]:
                self.cache = self._invalidate(self.cache, jnp.int32(s))
                if self.draft is not None:
                    # the draft scan advances dead lanes too, so the
                    # drafter's recurrent/KV lane is just as dirty
                    self.draft_cache = self._draft_invalidate(
                        self.draft_cache, jnp.int32(s))
        starts = {s: info["start"] for s, info in prefix_of.items()
                  if info["start"]}
        if self.chunked:
            first = self._prefill_chunked(reqs, starts)
        else:
            first = self._prefill_looped(reqs)
        if self.spec and self.draft is not None:
            self._prefill_draft(reqs)
        t_first = self.clock()
        for s, p in reqs.items():
            self.pos[s] = p.shape[0]
            self.live[s] = True
            self.outputs[s] = []
            self.tokens[s, 0] = first[s]
            self._clean[s] = False          # lane now holds the prompt
            self.temperature[s] = per_slot(temperature, s, 0.0)
            self.top_k[s] = per_slot(top_k, s, 0)
            self.stop_pos[s] = stop_of(s, p.shape[0])
            # drafting corpus + per-request telemetry: TTFT is measured
            # from submit() when the request came through the queue,
            # else from this call's start (direct slot-addressed adds)
            self.hist[s, :] = 0
            self.hist[s, :p.shape[0]] = p
            t_sub = (_t_submit or {}).get(s, t_call)
            rid = (_ids or {}).get(s)
            if rid is None:
                rid = self._mint_id()
            if _deadlines is not None and s in _deadlines:
                dl = _deadlines[s]
            else:
                d = per_slot(deadline_s, s, None)
                dl = None if d is None else t_call + float(d)
            cls = coerce_priority(per_slot(priority, s, None))
            self._req_meta[s] = {"id": rid, "ttft_s": t_first - t_sub,
                                 "t_admit": t_first, "deadline": dl,
                                 "priority": cls}
            self._class_count(cls, "admitted")
        self.counters["admitted"] += len(reqs)
        self.counters["peak_live"] = max(self.counters["peak_live"],
                                         int(self.live.sum()))
        if self.prefix_cache:
            # publish the fresh prompts' full pages NOW so requests
            # admitted in the very next sweep already hit
            for s in reqs:
                self._publish_committed(s)

    def _flush_block_tables(self):
        """Write the host block tables into the cache pytree (one upload
        covering every table edit since the last flush).

        The ``.copy()`` is the same jit-boundary rule as ``_snap``:
        ``self.block_tables`` is mutated in place by finish()/admission
        right after dispatch, and on the CPU backend jax may alias the
        numpy buffer into the async transfer instead of copying it."""
        self.cache = set_block_table(self.cache, self.block_tables.copy())
        self._bt_dirty = False

    # -- prefix caching ------------------------------------------------------
    def _match_prefix(self, prompt: np.ndarray) -> dict:
        """Plan a prompt's admission against the prefix index.

        Returns ``start`` (first suffix token to prefill), ``shared``
        (index pages to map read-only at table entries 0..len-1),
        ``cow`` (an index page to duplicate into the slot's first
        private page, or None), and ``depth``/``key`` (how far down the
        chain the match reached — where this slot's own publication
        will resume).  The planner does NOT move refcounts; callers
        share the returned pages while the plan is in flight.

        A full-prompt hit still prefills the last prompt token: the
        engine needs its logits (the first generated token), and its
        KV row — plus every decode write after it — lands in the final
        matched page, so that page is planned as the CoW duplicate
        rather than a read-only mapping.
        """
        ps = self.allocator.page_size
        plen = int(prompt.shape[0])
        m, pages, key = self.prefix_index.match(prompt)
        if m == 0:
            return {"start": 0, "shared": [], "cow": None,
                    "depth": 0, "key": ROOT}
        if m * ps == plen:
            return {"start": plen - 1, "shared": pages[:-1],
                    "cow": pages[-1], "depth": m, "key": key}
        return {"start": m * ps, "shared": pages, "cow": None,
                "depth": m, "key": key}

    def _publish_committed(self, slot: int) -> None:
        """Publish ``slot``'s fully-committed pages to the prefix index.

        A page is publishable once every one of its rows is below the
        slot's committed watermark — ``(depth+1)*page_size <= pos``.
        Safe under speculative decode: rewind is a pos edit whose
        accepted count is clipped to >= 1 (see build_spec_decode_loop),
        so ``pos`` never decreases and the condition can only keep
        holding — a published page is never un-committed.  Chunks whose
        chain key is already indexed (a concurrent same-prefix stream
        published first) are skipped; the slot's duplicate page simply
        stays private.  Publication transfers allocator ownership to
        :data:`PREFIX_OWNER` and moves the page to the slot's shared
        list, so a later finish()/preempt() decrements instead of
        freeing — O(new chunks), no device work.
        """
        ps = self.allocator.page_size
        depth, parent = self._pub.get(slot, (0, ROOT))
        pos = int(self.pos[slot])
        while (depth + 1) * ps <= pos:
            chunk = self.hist[slot, depth * ps:(depth + 1) * ps]
            key = self.prefix_index.chain_key(parent, chunk)
            if key in self.prefix_index:
                self.prefix_index.touch(key)
            else:
                page = int(self.block_tables[slot, depth])
                assert page != self._trash \
                    and page in self._slot_pages.get(slot, ()), \
                    "publishable chunk not backed by a private page"
                self.allocator.share([page])
                self.allocator.transfer([page], PREFIX_OWNER)
                self._slot_pages[slot].remove(page)
                self._slot_shared[slot].append(page)
                meta = self._req_meta.get(slot)
                self.prefix_index.put(
                    key, parent, chunk, page, depth,
                    cls=meta["priority"] if meta else None)
            depth, parent = depth + 1, key
        self._pub[slot] = (depth, parent)

    def _cow_guard(self) -> None:
        """Belt-and-braces copy-on-write sweep before a decode block.

        By construction no block ever writes a shared page — decode and
        spec-verify write at positions >= ``pos``, and every table entry
        from ``pos // page_size`` on is slot-private (the suffix pages
        allocated at admission; published pages all sit below the
        committed watermark).  If a future writer path breaks that
        proof, this sweep duplicates the offending page instead of
        corrupting every other consumer, and the ``cow_copies`` counter
        records that it fired.
        """
        ps = self.allocator.page_size
        dirty = False
        for s in range(self.batch):
            if self.outputs[s] is None:
                continue                    # empty lane: all-trash table
            row = self.block_tables[s]
            for e in range(int(self.pos[s]) // ps, row.shape[0]):
                page = int(row[e])
                if (page == self._trash
                        or self.allocator.refcount(page) <= 1
                        or page in self._slot_pages.get(s, ())):
                    continue
                meta = self._req_meta.get(s)
                fresh = self.allocator.alloc(
                    1, owner=s, cls=meta["priority"] if meta else None)[0]
                self.cache = self._copy_page(self.cache, jnp.int32(page),
                                             jnp.int32(fresh))
                self.block_tables[s, e] = fresh
                self._slot_pages[s].append(fresh)
                if page in self._slot_shared.get(s, ()):
                    self._slot_shared[s].remove(page)
                self.allocator.free([page])     # drop this slot's hold
                self.counters["cow_copies"] += 1
                dirty = True
        if dirty:
            self._flush_block_tables()

    # -- admission queue ----------------------------------------------------
    def _mint_id(self) -> int:
        rid = self._next_id
        self._next_id += 1
        return rid

    def submit(self, prompt: np.ndarray, *, gen_len: Optional[int] = None,
               temperature: float = 0.0, top_k: int = 0,
               deadline_s: Optional[float] = None, priority=None) -> int:
        """Queue a request; returns its request id.

        The id keys every later lifecycle interaction —
        :meth:`cancel`, :meth:`status`, and the terminal entry in
        ``results`` (status + whatever tokens the request committed).

        Admission happens inside :meth:`step_many` (and via
        :meth:`try_admit`): a request leaves the queue the moment a
        lane is free AND — in paged mode — the free list covers its
        token budget, i.e. the instant earlier requests' freed pages
        add up, not when a whole dense slot's ``max_len`` would.

        ``deadline_s`` is a TTL from submission: past it, the request
        is timed out at the next block boundary (queued or running)
        and its partial output lands in ``results`` — no exception.

        ``priority`` (class enum / name / int value, default STANDARD)
        sets the request's SLO class: the queue serves the most
        important non-empty class first (FIFO within a class, no
        skipping past a page-blocked higher-class head), victims spill
        in BATCH→STANDARD→REALTIME order, and per-class SLO targets
        (``slo_targets``) drive graceful degradation.  The class never
        changes *what* a request generates — only when.
        """
        prompt = validate_request(prompt, vocab=self.cfg.vocab,
                                  temperature=temperature, top_k=top_k,
                                  deadline_s=deadline_s, priority=priority)
        if prompt.shape[0] > self.max_len:
            raise ValueError(
                f"prompt of {prompt.shape[0]} tokens does not fit the "
                f"cache (max_len={self.max_len})")
        t = self.clock()
        req = {"id": self._mint_id(), "prompt": prompt, "gen_len": gen_len,
               "temperature": temperature, "top_k": top_k,
               "t_submit": t, "priority": coerce_priority(priority),
               "deadline": None if deadline_s is None
               else t + float(deadline_s)}
        if self.paged:
            need = self.allocator.pages_for(self._budget(req))
            if need > self.allocator.num_pages:
                # would head-of-line block the FIFO forever
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.allocator.num_pages}; raise num_pages or "
                    f"lower gen_len")
            cap = self.allocator.cap_pages(req["priority"])
            if cap is not None and need > cap:
                # same head-of-line-forever shape, quota edition
                raise ValueError(
                    f"request needs {need} pages but class "
                    f"{req['priority'].name.lower()} is capped at {cap} "
                    f"of {self.allocator.num_pages}; raise the cap or "
                    f"lower gen_len")
        self.waiting.append(req)
        if self._journal is not None and self._jmute == 0:
            # journaled with the minted id so replay can assert the
            # deterministic re-mint matches; deadline_s rides RELATIVE —
            # perf_counter values don't survive a process, so a
            # recovered request's TTL restarts at recovery (the
            # conservative reading of "its clock died with the process")
            self._journal.append(("submit", {
                "id": req["id"], "prompt": prompt, "gen_len": gen_len,
                "temperature": temperature, "top_k": top_k,
                "deadline_s": deadline_s,
                "priority": req["priority"].name.lower()}))
        return req["id"]

    def status(self, req_id: int):
        """Lifecycle status of a request id (None = unknown id)."""
        if req_id in self.results:
            return self.results[req_id]["status"]
        for r in self.waiting:
            if r["id"] == req_id:
                return (RequestStatus.PREEMPTED if r.get("resume")
                        else RequestStatus.QUEUED)
        for m in self._req_meta.values():
            if m["id"] == req_id:
                return RequestStatus.RUNNING
        return None

    def cancel(self, req_id: int) -> bool:
        """Cancel by request id, wherever the request currently is.

        Queued (fresh or preempted): removed from the queue, terminal
        CANCELLED with whatever tokens it had committed (a preempted
        record's spilled payload is simply dropped).  Running: its lane
        finishes NOW with the partial output — pages freed, the lane
        admits the next request at the coming block boundary.  Unknown
        or already-terminal ids return False."""
        with self._journal_scope("cancel", int(req_id)):
            for i, r in enumerate(self.waiting):
                if r["id"] == req_id:
                    del self.waiting[i]
                    self._finalize_queued(r, RequestStatus.CANCELLED)
                    return True
            for s, m in list(self._req_meta.items()):
                if m["id"] == req_id:
                    self.live[s] = False
                    self.finish(s, status=RequestStatus.CANCELLED)
                    return True
            return False

    def _finalize_queued(self, rec: dict, status: RequestStatus) -> None:
        """Terminal outcome for a request that never (re)occupied a
        lane: results entry only — ``done`` tracks lane streams."""
        self.results[rec["id"]] = {"status": status,
                                   "tokens": list(rec.get("outputs") or [])}
        if status is RequestStatus.TIMED_OUT:
            self.counters["timeouts"] += 1
            self._class_count(self._rec_priority(rec), "timeouts")
        elif status is RequestStatus.CANCELLED:
            self.counters["cancellations"] += 1
            self._class_count(self._rec_priority(rec), "cancellations")

    def _sweep_deadlines(self) -> None:
        """TTL check at the block boundary — the engine's only safe
        cancellation point (slots change hands between blocks, never
        inside one).  Expired queued requests finalize without a lane;
        expired running ones finish with their partial output."""
        t = self.clock()
        expired = [r for r in self.waiting
                   if r.get("deadline") is not None and t >= r["deadline"]]
        if expired:
            gone = {id(r) for r in expired}
            self.waiting = deque(r for r in self.waiting
                                 if id(r) not in gone)
            for r in expired:
                self._finalize_queued(r, RequestStatus.TIMED_OUT)
        for s in range(self.batch):
            m = self._req_meta.get(s)
            if (m is not None and m.get("deadline") is not None
                    and self.live[s] and t >= m["deadline"]):
                self.live[s] = False
                self.finish(s, status=RequestStatus.TIMED_OUT)

    def _token_budget(self, plen: int, gen_len: Optional[int]) -> int:
        """A request's cache-row budget — its final ``stop_pos``.

        The single source of truth for both page planning (try_admit /
        submit) and allocation+stopping (add_requests): clamped to the
        cache bound (an oversized gen_len must stop at max_len, not
        keep a slot live while decode writes clamp into the last row),
        with an empty prompt counting as its 1-token pad/BOS stand-in.
        """
        plen = max(1, int(plen))
        return min(plen + gen_len, self.max_len) if gen_len is not None \
            else self.max_len

    def _budget(self, req) -> int:
        return self._token_budget(len(req["prompt"]), req["gen_len"])

    def retire_finished(self) -> int:
        """finish() every slot whose generation ended (frees its lane —
        and, paged, its pages) so try_admit can reuse both."""
        with self._journal_scope("retire"):
            n = 0
            for s in range(self.batch):
                if self.outputs[s] is not None and not self.live[s]:
                    self.finish(s)
                    n += 1
            return n

    def _rec_priority(self, rec: dict) -> PriorityClass:
        """SLO class of a queue record (fresh or preempted resume)."""
        if rec.get("resume"):
            return coerce_priority(rec["meta"].get("priority"))
        return coerce_priority(rec.get("priority"))

    def _queue_head(self) -> int:
        """Index of the next admission candidate: the FRONT of the most
        important non-empty class.  Within a class the queue stays
        FIFO; across classes a more important arrival overtakes
        everything below it — but a page-blocked head still blocks all
        lower classes (no skipping downward), so admission order stays
        deterministic and a big REALTIME request cannot be starved by
        a stream of small BATCH ones slipping past it."""
        best, best_i = None, 0
        for i, r in enumerate(self.waiting):
            p = self._rec_priority(r)
            if best is None or p < best:
                best, best_i = p, i
                if p == PriorityClass.REALTIME:
                    break
        return best_i

    def try_admit(self) -> int:
        """Admit queued requests into free lanes while pages last:
        class-ordered (REALTIME > STANDARD > BATCH), FIFO within a
        class, no head-of-line skipping — a page-blocked head waits
        for pages rather than being starved by smaller requests behind
        it, so admission order is deterministic, which the
        cross-backend conformance suite relies on.  All fresh
        admissions of one call share a single batched prefill;
        preempted records resume individually (page payload + lane
        restore, no prefill at all).

        With ``preempt=True``, a head that stays page-blocked for
        ``preempt_after`` consecutive admission sweeps escalates
        (tracked per class — see ``_head_blocked``): running victims
        (see :meth:`_victim_order`) at or below the head's class are
        spilled until the head fits — head-of-line blocking becomes
        time slicing.  A head whose class has a TTFT SLO target and is
        already past it escalates immediately."""
        with self._journal_scope("admit"):
            return self._try_admit()

    def _try_admit(self) -> int:
        free = [s for s in range(self.batch)
                if self.outputs[s] is None and not self.live[s]]
        admit, kw = {}, {"gen_len": {}, "temperature": {}, "top_k": {},
                         "priority": {}, "_t_submit": {}, "_ids": {},
                         "_deadlines": {}, "_prefix": {}}
        planned = 0
        planned_cls: Dict[PriorityClass, int] = {}
        resumed = 0
        placed: set = set()
        while self.waiting and free:
            i = self._queue_head()
            req = self.waiting[i]
            cls = self._rec_priority(req)
            pre = None
            if self.paged:
                if req.get("resume"):
                    need = req["n_pages"]
                else:
                    need = self.allocator.pages_for(self._budget(req))
                    if self.prefix_cache:
                        # a hit's shared pages are mapped, not allocated:
                        # admission costs only the suffix's fresh pages
                        pre = self._match_prefix(req["prompt"])
                        need -= len(pre["shared"])
                fits = self.allocator.can_alloc(planned + need)
                if fits and self.allocator.class_quotas:
                    # the head waits (no exception) when its class is
                    # over cap or the free pages belong to another
                    # class's reserved floor — exactly how a pool-short
                    # head waits for pages
                    want = dict(planned_cls)
                    want[cls] = want.get(cls, 0) + need
                    fits = self.allocator.quota_violation(want) is None
                if not fits:
                    if self.prefix_cache:
                        # drop cold cached prefixes before touching any
                        # running request.  Pages already promised this
                        # sweep are share()-held (refcount >= 2), so
                        # the eviction cannot take them; the CURRENT
                        # head's match is not held yet and is protected
                        # explicitly.  Class floor: the head may only
                        # evict chunks of its own class or less
                        # important ones.
                        mine = set(pre["shared"]) if pre else set()
                        if pre and pre["cow"] is not None:
                            mine.add(pre["cow"])
                        # the sweep must cover whichever constraint
                        # actually blocks the head: the pool shortfall,
                        # or — quota-blocked with a free pool — the
                        # class's own published pages holding its budget
                        want = max(
                            planned + need - self.allocator.free_pages,
                            self.allocator.quota_evict_want(
                                cls, need, planned=planned_cls))
                        if self.prefix_index.evict(
                                self.allocator, want, protect=mine,
                                floor=(cls if self.allocator.class_quotas
                                       else None)):
                            continue    # freed pages; recheck the head
                    if self._maybe_preempt(req, cls, planned + need, free,
                                           exclude=placed):
                        continue        # victims spilled; recheck head
                    break
            del self.waiting[i]
            hb = self._head_blocked.get(cls)
            if hb is not None and hb[0] == req["id"]:
                # reset the escalation counter only when the tracked
                # blocked head itself got through — popping any OTHER
                # record (a resume, a small admission) must not clobber
                # a still-blocked head's count, or interleaved progress
                # would keep it one sweep short of preempting forever
                del self._head_blocked[cls]
            s = free.pop(0)
            placed.add(s)
            if req.get("resume"):
                # resume allocates immediately (not via ``planned``)
                self._resume(s, req)
                resumed += 1
                continue
            if self.paged:
                planned += need
                planned_cls[cls] = planned_cls.get(cls, 0) + need
            if pre is not None:
                # hold the matched pages NOW: a later head's eviction
                # (or a direct add elsewhere) must not free them while
                # this admission is pending in ``admit``
                h = pre["shared"] + ([pre["cow"]]
                                     if pre["cow"] is not None else [])
                if h:
                    self.allocator.share(h)
                kw["_prefix"][s] = pre
            admit[s] = req["prompt"]
            kw["gen_len"][s] = req["gen_len"]
            kw["temperature"][s] = req["temperature"]
            kw["top_k"][s] = req["top_k"]
            kw["priority"][s] = cls
            kw["_t_submit"][s] = req["t_submit"]
            kw["_ids"][s] = req["id"]
            kw["_deadlines"][s] = req["deadline"]
        if admit:
            self.add_requests(admit, **kw)
        return len(admit) + resumed

    # -- preempt-and-spill ---------------------------------------------------
    def _victim_order(self, exclude=(), floor=None) -> List[int]:
        """Spill order under pressure: class before slack — every BATCH
        request yields before any STANDARD one, and REALTIME yields
        last of all.  Within a class, requests WITHOUT deadlines yield
        first (nobody's SLO pays for the spill), then most-slack
        deadlines; ties break latest-admitted first — LIFO time
        slicing, the oldest work keeps its pages.

        ``floor`` (the preempting head's class) drops victims MORE
        important than the head entirely: a BATCH admission may spill
        other BATCH work, never a REALTIME stream."""
        cands = [s for s in range(self.batch)
                 if self.live[s] and s in self._req_meta
                 and s not in exclude]
        if floor is not None:
            cands = [s for s in cands
                     if coerce_priority(self._req_meta[s].get("priority"))
                     >= floor]

        def rank(s):
            m = self._req_meta[s]
            dl = m.get("deadline")
            return (-int(coerce_priority(m.get("priority"))),
                    dl is not None, -(dl or 0.0), -m["t_admit"], -s)

        return sorted(cands, key=rank)

    def _preempt_until(self, target_free: int, exclude=(),
                       floor=None) -> None:
        """Spill victims until ``free_pages`` covers ``target_free``
        (or no victims remain — the caller re-checks and degrades)."""
        for v in self._victim_order(exclude, floor=floor):
            if self.allocator.free_pages >= target_free:
                break
            self._preempt(v)

    def _maybe_preempt(self, req, cls: PriorityClass, need: int,
                       free: List[int], exclude=()) -> bool:
        """Escalating head-of-line response inside try_admit: only
        after the SAME head has been page-blocked ``preempt_after``
        consecutive sweeps (counted per class) do victims spill (a
        transient shortfall one retire sweep would fix must not thrash
        the pool).  Exception: a head whose class carries a TTFT SLO
        target it has already missed escalates NOW — patience is
        exactly the budget the SLO says it doesn't have."""
        if not self.preempt:
            return False
        hb = self._head_blocked.get(cls)
        rounds = hb[1] + 1 if hb is not None and hb[0] == req["id"] else 1
        self._head_blocked[cls] = (req["id"], rounds)
        if rounds < self.preempt_after and not self._past_ttft_slo(req, cls):
            return False
        progressed = False
        # quota-aware fit: charge the whole plan to the head's class —
        # conservative when the sweep's earlier admissions were other
        # classes (may spill one victim more than strictly needed),
        # never permissive
        for v in self._victim_order(exclude, floor=cls):
            if self.allocator.can_alloc(need, cls=cls):
                break
            self._preempt(v)
            free.append(v)          # the victim's lane is admittable now
            progressed = True
        return progressed and self.allocator.can_alloc(need, cls=cls)

    def _past_ttft_slo(self, req: dict, cls: PriorityClass) -> bool:
        """Has this queued record already blown its class TTFT target?
        (Resume records don't re-count — their first token shipped.)"""
        tgt = self.slo_targets.get(cls, {}).get("ttft_s")
        if tgt is None or req.get("resume"):
            return False
        return self.clock() - req["t_submit"] >= tgt

    def _page_payload(self, pages: List[int]) -> Dict[str, np.ndarray]:
        """Host copy of the pool pages' payload, keyed by cache path.

        Every page-pool leaf carries the page axis at position 1 —
        (layers_or_groups, num_pages+1, …) — so one gather rule covers
        lm dense/moe KV, hybrid attention, and int8 scale leaves alike.
        Families without page leaves (ssm: dense recurrent state, pool
        meters admission only) yield an empty payload."""
        ids = jnp.asarray(pages, jnp.int32)
        out = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.cache)[0]:
            if any(getattr(k, "key", None) == "pages" for k in path):
                out[jax.tree_util.keystr(path)] = np.asarray(leaf[:, ids])
        return out

    def _write_pages(self, payload: Dict[str, np.ndarray],
                     pages: List[int]) -> None:
        """Scatter a spilled payload into (new) physical pages."""
        ids = jnp.asarray(pages, jnp.int32)

        def put(path, leaf):
            data = payload.get(jax.tree_util.keystr(path))
            if data is None:
                return leaf
            return leaf.at[:, ids].set(jnp.asarray(data))

        self.cache = jax.tree_util.tree_map_with_path(put, self.cache)

    def _lane_state(self, slot: int):
        """Host copy of ``slot``'s recurrent lane (None for pure-KV
        families) via the same batch-leading view speculative rollback
        uses — preemption reuses the spec_state machinery instead of
        growing a second per-family state protocol."""
        rec = spec_state_fn(self.cache, self.cfg)
        if rec is None:
            return None
        return jax.tree_util.tree_map(lambda t: np.asarray(t[slot]), rec)

    def _write_lane(self, slot: int, lane) -> None:
        if lane is None:
            return
        rec = spec_state_fn(self.cache, self.cfg)
        rec = jax.tree_util.tree_map(
            lambda c, s: c.at[slot].set(jnp.asarray(s)), rec, lane)
        self.cache = spec_restore_fn(self.cache, rec, self.cfg)

    def _preempt(self, slot: int) -> None:
        """Spill ``slot``'s request to host memory and re-queue it.

        O(pages) + one lane gather: page payloads device_get through
        the shared axis-1 page indexing, the recurrent lane (ssm /
        hybrid) rides the spec_state hooks, the allocator takes the
        pages back atomically, and the block-table row points at the
        trash page.  The record re-enters the queue at the BACK —
        time slicing, not a livelock where the resumed head instantly
        re-preempts its own victim."""
        meta = self._req_meta.pop(slot)
        # the table row is the authoritative mapping: shared prefix
        # pages first, then the slot's private pages.  ALL of them are
        # payload-copied and ALL the slot's references dropped; resume
        # restores into fresh private pages, which stays correct even
        # if the index evicts the shared originals while the record
        # waits in the queue.
        row = self.block_tables[slot]
        mapped = [int(p) for p in row[row != self._trash]]
        payload = self._page_payload(mapped) if mapped else {}
        lane = self._lane_state(slot)
        shared = (self._slot_shared.pop(slot, [])
                  if self.prefix_cache else [])
        if shared:
            self.allocator.free(shared)         # drop this slot's holds
        private = self._slot_pages.pop(slot, [])
        spilled = self.allocator.spill(slot)
        assert sorted(spilled) == sorted(private) \
            and set(mapped) == set(shared) | set(private), \
            "allocator/engine page maps diverged"
        self.block_tables[slot, :] = self._trash
        self._bt_dirty = True
        rec = {"resume": True, "id": meta["id"], "meta": meta,
               "deadline": meta.get("deadline"),
               "n_pages": len(mapped), "payload": payload, "lane": lane,
               "pub": (self._pub.pop(slot, (0, ROOT))
                       if self.prefix_cache else None),
               "outputs": self.outputs[slot],
               "pos": int(self.pos[slot]),
               "token": int(self.tokens[slot, 0]),
               "hist": self.hist[slot].copy(),
               "temperature": float(self.temperature[slot]),
               "top_k": int(self.top_k[slot]),
               "stop_pos": int(self.stop_pos[slot])}
        self.outputs[slot] = None
        self.live[slot] = False
        self.pos[slot] = 0
        self.tokens[slot, 0] = 0
        self.temperature[slot] = 0.0
        self.top_k[slot] = 0
        self.stop_pos[slot] = self.max_len
        self.cache = self._invalidate(self.cache, jnp.int32(slot))
        self._clean[slot] = True
        self.waiting.append(rec)
        self.counters["preemptions"] += 1
        self._class_count(meta.get("priority"), "preemptions")
        self.counters["spilled_pages"] += len(mapped)

    def _resume(self, slot: int, rec: dict) -> None:
        """Re-admit a preempted request: restore, never recompute.

        Fresh physical pages receive the spilled payload and the block
        table re-targets them (restore does not pin physical ids);
        ``pos``, the held token, partial outputs and drafting history
        pick up exactly where the spill happened — a resumed greedy
        stream is byte-identical to an unpreempted one."""
        pages = self.allocator.alloc(rec["n_pages"], owner=slot,
                                     cls=self._rec_priority(rec))
        self._slot_pages[slot] = pages
        if self.prefix_cache:
            # a resumed request owns ALL its pages privately (the spill
            # copied shared-prefix payloads too); publication resumes at
            # the preserved chain position, so already-indexed chunks
            # are recognized and skipped rather than re-published
            self._slot_shared[slot] = []
            self._pub[slot] = rec.get("pub") or (0, ROOT)
        self.block_tables[slot, :] = self._trash
        self.block_tables[slot, :len(pages)] = pages
        self._flush_block_tables()
        if not self._clean[slot]:
            # the idle lane decayed under decode blocks since its last
            # occupant — recurrent families need the zeroing
            self.cache = self._invalidate(self.cache, jnp.int32(slot))
        if rec["payload"]:
            self._write_pages(rec["payload"], pages)
        self._write_lane(slot, rec["lane"])
        self.pos[slot] = rec["pos"]
        self.tokens[slot, 0] = rec["token"]
        self.live[slot] = True
        self.outputs[slot] = rec["outputs"]
        self.hist[slot] = rec["hist"]
        self.temperature[slot] = rec["temperature"]
        self.top_k[slot] = rec["top_k"]
        self.stop_pos[slot] = rec["stop_pos"]
        self._clean[slot] = False
        self._req_meta[slot] = rec["meta"]
        self.counters["peak_live"] = max(self.counters["peak_live"],
                                         int(self.live.sum()))

    def _prefill_chunked(self, reqs, starts=None) -> Dict[int, int]:
        """Batched chunked prefill; ``starts`` (slot -> first token to
        ingest) makes it suffix-only for prefix-cache hits — the chunk
        grid then runs at ``start + c0`` per slot, reading the shared
        prefix pages through the already-flushed block table."""
        chunk = self.prefill_chunk
        starts = starts or {}
        offs = {s: int(starts.get(s, 0)) for s in reqs}
        sufs = {s: p[offs[s]:] for s, p in reqs.items()}
        plen = max(t.shape[0] for t in sufs.values())
        padded = -(-plen // chunk) * chunk      # one compile per chunk width
        toks = np.zeros((self.batch, padded), np.int32)
        for s, t in sufs.items():
            toks[s, :t.shape[0]] = t
        fresh = np.fromiter(sorted(reqs), np.int64)
        # slots whose (shorter) suffix is exhausted park at the LAST
        # full chunk inside the table — their garbage writes land at
        # positions >= max_len (the margin region, never attendable and
        # below no slot's committed watermark) instead of clamping into
        # real rows.  Offsets exist only in paged+prefix mode, where
        # width * page_size >= max_len + margin >= max_len + chunk.
        park = (self.block_tables.shape[1] * self.allocator.page_size
                - chunk) if (self.paged and offs and max(offs.values()))\
            else None
        first: Dict[int, int] = {}
        for c0 in range(0, padded, chunk):
            if c0 >= plen:
                break
            # live slots keep their own position: their (ignored) writes
            # land at [pos, pos+chunk) inside the margin, never clamped.
            cur = self.pos.copy()
            if park is None:
                cur[fresh] = c0
            else:
                for s in reqs:
                    cur[s] = min(offs[s] + c0, park)
            logits, self.cache = self.prefill(
                self.params, {"tokens": _snap(toks[:, c0:c0 + chunk])},
                self.cache, _snap(cur))
            logits = np.asarray(logits)
            for s, t in sufs.items():
                t_last = t.shape[0] - 1
                if c0 <= t_last < c0 + chunk:
                    first[s] = int(np.argmax(logits[s, t_last - c0]))
        return first

    def _prefill_looped(self, reqs) -> Dict[int, int]:
        """Per-token fallback for families without chunkable prefill.

        The full-batch decode calls advance EVERY lane — on recurrent
        families the pad-token inputs would corrupt mid-generation
        neighbours' state (and earlier fresh slots would pollute later
        ones).  Each slot's loop therefore restores all OTHER lanes to
        their pre-loop state afterwards (``merge_slot``), making its
        prefill exactly equivalent to a solo prefill.
        """
        first: Dict[int, int] = {}
        for s, p in reqs.items():
            before = self.cache
            logits = None
            for t in range(p.shape[0]):
                tok = np.zeros((self.batch, 1), np.int32)
                tok[s, 0] = p[t]
                logits, self.cache = self.decode(
                    self.params, self.cache, _snap(tok), _snap(self.pos))
                self.pos[s] += 1
            first[s] = int(jnp.argmax(logits[s, -1]))
            self.cache = self._merge(self.cache, before, jnp.int32(s))
            # keep pos at prompt length: later slots' loops must not write
            # into this slot's freshly-filled rows (add_requests re-asserts
            # the same value afterwards)
        return first

    def _prefill_draft(self, reqs):
        """Ingest admitted prompts into the DRAFT model's cache.

        After this the drafter has consumed exactly each admitted
        slot's prompt — one token behind the engine's held first
        generated token, which is precisely the state the spec loop's
        draft scan expects (its first draft step consumes the held
        token).  Chunked for attention-cache drafters, per-slot looped
        with ``merge_slot`` isolation for recurrent ones, mirroring the
        target's two prefill regimes.
        """
        d_cfg, d_params, _ = self.draft
        if self.draft_chunked:
            chunk = self.prefill_chunk
            plen = max(p.shape[0] for p in reqs.values())
            padded = -(-plen // chunk) * chunk
            toks = np.zeros((self.batch, padded), np.int32)
            for s, p in reqs.items():
                toks[s, :p.shape[0]] = p
            fresh = np.fromiter(sorted(reqs), np.int64)
            for c0 in range(0, padded, chunk):
                if c0 >= plen:
                    break
                cur = self.pos.copy()
                cur[fresh] = c0
                _, self.draft_cache = self._draft_prefill(
                    d_params, {"tokens": _snap(toks[:, c0:c0 + chunk])},
                    self.draft_cache, _snap(cur))
        else:
            for s, p in reqs.items():
                before = self.draft_cache
                cur = self.pos.copy()
                cur[s] = 0
                for t in range(p.shape[0]):
                    tok = np.zeros((self.batch, 1), np.int32)
                    tok[s, 0] = p[t]
                    _, self.draft_cache = self._draft_decode(
                        d_params, self.draft_cache, _snap(tok), _snap(cur))
                    cur[s] += 1
                self.draft_cache = self._draft_merge(self.draft_cache,
                                                     before, jnp.int32(s))

    # -- decode / retire -----------------------------------------------------
    # NOTE: all engine state crosses the jit boundary via ``_snap`` (a
    # defensive numpy copy): pos/tokens/live are mutated in place right
    # after the async dispatch, and on the CPU backend even jnp.array's
    # host copy can complete after that mutation (see ``_snap``).
    def step_many(self, n: int):
        """Run ``n`` fused decode steps in ONE jit call, sync once.

        Returns ``(block, block_live)`` — (n, B) emitted tokens and
        their validity mask.  Token-for-token identical to ``n`` calls
        of ``step()`` (same model step order, same PRNG stream: step
        ``i`` of the block draws with the global step counter the i-th
        single step would use).

        With speculation enabled (``spec=True``) ``n`` counts
        *draft→verify rounds* instead of single tokens: the block is
        (n * (spec_k + 1), B) and each live slot commits between 1 and
        spec_k + 1 tokens per round.  Greedy streams remain
        byte-identical to the non-speculative engine's.

        Robustness path: every block boundary sweeps deadlines, applies
        the pressure-shedding policy, and — when recovery is on (a
        fault injector is attached, or ``recover=True``) — snapshots
        the engine first.  A faulted block (injected exception, device
        fault lane, corruption report) restores the snapshot and
        replays: the injector fires once per (round, kind), so the
        replay runs clean and commits the exact tokens the fault-free
        run would.  Without recovery, device-flagged slots finish
        FAILED with their valid prefix; host-side faults propagate.

        Durable mode (``durable_dir``): the block commitment is
        journaled WRITE-AHEAD — fsync'd before any device work — so a
        crash anywhere inside the block re-executes it on recovery;
        every ``snapshot_every`` blocks a full snapshot (with the
        journal cursor) bounds the replay tail.
        """
        if self._journal is not None and self._jmute == 0:
            self._blocks_since_snap += 1
            if (self.snapshot_every
                    and self._blocks_since_snap > self.snapshot_every):
                # snapshot BEFORE this block's journal record: the
                # cursor must not cover a block the snapshot state
                # hasn't executed, or recovery would skip it
                self._save_durable()
                self._blocks_since_snap = 1
        with self._journal_scope("block", int(n), ahead=True):
            return self._step_many(n)

    def _step_many(self, n: int):
        self._round += 1
        self._sweep_deadlines()
        n_eff, spec_now = self._shed_policy(n)
        if self.prefix_cache:
            self._cow_guard()
        if self.paged and self._bt_dirty:
            self._flush_block_tables()
        snap = self.snapshot() if self._recover else None
        pos_before = self.pos.copy()
        injector = self.fault_injector
        attempt = 0
        fault_slots: tuple = ()
        while True:
            try:
                self._injected_slow = False
                if injector is not None:
                    injector.before_block(self._round, self)
                t0 = self.clock()
                if spec_now:
                    block, block_live, fault = self._block_spec(n_eff)
                else:
                    block, block_live, fault = self._block_decode(n_eff)
                if injector is not None:
                    injector.after_block(self._round, self)
                t1 = self.clock()
                if fault.any():
                    raise DeviceFault(np.where(fault)[0])
                break
            except (RuntimeError, FloatingPointError) as e:
                if snap is not None and attempt < self.max_replays:
                    attempt += 1
                    self.restore(snap)
                    self.counters["replays"] += 1
                    continue
                if isinstance(e, DeviceFault):
                    # no recovery path: keep the block's committed
                    # prefix and fail the flagged slots below
                    fault_slots = e.slots
                    break
                raise
        self._gen_step += n_eff
        self._clean[:] = False              # decode advanced every lane
        self.counters["decode_s"] += t1 - t0
        self.counters["gen_tokens"] += int(block_live.sum())
        if spec_now and self._spec_adapter is not None:
            # acceptance-adaptive spec_k: feed the block's measured
            # accept telemetry and re-rank k for the NEXT block.
            # Committed tokens cannot change — the verifier accepts the
            # longest argmax-matching prefix at any k — only the
            # draft-depth economics do.  A k change swaps to (or
            # traces) the (n, k) loop on the next block.
            rounds, acc = self._last_spec_obs
            self._spec_adapter.observe(rounds, acc)
            k_new = self._spec_adapter.propose()
            if k_new != self.spec_k:
                self.spec_k = int(k_new)
                self.counters["spec_k_rejits"] += 1
        # per-block straggler telemetry: wall time per fused step; the
        # injector's deterministic slow flag adds a synthetic penalty
        # so CI chaos runs flag stragglers without real sleeps
        dur = (t1 - t0) / max(1, n_eff)
        if self._injected_slow:
            dur += self._slow_penalty
            self._injected_slow = False
        if (self.straggler is not None
                and self.straggler.record(self._round, dur)):
            self.counters["straggler_blocks"] += 1
            # attribute the straggler block to every class that had a
            # request in it — the classes whose latency actually paid
            # for the slow step (meta spans slots that finished
            # mid-block too: they waited on the same sync)
            for cls in {coerce_priority(m.get("priority"))
                        for m in self._req_meta.values()}:
                self._class_count(cls, "straggler_blocks")
        # stamp generation end the moment a slot's live drops: finish()
        # may run much later (deferred retirement), and the idle gap
        # must not count against the request's decode throughput
        for s in range(self.batch):
            if not self.live[s] and s in self._req_meta:
                self._req_meta[s].setdefault("t_done", t1)
        for s in range(self.batch):
            if self.outputs[s] is not None:
                self.outputs[s].extend(
                    int(t) for t in block[block_live[:, s], s])
        if (self.spec or self.prefix_cache) and not spec_now:
            # a plain block still has to feed hist — the drafting
            # corpus under speculation, the publication token source
            # under prefix caching: commit its tokens at their absolute
            # positions (the device spec loop does this on-device)
            for s in range(self.batch):
                col = block[:, s][block_live[:, s]]
                if col.size:
                    p0 = int(pos_before[s])
                    end = min(p0 + col.size, self.hist.shape[1])
                    self.hist[s, p0:end] = col[:end - p0]
        if self.prefix_cache:
            # the invariant the spec-rewind clip guarantees (and the
            # publication condition depends on): a block only ever
            # advances the committed watermark
            assert (self.pos >= pos_before).all(), \
                "pos went backwards across a block"
            for s in range(self.batch):
                # publish live slots AND slots that finished mid-block
                # (their pages are still mapped until retirement) —
                # but never a fault-flagged slot: its pages may hold
                # the very corruption the fault lane caught
                if self.outputs[s] is not None and s not in fault_slots:
                    self._publish_committed(s)
        for s in fault_slots:
            if self.outputs[s] is not None:
                self.live[s] = False
                self.finish(s, status=RequestStatus.FAILED)
        # continuous batching: with requests waiting, retire finished
        # slots NOW and admit whatever the freed lanes/pages cover —
        # admission latency is one block, not one drained batch
        if self.waiting:
            self.retire_finished()
            self.try_admit()
        return block, block_live

    def _shed_policy(self, n: int):
        """Pressure shedding.  Returns (block size, run speculative?).

        With per-class ``slo_targets`` set, pressure is defined by SLO
        *risk* instead of the fixed pool-occupancy constant: a class is
        at risk when its oldest queued request has waited past the
        class TTFT target, or its recent completions ran below the
        class tok-per-s target.  Degradation is ordered by class —
        BATCH's budget goes first (risk anywhere sheds speculation,
        whose verify waste mostly buys batch throughput), the fused
        block is halved only when REALTIME itself is at risk (admission
        and retire checks must come sooner than anything else).

        Without targets, the legacy knob applies: past
        ``shed_threshold`` pool occupancy, halve the fused block and
        drop speculation for the block.  Both knobs are block-shape
        changes, not sampling changes — greedy streams are unaffected
        by construction."""
        if self.slo_targets:
            cls = self._slo_pressure()
            if cls is None:
                return n, self.spec
            if self.spec:
                self.counters["shed_spec_rounds"] += 1
            self._class_count(cls, "shed_rounds")
            if cls == PriorityClass.REALTIME:
                return max(1, n // 2), False
            return n, False
        if (self.shed_threshold is None or not self.paged
                or self.allocator.num_pages == 0):
            return n, self.spec
        occ = self.allocator.used_pages / self.allocator.num_pages
        if occ < self.shed_threshold:
            return n, self.spec
        if self.spec:
            self.counters["shed_spec_rounds"] += 1
        return max(1, n // 2), False

    def _slo_pressure(self) -> Optional[PriorityClass]:
        """Most important class currently behind its SLO target (None =
        every class inside budget).  Queued-wait risk reads the oldest
        FRESH queued request per class (resumes already shipped their
        first token); throughput risk reads the last few measurable
        completions of the class."""
        t = self.clock()
        worst = None
        for cls, tgt in self.slo_targets.items():
            at_risk = False
            ttft = tgt.get("ttft_s")
            if ttft is not None:
                at_risk = any(
                    not r.get("resume") and t - r["t_submit"] >= ttft
                    for r in self.waiting
                    if self._rec_priority(r) == cls)
            rate = tgt.get("tok_per_s")
            if rate is not None and not at_risk:
                recent = [r["tok_per_s"] for r in self.request_log[-8:]
                          if r.get("priority") == cls.name.lower()
                          and r["tok_per_s"] is not None]
                at_risk = bool(recent) and float(np.mean(recent)) < rate
            if at_risk and (worst is None or cls < worst):
                worst = cls
        return worst

    def _block_decode(self, n: int):
        """One fused plain-decode block (n single-token steps)."""
        loop = self._loops.get(n)
        if loop is None:
            # cache donated for the same reason as _invalidate: the
            # loop's output cache replaces self.cache unconditionally,
            # and a block must not materialize a second full KV copy
            loop = jax.jit(build_decode_loop(self.cfg, self.ctx, n),
                           donate_argnums=(1,))
            self._loops[n] = loop
        sample_params = {"temperature": _snap(self.temperature),
                         "top_k": _snap(self.top_k)}
        # all-greedy batches skip the top-k sorts / noise generation
        # (greedy consumes no PRNG state, so the stream is unaffected)
        key = self._key if (self.temperature > 0).any() else None
        self.cache, tokens, pos, live, block, block_live, fault = loop(
            self.params, self.cache, _snap(self.tokens), _snap(self.pos),
            _snap(self.live), _snap(self.stop_pos), sample_params,
            key, jnp.int32(self._gen_step), jnp.int32(self.eos_id))
        # ONE host sync for the whole block (np.asarray blocks until the
        # device values are ready; .copy() detaches the engine's mutable
        # state from the device buffers)
        block = np.asarray(block)
        block_live = np.asarray(block_live)
        self.tokens = np.asarray(tokens).copy()
        self.pos = np.asarray(pos).copy()
        self.live = np.asarray(live).copy()
        return block, block_live, np.asarray(fault)

    def _block_spec(self, n: int):
        """One fused speculative block (n draft→verify rounds).

        The whole pipeline — drafting, the single k+1-position target
        pass, acceptance, position rewind, recurrent-state rollback —
        runs inside ONE jit call; the host sees only the committed
        tokens, exactly like the plain decode block.
        """
        model_draft = self.draft is not None and self.drafter_fn is None
        # keyed by (block size, k): adaptive spec_k swaps k between
        # blocks, and each distinct pair is ONE trace — revisiting a
        # previous k is a cache hit, so re-jits are bounded by the
        # number of distinct k values the adapter ever proposes
        loop = self._spec_loops.get((n, self.spec_k))
        if loop is None:
            if self.drafter_fn is not None:
                drafter, kw = self.drafter_fn, {}
            elif model_draft:
                drafter = "model"
                kw = dict(draft_cfg=self.draft[0], draft_ctx=self.draft[2])
            else:
                drafter, kw = "ngram", {}
            loop = jax.jit(
                build_spec_decode_loop(self.cfg, self.ctx, n, self.spec_k,
                                       drafter=drafter,
                                       ngram=self.spec_ngram, **kw),
                donate_argnums=(1, 11) if model_draft else (1,))
            self._spec_loops[(n, self.spec_k)] = loop
        sample_params = {"temperature": _snap(self.temperature),
                         "top_k": _snap(self.top_k)}
        key = self._key if (self.temperature > 0).any() else None
        common = (self.params, self.cache, _snap(self.tokens),
                  _snap(self.pos), _snap(self.live), _snap(self.stop_pos),
                  sample_params, key, jnp.int32(self._gen_step),
                  jnp.int32(self.eos_id))
        if model_draft:
            out = loop(*common, self.draft[1], self.draft_cache)
        else:
            out = loop(*common, _snap(self.hist))
        (self.cache, tokens, pos, live, aux, block, block_live,
         accepted, fault) = out
        block = np.asarray(block)
        block_live = np.asarray(block_live)
        accepted = np.asarray(accepted)
        self.tokens = np.asarray(tokens).copy()
        self.pos = np.asarray(pos).copy()
        self.live = np.asarray(live).copy()
        if model_draft:
            self.draft_cache = aux
        else:
            self.hist = np.asarray(aux).copy()
        # acceptance telemetry: rounds in which a slot was live, and
        # how many drafts each such round committed (0..spec_k)
        step_live = block_live.reshape(n, self.spec_k + 1,
                                       self.batch)[:, 0]
        rounds = int(step_live.sum())
        acc = int(accepted[step_live].sum())
        self.counters["verify_steps"] += rounds
        self.counters["draft_accepted"] += acc
        # this block's delta, for the spec_k adapter — consumed by
        # step_many only after the block survives the fault check, so
        # a restored-and-replayed block is observed exactly once
        self._last_spec_obs = (rounds, acc)
        return block, block_live, np.asarray(fault)

    def step(self):
        """Per-token decode: the n=1 decode loop (baseline path)."""
        self.step_many(1)

    def finish(self, slot: int,
               status: RequestStatus = RequestStatus.COMPLETED):
        """Retire ``slot`` with a terminal ``status``.

        Whatever the slot committed lands in ``results[req_id]`` — a
        cancelled/timed-out/failed request returns its partial output
        with the status, never an exception (exceptions are for caller
        bugs and unrecoverable engine faults)."""
        with self._journal_scope("finish", int(slot), status.value):
            self._finish(slot, status)

    def _finish(self, slot: int, status: RequestStatus):
        meta = self._req_meta.pop(slot, None)
        if meta is not None:
            cls = coerce_priority(meta.get("priority"))
            done = meta.get("t_done", self.clock())
            self.request_log.append(request_row(
                ttft_s=meta["ttft_s"],
                gen_tokens=len(self.outputs[slot] or []),
                decode_s=done - meta["t_admit"], status=status,
                priority=cls))
            self.results[meta["id"]] = {
                "status": status, "tokens": list(self.outputs[slot] or [])}
            if status is RequestStatus.CANCELLED:
                self.counters["cancellations"] += 1
                self._class_count(cls, "cancellations")
            elif status is RequestStatus.TIMED_OUT:
                self.counters["timeouts"] += 1
                self._class_count(cls, "timeouts")
            elif status is RequestStatus.FAILED:
                self.counters["failures"] += 1
                self._class_count(cls, "failures")
            elif status is RequestStatus.COMPLETED:
                self._class_count(cls, "completed")
        self.done.append(self.outputs[slot])
        self.outputs[slot] = None
        self.live[slot] = False
        self.pos[slot] = 0
        self.temperature[slot] = 0.0
        self.top_k[slot] = 0
        self.stop_pos[slot] = self.max_len
        # invalidate the retired request's serving state (KV rows /
        # recurrent state) so a recycled slot can never observe a
        # previous occupant — family-aware (see models.api.invalidate_fn),
        # in-place via donation.  Paged KV needs no zeroing at all: the
        # block-table reset below makes the pages unreachable, so only
        # recurrent-state lanes (ssm/hybrid) are touched.
        self.cache = self._invalidate(self.cache, jnp.int32(slot))
        if self.paged:
            # O(pages) retirement: free-list append + host table edit;
            # the pages' contents are left as-is (never observable — a
            # new owner's visibility mask hides them until overwritten)
            # and the device table write is deferred to the next
            # consumer, so a whole retire sweep costs one upload
            if self.prefix_cache:
                # decrement-not-free: shared prefix pages lose this
                # slot's reference only — the index (and any other
                # sharer) keeps them resident and matchable
                self.allocator.free(self._slot_shared.pop(slot, []))
                self._pub.pop(slot, None)
            self.allocator.free(self._slot_pages.pop(slot, []))
            self.block_tables[slot, :] = self._trash
            self._bt_dirty = True
        self._clean[slot] = True

    # -- snapshot / restore --------------------------------------------------
    def snapshot(self) -> dict:
        """Copy-complete engine snapshot in host memory.

        Everything a block can mutate is captured — the device cache(s),
        slot arrays, allocator free-list ORDER, block tables, queue,
        outputs, results, counters, and the PRNG round (``_gen_step``)
        — so :meth:`restore` rewinds the engine to this exact block
        boundary and a replay consumes identical randomness.  The
        device cache crosses via ``device_get``: the fused loops donate
        their cache argument, so holding a device reference would alias
        freed buffers."""
        snap = {
            "cache": jax.device_get(self.cache),
            "pos": self.pos.copy(), "tokens": self.tokens.copy(),
            "live": self.live.copy(), "clean": self._clean.copy(),
            "temperature": self.temperature.copy(),
            "top_k": self.top_k.copy(),
            "stop_pos": self.stop_pos.copy(), "hist": self.hist.copy(),
            "gen_step": self._gen_step, "round": self._round,
            "next_id": self._next_id,
            "head_blocked": dict(self._head_blocked),
            "class_counters": {c: dict(row) for c, row
                               in self.class_counters.items()},
            "outputs": [None if o is None else list(o)
                        for o in self.outputs],
            "done": list(self.done),
            "waiting": [_copy_record(r) for r in self.waiting],
            "req_meta": {s: dict(m) for s, m in self._req_meta.items()},
            "results": {k: {"status": v["status"],
                            "tokens": list(v["tokens"])}
                        for k, v in self.results.items()},
            "counters": dict(self.counters),
            "request_log": [dict(r) for r in self.request_log],
        }
        if self.paged:
            snap["allocator"] = self.allocator.state()
            snap["block_tables"] = self.block_tables.copy()
            snap["bt_dirty"] = self._bt_dirty
            snap["slot_pages"] = {s: list(p)
                                  for s, p in self._slot_pages.items()}
        if self.prefix_cache:
            snap["prefix_index"] = self.prefix_index.state()
            snap["slot_shared"] = {s: list(p)
                                   for s, p in self._slot_shared.items()}
            snap["pub"] = dict(self._pub)
        if self.draft is not None:
            snap["draft_cache"] = jax.device_get(self.draft_cache)
        return snap

    def restore(self, snap: dict) -> None:
        """Rewind the engine to :meth:`snapshot` state; the snapshot
        stays pristine (everything mutable is re-copied), so one
        snapshot survives any number of replays.

        Forward-compat: snapshots written before the priority /
        warm-restart layer (PR 6-era dicts) miss the new fields —
        per-class head tracking (then a single tuple), class counters,
        prefix-index state, journal cursor.  Each defaults cleanly
        instead of KeyError'ing: old snapshots stay restorable, their
        requests simply land in STANDARD."""
        self.cache = jax.device_put(snap["cache"], self._cache_sh)
        self.pos = snap["pos"].copy()
        self.tokens = snap["tokens"].copy()
        self.live = snap["live"].copy()
        self._clean = snap["clean"].copy()
        self.temperature = snap["temperature"].copy()
        self.top_k = snap["top_k"].copy()
        self.stop_pos = snap["stop_pos"].copy()
        self.hist = snap["hist"].copy()
        self._gen_step = snap["gen_step"]
        self._round = snap["round"]
        self._next_id = snap["next_id"]
        hb = snap.get("head_blocked")
        if isinstance(hb, tuple):
            # legacy single-head tuple: a tracked head predating the
            # class split was necessarily scheduled as STANDARD-like
            # FIFO — park its count there, drop the no-head sentinel
            hb = ({PriorityClass.STANDARD: hb} if hb[0] is not None
                  else {})
        self._head_blocked = dict(hb or {})
        self.class_counters = {c: self._fresh_class_row()
                               for c in PriorityClass}
        for c, row in (snap.get("class_counters") or {}).items():
            self.class_counters[coerce_priority(c)].update(row)
        self.outputs = [None if o is None else list(o)
                        for o in snap["outputs"]]
        self.done = list(snap["done"])
        self.waiting = deque(_copy_record(r) for r in snap["waiting"])
        self._req_meta = {s: dict(m) for s, m in snap["req_meta"].items()}
        self.results = {k: {"status": v["status"],
                            "tokens": list(v["tokens"])}
                        for k, v in snap["results"].items()}
        self.counters = dict(self.counters, **snap["counters"])
        self.request_log = [dict(r) for r in snap["request_log"]]
        if self.paged:
            self.allocator.load_state(snap["allocator"])
            self.block_tables = snap["block_tables"].copy()
            self._bt_dirty = snap["bt_dirty"]
            self._slot_pages = {s: list(p)
                                for s, p in snap["slot_pages"].items()}
        if self.prefix_cache:
            idx = snap.get("prefix_index")
            if idx is not None:
                self.prefix_index.load_state(idx)
                self._slot_shared = {s: list(p) for s, p
                                     in snap["slot_shared"].items()}
                self._pub = dict(snap["pub"])
            else:
                # snapshot predates the prefix layer: start the index
                # cold — correctness never depended on it being warm
                self.prefix_index = PrefixIndex(self.allocator.page_size)
                self._slot_shared = {s: [] for s in self._slot_pages}
                self._pub = {s: (0, ROOT) for s in self._slot_pages}
        if self.draft is not None and "draft_cache" in snap:
            self.draft_cache = jax.device_put(snap["draft_cache"])

    def save_snapshot(self, directory: str, step: int = 0) -> str:
        """Persist :meth:`snapshot` to disk with the checkpoint store's
        atomics (write to ``.tmp``, ``os.replace``): a crash mid-save
        can never corrupt the newest complete snapshot."""
        from ..checkpoint.store import save_blob
        return save_blob(self.snapshot(), directory, step)

    def load_snapshot(self, directory: str,
                      step: Optional[int] = None) -> None:
        """Restore the newest (or given) on-disk snapshot."""
        from ..checkpoint.store import latest_step, load_blob
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no engine snapshot under "
                                        f"{directory}")
        self.restore(load_blob(directory, step))

    # -- crash-safe warm restart ---------------------------------------------
    def _save_durable(self) -> str:
        """One durable snapshot: :meth:`snapshot` plus the journal
        cursor (records already REFLECTED in the state — recovery
        replays only the tail past it), through save_blob's tmp +
        os.replace atomics, so a crash mid-save leaves the previous
        snapshot authoritative."""
        from ..checkpoint.store import save_blob
        snap = self.snapshot()
        snap["journal_cursor"] = self._journal.count
        path = save_blob(snap, self._durable_dir, self._durable_step)
        self._durable_step += 1
        return path

    def recover(self, directory: str) -> dict:
        """Rebuild this (freshly constructed) engine from a killed
        run's durable directory and resume journaling into it.

        Construct the engine with the SAME arguments as the dead one
        but WITHOUT ``durable_dir`` (that would truncate the evidence),
        then call ``recover``: the newest durable snapshot restores
        (if one landed), the journal tail past its cursor re-executes
        — deterministic replay of the exact submit / admit / block /
        cancel / finish / retire sequence, muted so it is not
        re-journaled — and the journal reopens for append, torn tail
        truncated.  Every in-flight stream resumes byte-identically:
        greedy decode is deterministic and sampled decode replays the
        same PRNG round (``gen_step`` rides the snapshot).

        Returns ``{"snapshot_step", "replayed"}`` telemetry.
        """
        from ..checkpoint.store import BlobLog, latest_step, load_blob
        if self._journal is not None:
            raise RuntimeError(
                "recover() on an engine constructed with durable_dir: "
                "construction already truncated the journal — build "
                "the engine without durable_dir and recover into it")
        log = BlobLog(os.path.join(directory, "journal.log"))
        step = latest_step(directory)
        cursor = 0
        if step is not None:
            snap = load_blob(directory, step)
            cursor = int(snap.get("journal_cursor", 0))
            self.restore(snap)
            self._durable_step = step + 1
        records = log.read(cursor)
        self._jmute += 1
        try:
            for rec in records:
                self._replay_event(rec)
        finally:
            self._jmute -= 1
        self._durable_dir = str(directory)
        self._journal = log
        self._blocks_since_snap = 0
        self.counters["recoveries"] += 1
        return {"snapshot_step": step, "replayed": len(records)}

    def _replay_event(self, rec: tuple) -> None:
        """Re-execute one journaled transition (muted by recover)."""
        kind = rec[0]
        if kind == "submit":
            p = rec[1]
            rid = self.submit(p["prompt"], gen_len=p["gen_len"],
                              temperature=p["temperature"],
                              top_k=p["top_k"], deadline_s=p["deadline_s"],
                              priority=p["priority"])
            if rid != p["id"]:
                raise RuntimeError(
                    f"journal replay diverged: submit re-minted id "
                    f"{rid}, journal says {p['id']} (snapshot and "
                    f"journal are from different runs?)")
        elif kind == "add":
            p = rec[1]
            self.add_requests(p["requests"], gen_len=p["gen_len"],
                              temperature=p["temperature"],
                              top_k=p["top_k"],
                              deadline_s=p["deadline_s"],
                              priority=p["priority"])
        elif kind == "admit":
            self.try_admit()
        elif kind == "block":
            self.step_many(rec[1])
        elif kind == "retire":
            self.retire_finished()
        elif kind == "cancel":
            self.cancel(rec[1])
        elif kind == "finish":
            self.finish(rec[1], status=RequestStatus(rec[2]))
        else:
            raise RuntimeError(f"unknown journal record kind {kind!r}")

    def _poison_cache(self, value: float) -> None:
        """Chaos hook: overwrite every float leaf of the serving cache.

        Block tables and integer page payloads stay intact — injected
        corruption models bad page *contents*; a structurally broken
        table is an allocator bug, tested separately."""
        val = float(value)
        self.cache = jax.tree_util.tree_map(
            lambda leaf: (jnp.full_like(leaf, val)
                          if jnp.issubdtype(leaf.dtype, jnp.floating)
                          else leaf),
            self.cache)

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate serving telemetry.

        Combines the running counters with per-request rows from
        ``request_log``: time-to-first-token (submit→first token for
        queued requests), engine decode throughput (committed tokens
        per second of block walltime, syncs included), and — under
        speculation — the mean number of drafted tokens accepted per
        verify round (committed tokens per round = that + 1).
        """
        c = dict(self.counters)
        out = {"requests": len(self.done), "admitted": c["admitted"],
               "peak_live": c["peak_live"], "gen_tokens": c["gen_tokens"],
               "decode_s": c["decode_s"],
               # None — not 0.0 — when no decode interval was measurable
               # (fake clocks, sub-resolution runs): the same rule
               # request_row applies per request, so aggregates skip the
               # value instead of reporting a fictitious stall
               "decode_tok_per_s": (c["gen_tokens"] / c["decode_s"]
                                    if c["decode_s"] > 0 else None)}
        if self.request_log:
            out["ttft_mean_s"] = float(np.mean(
                [r["ttft_s"] for r in self.request_log]))
            # rows with tok_per_s None had no measurable decode
            # interval (fake clocks, sub-resolution completions) —
            # skip them rather than average in a fictitious zero
            rates = [r["tok_per_s"] for r in self.request_log
                     if r["tok_per_s"] is not None]
            out["req_tok_per_s_mean"] = (float(np.mean(rates))
                                         if rates else 0.0)
        if self.spec:
            out["verify_steps"] = c["verify_steps"]
            out["accepted_per_step"] = (c["draft_accepted"]
                                        / max(c["verify_steps"], 1))
            # the adapted draft depth: current k, the construction cap,
            # and how many loop re-traces adaptation actually cost
            out["spec_k"] = self.spec_k
            out["spec_k_init"] = self._spec_k_init
            out["spec_k_rejits"] = c["spec_k_rejits"]
        # which model picked the knobs ("off" = legacy defaults), its
        # provenance, and the block size it resolved (None under "off":
        # the caller drives block size directly)
        out["autotune"] = self.autotune
        if self._autotune_est is not None:
            out["autotune_source"] = self._autotune_est.source
        if self.decode_block is not None:
            out["decode_block"] = self.decode_block
        if self.paged:
            # the resolved split-KV reuse factor this geometry runs
            # with (cost-model choice unless pinned by flag/ctx)
            out["kv_split"] = self.kv_split
            out["pages_per_step"] = self.pages_per_step
        if self.prefix_cache:
            out["prefix_hits"] = c["prefix_hits"]
            out["prefix_hit_pages"] = c["prefix_hit_pages"]
            out["prefix_tokens_saved"] = c["prefix_tokens_saved"]
            out["cow_copies"] = c["cow_copies"]
            out["shared_pages"] = self.allocator.shared_pages()
            out["prefix_index_pages"] = len(self.prefix_index)
        # lifecycle / robustness counters (see the PR 6 layer): how many
        # requests left through each non-happy path, and what the
        # degradation machinery did about pressure and faults
        out["queued"] = len(self.waiting)
        for k in ("preemptions", "cancellations", "timeouts", "failures",
                  "replays", "spilled_pages", "shed_spec_rounds",
                  "straggler_blocks"):
            out[k] = c[k]
        out["straggler_events"] = (len(self.straggler.events)
                                   if self.straggler is not None else 0)
        # fleet-facing health counters: how long this engine has been
        # up, how many times it was rebuilt from a journal (recover /
        # promotion), and how far a hot standby trails its journal
        # (None = no fleet heartbeat feeds it, like decode_tok_per_s
        # when unmeasurable)
        out["uptime_s"] = float(self.clock() - self._t_start)
        out["recoveries"] = c["recoveries"]
        out["journal_lag_records"] = self.journal_lag_records
        # per-class SLO telemetry: lifecycle counters plus latency
        # percentiles over the class's retired rows — only classes
        # with any activity appear, so single-class runs stay tidy
        classes = {}
        for cls in PriorityClass:
            row = dict(self.class_counters[cls])
            rows = [r for r in self.request_log
                    if r.get("priority", "standard") == cls.name.lower()]
            row["requests"] = len(rows)
            row["queued"] = sum(1 for r in self.waiting
                                if self._rec_priority(r) == cls)
            if rows:
                tt = [r["ttft_s"] for r in rows]
                row["ttft_p50_s"] = float(np.percentile(tt, 50))
                row["ttft_p99_s"] = float(np.percentile(tt, 99))
                rates = [r["tok_per_s"] for r in rows
                         if r["tok_per_s"] is not None]
                row["tok_per_s_mean"] = (float(np.mean(rates))
                                         if rates else None)
            if (row["requests"] or row["queued"]
                    or any(row[k] for k in self._fresh_class_row())):
                classes[cls.name.lower()] = row
        if classes:
            out["classes"] = classes
        if self.slo_targets:
            out["slo_targets"] = {c.name.lower(): dict(t)
                                  for c, t in self.slo_targets.items()}
        return out


def quantize_for_serving(params, ctx: QuantContext):
    """PTQ the parameter tree once, at engine construction.

    Weight matrices become QTensor (per-out-channel scales) per the
    context's precision policy; ``linear()`` then consumes them with
    zero per-forward weight-quantization work.
    """
    from ..core.quantize import ptq_params
    return ptq_params(params, ctx.policy)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--quant", default="none",
                    choices=["none", "fake", "int8"])
    ap.add_argument("--qbits", type=int, default=8)
    ap.add_argument("--lut", action="store_true")
    ap.add_argument("--f32", action="store_true")
    ap.add_argument("--reuse-factor", type=int, default=1)
    ap.add_argument("--kv-bits", type=int, default=None, choices=[8],
                    help="int8 KV cache (per-token scales)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="tokens per batched prefill step")
    ap.add_argument("--decode-block", type=int, default=None,
                    help="decode steps fused per jit call (1 = per-"
                         "token); default: the autotuner's resolved "
                         "block (8 with --autotune off)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: shared page pool + block tables; "
                         "admission metered by used tokens (dense mode "
                         "still wins at tiny batches — no indirection)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV rows per page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (default: batch*max_len/page_size, "
                         "the dense-equivalent HBM budget)")
    ap.add_argument("--kv-split", default="auto",
                    help="split-KV paged attention: number of parallel "
                         "flash-decoding partitions per slot (the kernel-"
                         "side reuse factor; 1 = today's serial page "
                         "chain, byte-identical). 'auto' picks from a "
                         "cached cost model (default)")
    ap.add_argument("--pages-per-step", default="auto",
                    help="KV pages DMA'd per grid step (multi-page tile, "
                         "double-buffered); 'auto' sizes the tile to a "
                         "~128-row MXU operand (default)")
    ap.add_argument("--autotune", default="analytic",
                    choices=("off", "analytic", "fitted"),
                    help="unified knob resolution: 'off' = legacy "
                         "defaults byte-for-byte; 'analytic' resolves "
                         "kv-split/pages-per-step/decode-block/spec-k "
                         "from the hand-set cost model and adapts "
                         "spec-k online from measured acceptance; "
                         "'fitted' does the same on least-squares "
                         "constants fitted from bench_calibrate "
                         "measurements (AUTOTUNE.json, falling back "
                         "to analytic without data). Explicit knob "
                         "flags always win")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="prefix caching over the page pool (paged "
                         "mode): committed prompt pages are indexed "
                         "and shared copy-on-write with later requests "
                         "that open with the same tokens — a hit "
                         "prefills only its suffix (inert for "
                         "recurrent families, whose state cannot skip "
                         "tokens)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k best logits (0 = off)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding: draft k tokens per round "
                         "and verify them with ONE target pass (greedy "
                         "streams stay byte-identical; helps on "
                         "repetitive/code-like continuations, costs a "
                         "little on incompressible ones)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify round (the serving-"
                         "side reuse factor: deeper = fewer target "
                         "passes when drafts hit, more waste when not)")
    ap.add_argument("--spec-draft", default=None,
                    help="arch name of a (smaller) draft model sharing "
                         "the target's vocab (implies --spec); default = "
                         "prompt-lookup self-speculation, no second model")
    ap.add_argument("--spec-ngram", type=int, default=2,
                    help="context length of the prompt-lookup match")
    ap.add_argument("--preempt", action="store_true",
                    help="preempt-and-spill (paged mode): under page "
                         "pressure spill a running victim's pages to "
                         "host memory and resume it later — graceful "
                         "degradation instead of head-of-line blocking")
    ap.add_argument("--shed-threshold", type=float, default=None,
                    help="page-pool occupancy (0..1) past which the "
                         "engine sheds pressure: halves the decode "
                         "block and skips speculation for the block")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request TTL from submission; past it the "
                         "request times out at the next block boundary "
                         "and returns its partial output")
    ap.add_argument("--priority-class", default="standard",
                    choices=[c.name.lower() for c in PriorityClass],
                    help="SLO class for the submitted requests: the "
                         "queue serves realtime > standard > batch "
                         "(FIFO within a class), victims spill batch "
                         "first, and per-class SLO targets drive the "
                         "shed knobs")
    ap.add_argument("--slo-ttft-s", type=float, default=None,
                    help="TTFT target (seconds) for the REALTIME "
                         "class; a realtime request queued past it "
                         "escalates preemption immediately and puts "
                         "the engine in SLO-shed mode (drops spec, "
                         "halves the block) until it is served")
    ap.add_argument("--slo-tok-per-s", type=float, default=None,
                    help="decode-throughput target (tok/s) for the "
                         "REALTIME class, driving the same shed knobs")
    ap.add_argument("--durable-dir", default=None,
                    help="crash-safe warm restart: journal every "
                         "request/block event (fsync'd write-ahead "
                         "log) and snapshot the engine every "
                         "--snapshot-every blocks under this "
                         "directory; rebuild a killed engine with "
                         "Engine.recover(dir)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="blocks between durable snapshots "
                         "(--durable-dir mode); smaller = shorter "
                         "replay tail, more snapshot IO")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a Fleet of N engine replicas "
                         "with class-aware least-pressure routing and "
                         "heartbeat failure detection (1 = single "
                         "engine, no fleet layer)")
    ap.add_argument("--standby-dir", default=None,
                    help="journal-shipped hot standby (implies a "
                         "fleet): the primary journals under this "
                         "directory, a warm standby tails it within "
                         "--replicas' bounded lag, and on primary "
                         "death the fleet promotes the standby and "
                         "resumes every in-flight stream "
                         "byte-identically")
    ap.add_argument("--class-quota", action="append", default=None,
                    metavar="CLASS:KIND=FRACTION",
                    help="partition the page pool per SLO class "
                         "(repeatable; needs --paged): e.g. "
                         "'realtime:floor=0.25' reserves a quarter of "
                         "the pages for realtime, 'batch:cap=0.5' "
                         "caps batch at half — a batch flood can "
                         "then never evict the realtime working set")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    ctx = build_ctx(args)
    mesh = make_local_mesh(model=args.model_parallel)
    fam = get_family(cfg)

    with use_mesh(mesh):
        params = fam.init(jax.random.PRNGKey(args.seed), cfg)
        if args.quant == "int8":
            # the fused pipeline's first leg: weights quantized ONCE here
            params = quantize_for_serving(params, ctx)
        p_sh = named(param_specs(params, mesh), mesh)
        params = jax.device_put(params, p_sh)
        if args.spec_draft:
            args.spec = True                    # a drafter implies --spec
        spec_draft = None
        if args.spec_draft:
            d_cfg = get_config(args.spec_draft)
            if args.smoke:
                d_cfg = d_cfg.smoke()
            d_params = get_family(d_cfg).init(
                jax.random.PRNGKey(args.seed + 1), d_cfg)
            spec_draft = (d_cfg, d_params, ctx)
        max_len = args.prompt_len + args.gen_len + 1

        def knob(v):
            return "auto" if v == "auto" else int(v)

        eng_kw = dict(batch=args.batch,
                      max_len=max_len, kv_bits=args.kv_bits,
                      prefill_chunk=args.prefill_chunk, seed=args.seed,
                      paged=args.paged, page_size=args.page_size,
                      num_pages=args.num_pages,
                      kv_split=knob(args.kv_split),
                      pages_per_step=knob(args.pages_per_step),
                      prefix_cache=args.prefix_cache,
                      autotune=args.autotune,
                      spec=args.spec,
                      spec_k=args.spec_k, spec_draft=spec_draft,
                      spec_ngram=args.spec_ngram, preempt=args.preempt,
                      shed_threshold=args.shed_threshold,
                      class_quotas=_parse_class_quotas(args.class_quota),
                      slo_targets=(
                          {"realtime": {"ttft_s": args.slo_ttft_s,
                                        "tok_per_s": args.slo_tok_per_s}}
                          if (args.slo_ttft_s is not None
                              or args.slo_tok_per_s is not None) else None),
                      durable_dir=args.durable_dir,
                      snapshot_every=args.snapshot_every)

        def make_engine(**over):
            return Engine(cfg, ctx, params, mesh, **dict(eng_kw, **over))

        fleet = None
        if args.replicas > 1 or args.standby_dir is not None:
            from .fleet import Fleet
            # the fleet owns durability (primary journals under
            # --standby-dir); replicas sharing one --durable-dir would
            # clobber each other's journal
            eng_kw["durable_dir"] = None
            fleet = Fleet(make_engine, args.replicas,
                          standby_dir=args.standby_dir)
            eng = fleet.replicas[0]
        else:
            eng = make_engine()

        src = SyntheticLM(cfg.vocab, seed=args.seed)
        prompts = [src.tokens(i, 1, args.prompt_len)[0, :-1]
                   for i in range(args.requests)]
        # explicit flag > autotuner-resolved block > the legacy default
        block = max(1, args.decode_block if args.decode_block is not None
                    else (eng.decode_block or 8))
        t0 = time.perf_counter()
        gen_tokens = 0
        # continuous batching through the admission queue: every request
        # is submitted up front; step_many retires finished slots and
        # admits whatever the freed lanes (and, paged, freed pages)
        # cover, one block's latency after they free up
        if fleet is not None:
            for p in prompts:
                fleet.submit(p, gen_len=args.gen_len,
                             temperature=args.temperature,
                             top_k=args.top_k,
                             deadline_s=args.deadline_s,
                             priority=args.priority_class)
            fleet.try_admit()
            fleet.drain(block=block)
            eng = fleet.replicas[0]     # promotion may have swapped it
            gen_tokens = sum(
                s["gen_tokens"] for s in fleet.stats()["per_replica"]
                if s is not None)
        else:
            for p in prompts:
                eng.submit(p, gen_len=args.gen_len,
                           temperature=args.temperature, top_k=args.top_k,
                           deadline_s=args.deadline_s,
                           priority=args.priority_class)
            eng.try_admit()
            while eng.live.any() or eng.waiting:
                _, block_live = eng.step_many(block)
                gen_tokens += int(block_live.sum())
            eng.retire_finished()
        dt = time.perf_counter() - t0
        paged_note = (f" paged(ps={eng.allocator.page_size},"
                      f"pages={eng.allocator.num_pages},"
                      f"kv_split={eng.kv_split},"
                      f"pages_per_step={eng.pages_per_step})"
                      if args.paged else " dense")
        spec_note = (f" spec(k={eng.spec_k},"
                     f"draft={args.spec_draft or 'ngram'})"
                     if args.spec else "")
        st = eng.stats()
        served = (len(fleet.results) if fleet is not None
                  else len(eng.done))
        print(f"served {served} requests, {gen_tokens} tokens in "
              f"{dt:.2f}s ({gen_tokens / dt:.1f} tok/s), "
              f"quant={args.quant} lut={args.lut} kv_bits={args.kv_bits} "
              f"decode_block={block}{paged_note}{spec_note} "
              f"peak_live={st['peak_live']}")
        if fleet is not None:
            fs = fleet.stats()
            print(f"-- fleet: {args.replicas} replicas "
                  f"(states {','.join(fs['states'])}), "
                  f"standby={'on' if fs['standby'] else 'off'}, "
                  f"deaths={fs['deaths']} promotions={fs['promotions']} "
                  f"redispatched={fs['redispatched']}")
        print_stats_table(st)
    return fleet.results if fleet is not None else eng.done


def _parse_class_quotas(specs) -> Optional[dict]:
    """``--class-quota CLASS:KIND=FRACTION`` strings -> the nested dict
    :func:`normalize_class_quotas` validates (None when no flag given)."""
    if not specs:
        return None
    quotas: Dict[str, Dict[str, float]] = {}
    for spec in specs:
        head, sep, val = spec.partition("=")
        cls, csep, kind = head.partition(":")
        if not sep or not csep or not cls or not kind:
            raise SystemExit(
                f"--class-quota {spec!r}: expected CLASS:KIND=FRACTION "
                f"(e.g. realtime:floor=0.25)")
        try:
            frac = float(val)
        except ValueError:
            raise SystemExit(
                f"--class-quota {spec!r}: fraction {val!r} is not a number")
        quotas.setdefault(cls, {})[kind] = frac
    return normalize_class_quotas(quotas)


def print_stats_table(st: dict) -> None:
    """Summary table of :meth:`Engine.stats` rows (serve CLI + examples)."""
    tps = st["decode_tok_per_s"]
    rows = [("requests served", f"{st['requests']}"),
            ("peak concurrent", f"{st['peak_live']}"),
            ("generated tokens", f"{st['gen_tokens']}"),
            # None = no measurable decode interval; "n/a" beats a
            # fictitious 0.0 that reads as a stalled engine
            ("decode tok/s", "n/a" if tps is None else f"{tps:.1f}")]
    if "ttft_mean_s" in st:
        rows.append(("mean TTFT", f"{st['ttft_mean_s'] * 1e3:.1f} ms"))
    if "uptime_s" in st:
        rows.append(("uptime", f"{st['uptime_s']:.2f} s"))
    if "accepted_per_step" in st:
        rows.append(("verify rounds", f"{st['verify_steps']}"))
        rows.append(("drafts accepted/round",
                     f"{st['accepted_per_step']:.2f}"))
    if st.get("autotune", "off") != "off":
        src = st.get("autotune_source", st["autotune"])
        rows.append(("autotune", f"{st['autotune']} ({src})"))
    if "spec_k" in st:
        rows.append(("spec k (now/cap/re-jits)",
                     f"{st['spec_k']}/{st['spec_k_init']}"
                     f"/{st['spec_k_rejits']}"))
    if "decode_block" in st:
        rows.append(("resolved decode block", f"{st['decode_block']}"))
    if "kv_split" in st:
        rows.append(("kv split / pages per step",
                     f"{st['kv_split']} / {st['pages_per_step']}"))
    for key, label in (("prefix_hits", "prefix-cache hits"),
                       ("prefix_tokens_saved", "prefill tokens skipped"),
                       ("cow_copies", "CoW page copies"),
                       ("shared_pages", "shared pages now"),
                       ("prefix_index_pages", "cached prefix pages"),
                       ("preemptions", "preemptions"),
                       ("spilled_pages", "pages spilled"),
                       ("cancellations", "cancellations"),
                       ("timeouts", "timeouts"),
                       ("failures", "failures"),
                       ("replays", "fault replays"),
                       ("recoveries", "recoveries"),
                       ("journal_lag_records", "journal lag (records)"),
                       ("shed_spec_rounds", "spec rounds shed"),
                       ("straggler_blocks", "straggler blocks")):
        if st.get(key):
            rows.append((label, f"{st[key]}"))
    # per-class lines only when more than one class saw traffic (or an
    # SLO target is set): single-class runs already read off the totals
    classes = st.get("classes", {})
    if len(classes) > 1 or "slo_targets" in st:
        for name, c in classes.items():
            p99 = c.get("ttft_p99_s")
            rows.append((
                f"class {name}",
                f"{c['requests']} done, {c['queued']} queued, "
                f"{c['preemptions']} preempted"
                + (f", p99 TTFT {p99 * 1e3:.1f} ms"
                   if p99 is not None else "")))
    width = max(len(k) for k, _ in rows)
    print("-- serving stats " + "-" * (width + 8))
    for k, v in rows:
        print(f"  {k:<{width}}  {v}")


if __name__ == "__main__":
    main()
