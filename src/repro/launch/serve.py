"""Serving entrypoint: batched prefill + decode with continuous batching.

The paper's deployment scenario — a *quantized inference accelerator* —
realized at framework level: PTQ'd weights (int8 / fake-quant ac_fixed /
minifloat), LUT activations, batched requests with slot-based continuous
batching (a finished sequence's slot is refilled by the next queued
request without draining the batch).

Usage (CPU-scale)::

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 16 --batch 4 --prompt-len 32 --gen-len 16 --quant fake
"""

from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.pipeline import SyntheticLM, make_batch
from ..dist.constrain import use_mesh
from ..dist.sharding import cache_specs, named, param_specs
from ..models.api import get_family
from ..nn.context import QuantContext
from ..train.step import build_prefill_step, build_serve_step
from .mesh import make_local_mesh
from .train import build_ctx


class Engine:
    """Slot-based continuous batching engine over prefill/decode steps."""

    def __init__(self, cfg, ctx, params, mesh, *, batch: int, max_len: int,
                 kv_bits=None):
        self.cfg, self.ctx, self.mesh = cfg, ctx, mesh
        self.batch, self.max_len = batch, max_len
        fam = get_family(cfg)
        self.params = params
        cache_dtype = jnp.int8 if kv_bits == 8 else jnp.float32
        self.cache = fam.init_cache(cfg, batch, max_len, cache_dtype)
        c_sh = named(cache_specs(self.cache, mesh), mesh)
        self.cache = jax.device_put(self.cache, c_sh)
        self.decode = jax.jit(build_serve_step(cfg, ctx))
        self.prefill = jax.jit(build_prefill_step(cfg, ctx))
        self.pos = np.zeros((batch,), np.int32)
        self.live = np.zeros((batch,), bool)
        self.tokens = np.zeros((batch, 1), np.int32)
        self.outputs: List[Optional[list]] = [None] * batch
        self.done: List[list] = []

    def add_request(self, slot: int, prompt: np.ndarray):
        """Prefill one request into ``slot`` (per-slot chunked prefill)."""
        fam = get_family(self.cfg)
        # single-slot prefill: run decode steps over the prompt tokens
        # (slot-local; production would use a dedicated bucketed prefill)
        for t in range(prompt.shape[0]):
            tok = np.zeros((self.batch, 1), np.int32)
            tok[slot, 0] = prompt[t]
            logits, self.cache = self.decode(
                self.params, self.cache, jnp.asarray(tok),
                jnp.asarray(self.pos))
            self.pos[slot] += 1
        self.live[slot] = True
        self.outputs[slot] = []
        self.tokens[slot, 0] = int(jnp.argmax(logits[slot, -1]))

    def step(self):
        logits, self.cache = self.decode(
            self.params, self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for s in range(self.batch):
            if self.live[s]:
                self.outputs[s].append(int(self.tokens[s, 0]))
                self.tokens[s, 0] = nxt[s]
                self.pos[s] += 1

    def finish(self, slot: int):
        self.done.append(self.outputs[slot])
        self.outputs[slot] = None
        self.live[slot] = False
        self.pos[slot] = 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--quant", default="none",
                    choices=["none", "fake", "int8"])
    ap.add_argument("--qbits", type=int, default=8)
    ap.add_argument("--lut", action="store_true")
    ap.add_argument("--f32", action="store_true")
    ap.add_argument("--reuse-factor", type=int, default=1)
    ap.add_argument("--kv-bits", type=int, default=None, choices=[8],
                    help="int8 KV cache (per-token scales)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    ctx = build_ctx(args)
    mesh = make_local_mesh(model=args.model_parallel)
    fam = get_family(cfg)

    with use_mesh(mesh):
        params = fam.init(jax.random.PRNGKey(args.seed), cfg)
        p_sh = named(param_specs(params, mesh), mesh)
        params = jax.device_put(params, p_sh)
        max_len = args.prompt_len + args.gen_len + 1
        eng = Engine(cfg, ctx, params, mesh, batch=args.batch,
                     max_len=max_len, kv_bits=args.kv_bits)

        src = SyntheticLM(cfg.vocab, seed=args.seed)
        prompts = [src.tokens(i, 1, args.prompt_len)[0, :-1]
                   for i in range(args.requests)]
        queue = list(range(args.requests))
        t0 = time.perf_counter()
        gen_tokens = 0
        # continuous batching: fill all slots, refill as slots finish
        for s in range(min(args.batch, len(queue))):
            eng.add_request(s, prompts[queue.pop(0)])
        while eng.live.any():
            eng.step()
            gen_tokens += int(eng.live.sum())
            for s in range(args.batch):
                if eng.live[s] and len(eng.outputs[s]) >= args.gen_len:
                    eng.finish(s)
                    if queue:
                        eng.add_request(s, prompts[queue.pop(0)])
        dt = time.perf_counter() - t0
        print(f"served {len(eng.done)} requests, {gen_tokens} tokens in "
              f"{dt:.2f}s ({gen_tokens / dt:.1f} tok/s), "
              f"quant={args.quant} lut={args.lut} kv_bits={args.kv_bits}")
    return eng.done


if __name__ == "__main__":
    main()
