"""Request lifecycle for the serving engine: states, records, deadlines.

The de-specialization thesis applied to *failure* shapes: one request
abstraction has to survive every way a request can leave the engine,
not just the happy path.  A request moves through

::

    QUEUED ──> RUNNING ──> COMPLETED
       │          │ ├────> CANCELLED   (cancel(req_id))
       │          │ ├────> TIMED_OUT   (deadline passed at a block boundary)
       │          │ ├────> FAILED      (device fault lane, no recovery path)
       │          │ └────> PREEMPTED ──> QUEUED   (pages spilled to host)
       ├────────> CANCELLED
       └────────> TIMED_OUT

Every terminal transition returns whatever tokens the request committed
so far (``Engine.results[req_id]``) instead of raising — exceptions are
reserved for caller errors (bad input at ``submit``) and for genuinely
unrecoverable engine faults.  ``PREEMPTED`` is the one non-terminal
exit: the request's pages are copied to host memory and it re-enters
the queue carrying its full restart state (position, held token,
partial outputs, drafting history, spilled page payloads, recurrent
lane), so resumption is a restore, never a recompute.
"""

from __future__ import annotations

import enum
import time

import numpy as np

__all__ = ["RequestStatus", "TERMINAL_STATUSES", "validate_request",
           "request_row"]


class RequestStatus(str, enum.Enum):
    """Where a request is in its lifecycle (str-valued for JSON/stats)."""

    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    COMPLETED = "completed"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    FAILED = "failed"


#: statuses a request never leaves
TERMINAL_STATUSES = frozenset({
    RequestStatus.COMPLETED, RequestStatus.CANCELLED,
    RequestStatus.TIMED_OUT, RequestStatus.FAILED,
})


def validate_request(prompt, *, vocab: int, temperature=None, top_k=None,
                     deadline_s=None) -> np.ndarray:
    """Admission-time input validation; returns the prompt as int32.

    Garbage that used to flow straight into the embedding gather is
    rejected at the API boundary instead:

    * non-integer token ids (a float array with fractional values would
      silently truncate to different tokens than the caller sent),
    * out-of-vocab ids (negative, or >= vocab: the gather would read a
      neighbouring row — worse than an error, a *wrong answer*),
    * negative ``temperature`` (<= 0 means greedy by engine convention,
      but a negative value is always a caller bug: it would flip the
      distribution toward the *least* likely tokens),
    * negative ``top_k`` (0 disables the filter; negative has no
      meaning), and
    * non-positive ``deadline_s`` (the request could never run).

    ``temperature``/``top_k``/``deadline_s`` accept the same
    scalar-or-``{slot: v}`` forms ``add_requests`` does; every value is
    checked individually (``None`` entries mean "no limit" and are
    skipped, never compared).  Collapsing a dict to one representative
    — an earlier revision validated ``min(deadline_s.values())`` — is
    exactly the specialization bug this layer exists to prevent: it
    crashes on mixed ``None`` entries and hides which request was
    invalid.
    """
    p = np.asarray(prompt)
    if p.ndim > 1:
        p = p.reshape(-1)
    if p.size and not np.issubdtype(p.dtype, np.integer):
        if not (np.issubdtype(p.dtype, np.floating)
                and np.all(np.isfinite(p)) and np.all(p == np.floor(p))):
            raise ValueError(
                f"prompt token ids must be integers (got dtype {p.dtype} "
                f"with non-integral values); refusing to truncate")
    p = p.astype(np.int64, copy=False)
    if p.size and (int(p.min()) < 0 or int(p.max()) >= vocab):
        bad = p[(p < 0) | (p >= vocab)][0]
        raise ValueError(
            f"prompt contains out-of-vocab token id {int(bad)} "
            f"(vocab={vocab}); the embedding gather would read garbage")

    def each(v, name):
        vals = v.values() if isinstance(v, dict) else [v]
        for x in vals:
            if x is None:
                continue
            yield name, x

    for name, x in each(temperature, "temperature"):
        if float(x) < 0:
            raise ValueError(
                f"negative temperature {x} (0 = greedy; negative would "
                f"invert the sampling distribution)")
    for name, x in each(top_k, "top_k"):
        if int(x) < 0:
            raise ValueError(f"negative top_k {x} (0 disables the filter)")
    for name, x in each(deadline_s, "deadline_s"):
        if float(x) <= 0:
            raise ValueError(f"deadline_s must be positive (got {x})")
    return p.astype(np.int32)


def request_row(*, ttft_s: float, gen_tokens: int, decode_s: float,
                status: RequestStatus) -> dict:
    """One ``Engine.request_log`` row for a retired request.

    ``tok_per_s`` is ``None`` — not ``0.0`` — when the decode interval
    is not measurable (``decode_s == 0`` under fake clocks, or a request
    that finished within the clock's resolution): a literal zero would
    read as a stalled request and drag throughput means toward zero, so
    aggregates must *skip* unmeasurable rows rather than average them.
    """
    return {"ttft_s": float(ttft_s), "gen_tokens": int(gen_tokens),
            "decode_s": float(decode_s), "status": status.value,
            "tok_per_s": (gen_tokens / decode_s) if decode_s > 0
            else None}


def now() -> float:
    """Engine wall clock (monkeypatchable seam for deadline tests)."""
    return time.perf_counter()
