"""Request lifecycle for the serving engine: states, records, deadlines.

The de-specialization thesis applied to *failure* shapes: one request
abstraction has to survive every way a request can leave the engine,
not just the happy path.  A request moves through

::

    QUEUED ──> RUNNING ──> COMPLETED
       │          │ ├────> CANCELLED   (cancel(req_id))
       │          │ ├────> TIMED_OUT   (deadline passed at a block boundary)
       │          │ ├────> FAILED      (device fault lane, no recovery path)
       │          │ └────> PREEMPTED ──> QUEUED   (pages spilled to host)
       ├────────> CANCELLED
       └────────> TIMED_OUT

Every terminal transition returns whatever tokens the request committed
so far (``Engine.results[req_id]``) instead of raising — exceptions are
reserved for caller errors (bad input at ``submit``) and for genuinely
unrecoverable engine faults.  ``PREEMPTED`` is the one non-terminal
exit: the request's pages are copied to host memory and it re-enters
the queue carrying its full restart state (position, held token,
partial outputs, drafting history, spilled page payloads, recurrent
lane), so resumption is a restore, never a recompute.
"""

from __future__ import annotations

import enum
import time

import numpy as np

__all__ = ["RequestStatus", "TERMINAL_STATUSES", "PriorityClass",
           "coerce_priority", "normalize_slo_targets",
           "normalize_class_quotas", "validate_request", "request_row"]


class RequestStatus(str, enum.Enum):
    """Where a request is in its lifecycle (str-valued for JSON/stats)."""

    QUEUED = "queued"
    RUNNING = "running"
    PREEMPTED = "preempted"
    COMPLETED = "completed"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    FAILED = "failed"


#: statuses a request never leaves
TERMINAL_STATUSES = frozenset({
    RequestStatus.COMPLETED, RequestStatus.CANCELLED,
    RequestStatus.TIMED_OUT, RequestStatus.FAILED,
})


class PriorityClass(enum.IntEnum):
    """SLO class of a request — a *scheduling* property, never a
    sampling one (the same prompt yields the same tokens in every
    class; only admission order, victim order and shed budget differ).

    Lower value = more important.  The ordering is load-bearing in
    three places: the admission queue serves the lowest-valued
    non-empty class first (FIFO within a class), preempt-and-spill
    ranks victims by *descending* value (BATCH pages spill before a
    REALTIME request ever loses one), and SLO-driven shedding
    sacrifices the budgets that serve high-valued classes first.
    """

    REALTIME = 0
    STANDARD = 1
    BATCH = 2


def coerce_priority(value) -> PriorityClass:
    """Accept a :class:`PriorityClass`, its int value, or its name
    (any case); reject everything else with the valid choices named.

    ``None`` means "caller didn't say" and maps to STANDARD — the
    middle class, so defaulted traffic neither starves batch work nor
    jumps ahead of explicitly-realtime requests.
    """
    if value is None:
        return PriorityClass.STANDARD
    if isinstance(value, PriorityClass):
        return value
    if isinstance(value, str):
        try:
            return PriorityClass[value.strip().upper()]
        except KeyError:
            raise ValueError(
                f"unknown priority class {value!r} (choices: "
                f"{[c.name.lower() for c in PriorityClass]})") from None
    if isinstance(value, (int, np.integer)) and not isinstance(value, bool):
        try:
            return PriorityClass(int(value))
        except ValueError:
            raise ValueError(
                f"priority class value {int(value)} out of range "
                f"(valid: {[int(c) for c in PriorityClass]})") from None
    raise ValueError(
        f"priority must be a PriorityClass, its name or its int value "
        f"(got {type(value).__name__})")


def normalize_slo_targets(targets) -> dict:
    """Validate per-class SLO targets into ``{PriorityClass: {...}}``.

    ``targets`` maps a class (enum / name / int, via
    :func:`coerce_priority`) to ``{"ttft_s": s, "tok_per_s": r}``;
    either key may be absent or ``None`` (no target on that axis).
    A non-positive target is rejected like a non-positive
    ``deadline_s`` — it could never be met, so it is always a caller
    bug, and a zero TTFT target would make every queued request
    "at risk" forever (permanent shedding).
    """
    out = {}
    for key, tgt in (targets or {}).items():
        cls = coerce_priority(key)
        if tgt is None:
            continue
        if not isinstance(tgt, dict):
            raise ValueError(
                f"SLO target for {cls.name.lower()} must be a dict "
                f"with 'ttft_s'/'tok_per_s' keys (got "
                f"{type(tgt).__name__})")
        unknown = set(tgt) - {"ttft_s", "tok_per_s"}
        if unknown:
            raise ValueError(
                f"unknown SLO target keys {sorted(unknown)} for "
                f"{cls.name.lower()} (valid: ttft_s, tok_per_s)")
        clean = {}
        for k in ("ttft_s", "tok_per_s"):
            v = tgt.get(k)
            if v is None:
                continue
            if float(v) <= 0:
                raise ValueError(
                    f"SLO {k} for class {cls.name.lower()} must be "
                    f"positive (got {v})")
            clean[k] = float(v)
        if clean:
            out[cls] = clean
    return out


def normalize_class_quotas(quotas) -> dict:
    """Validate per-class page-pool quotas into
    ``{PriorityClass: {"floor": f, "cap": f}}``.

    ``quotas`` maps a class (enum / name / int, via
    :func:`coerce_priority`) to ``{"floor": fraction, "cap": fraction}``:

    * ``floor`` *reserves* that fraction of the pool — other classes may
      never allocate into it, so the class always has room to admit
      (the REALTIME working-set guarantee);
    * ``cap`` *bounds* the fraction the class may occupy at admission
      (a soft cap: it blocks new allocations, it never evicts running
      requests when traffic shifts — the BATCH-flood limiter).

    Fractions must lie in (0, 1]: zero is a no-op spelled as a
    guarantee, above one can never be satisfied.  The floors must sum
    to at most 1 (you cannot reserve more than the pool), and a floor
    above the same class's cap is contradictory (the class could never
    fill its own reservation).
    """
    out: dict = {}
    total_floor = 0.0
    for key, quota in (quotas or {}).items():
        cls = coerce_priority(key)
        if quota is None:
            continue
        if not isinstance(quota, dict):
            raise ValueError(
                f"class quota for {cls.name.lower()} must be a dict "
                f"with 'floor'/'cap' keys (got {type(quota).__name__})")
        unknown = set(quota) - {"floor", "cap"}
        if unknown:
            raise ValueError(
                f"unknown class-quota keys {sorted(unknown)} for "
                f"{cls.name.lower()} (valid: floor, cap)")
        if cls in out:
            raise ValueError(
                f"duplicate class quota for {cls.name.lower()} "
                f"(the same class named twice under different spellings)")
        clean = {}
        for k in ("floor", "cap"):
            v = quota.get(k)
            if v is None:
                continue
            v = float(v)
            if not 0.0 < v <= 1.0:
                raise ValueError(
                    f"class-quota {k} for {cls.name.lower()} must lie in "
                    f"(0, 1] (got {v}): 0 is a no-op spelled as a "
                    f"guarantee, above 1 can never be satisfied")
            clean[k] = v
        if ("floor" in clean and "cap" in clean
                and clean["floor"] > clean["cap"]):
            raise ValueError(
                f"class-quota floor {clean['floor']} above cap "
                f"{clean['cap']} for {cls.name.lower()}: the class could "
                f"never fill its own reservation")
        total_floor += clean.get("floor", 0.0)
        if clean:
            out[cls] = clean
    if total_floor > 1.0 + 1e-9:
        raise ValueError(
            f"class-quota floors sum to {total_floor:.3f} > 1: cannot "
            f"reserve more than the whole pool")
    return out


def validate_request(prompt, *, vocab: int, temperature=None, top_k=None,
                     deadline_s=None, priority=None) -> np.ndarray:
    """Admission-time input validation; returns the prompt as int32.

    Garbage that used to flow straight into the embedding gather is
    rejected at the API boundary instead:

    * non-integer token ids (a float array with fractional values would
      silently truncate to different tokens than the caller sent),
    * out-of-vocab ids (negative, or >= vocab: the gather would read a
      neighbouring row — worse than an error, a *wrong answer*),
    * negative ``temperature`` (<= 0 means greedy by engine convention,
      but a negative value is always a caller bug: it would flip the
      distribution toward the *least* likely tokens),
    * negative ``top_k`` (0 disables the filter; negative has no
      meaning),
    * non-positive ``deadline_s`` (the request could never run), and
    * unknown ``priority`` classes (a typo'd class name or an
      out-of-range value would silently schedule the request in a
      class the caller never meant — see :func:`coerce_priority`).

    ``temperature``/``top_k``/``deadline_s`` accept the same
    scalar-or-``{slot: v}`` forms ``add_requests`` does; every value is
    checked individually (``None`` entries mean "no limit" and are
    skipped, never compared).  Collapsing a dict to one representative
    — an earlier revision validated ``min(deadline_s.values())`` — is
    exactly the specialization bug this layer exists to prevent: it
    crashes on mixed ``None`` entries and hides which request was
    invalid.
    """
    p = np.asarray(prompt)
    if p.ndim > 1:
        p = p.reshape(-1)
    if p.size and not np.issubdtype(p.dtype, np.integer):
        if not (np.issubdtype(p.dtype, np.floating)
                and np.all(np.isfinite(p)) and np.all(p == np.floor(p))):
            raise ValueError(
                f"prompt token ids must be integers (got dtype {p.dtype} "
                f"with non-integral values); refusing to truncate")
    p = p.astype(np.int64, copy=False)
    if p.size and (int(p.min()) < 0 or int(p.max()) >= vocab):
        bad = p[(p < 0) | (p >= vocab)][0]
        raise ValueError(
            f"prompt contains out-of-vocab token id {int(bad)} "
            f"(vocab={vocab}); the embedding gather would read garbage")

    def each(v, name):
        vals = v.values() if isinstance(v, dict) else [v]
        for x in vals:
            if x is None:
                continue
            yield name, x

    for name, x in each(temperature, "temperature"):
        if float(x) < 0:
            raise ValueError(
                f"negative temperature {x} (0 = greedy; negative would "
                f"invert the sampling distribution)")
    for name, x in each(top_k, "top_k"):
        if int(x) < 0:
            raise ValueError(f"negative top_k {x} (0 disables the filter)")
    for name, x in each(deadline_s, "deadline_s"):
        if float(x) <= 0:
            raise ValueError(f"deadline_s must be positive (got {x})")
    for name, x in each(priority, "priority"):
        coerce_priority(x)          # unknown/out-of-range classes raise
    return p.astype(np.int32)


def request_row(*, ttft_s: float, gen_tokens: int, decode_s: float,
                status: RequestStatus, priority=None) -> dict:
    """One ``Engine.request_log`` row for a retired request.

    ``tok_per_s`` is ``None`` — not ``0.0`` — when the decode interval
    is not measurable (``decode_s == 0`` under fake clocks, or a request
    that finished within the clock's resolution): a literal zero would
    read as a stalled request and drag throughput means toward zero, so
    aggregates must *skip* unmeasurable rows rather than average them.

    ``priority`` lands as the class *name* (``"realtime"`` /
    ``"standard"`` / ``"batch"``) so rows stay JSON-serializable like
    ``status``; per-class percentile aggregation keys on it.
    """
    return {"ttft_s": float(ttft_s), "gen_tokens": int(gen_tokens),
            "decode_s": float(decode_s), "status": status.value,
            "priority": coerce_priority(priority).name.lower(),
            "tok_per_s": (gen_tokens / decode_s) if decode_s > 0
            else None}


def now() -> float:
    """Engine wall clock (monkeypatchable seam for deadline tests)."""
    return time.perf_counter()
