"""Trip-count-aware cost analysis over post-optimization HLO text.

Why this exists: ``compiled.cost_analysis()`` counts every while-loop BODY
exactly once (verified empirically: a 16-step ``lax.scan`` over a matmul
reports 1/16th of the unrolled flops).  Our programs are scan-heavy by
design — layer stacks, gradient-accumulation microbatches, attention
chunks — so flops, bytes *and in-loop collectives* would all be
undercounted by 1–2 orders of magnitude without correction.

Method:
  pass 1 — build a symbol table: instruction name → result shape (operand
           references in CPU post-opt HLO are bare ``%name``s);
  pass 2 — per-computation costs:
           * dot flops = 2 · result_elements · contracted_elements
             (contraction sizes from ``lhs_contracting_dims`` + the lhs
             operand's shape),
           * elementwise flops = result elements (guard rail; dots and
             collectives dominate every roofline we report),
           * bytes = result + operand bytes per instruction, with pure
             data-movement ops (parameter/tuple/gte/bitcast/copy/reshape/
             broadcast/transpose) free — approximating TPU fusion,
           * collective wire bytes under the ring model, replica-group
             aware;
  pass 3 — propagate through the call graph: ``while`` bodies scale by
           ``backend_config.known_trip_count`` (fallback: largest constant
           in the loop condition), fusions/calls/reduces by 1.

Result: per-device cost of ONE step, loop-corrected.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost", "per_op_breakdown",
           "count_jaxpr_primitive"]


def count_jaxpr_primitive(jaxpr, name: str) -> int:
    """Recursively count equations with primitive ``name``, descending
    into every sub-jaxpr carried in params (pjit/scan/cond/custom calls).

    Static-graph companion to the HLO costs above — used to assert
    kernel-launch counts (e.g. ONE ``pallas_call`` for the fused qmatmul
    epilogue) in tests and benchmarks.
    """
    def sub(v):
        if hasattr(v, "jaxpr"):          # ClosedJaxpr
            return count_jaxpr_primitive(v.jaxpr, name)
        if hasattr(v, "eqns"):           # raw Jaxpr
            return count_jaxpr_primitive(v, name)
        if isinstance(v, (list, tuple)):
            return sum(sub(vv) for vv in v)
        return 0

    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            n += sub(v)
    return n

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\dm\d(?:fn)?)?)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*->.*\{\s*$")

_FREE_OPS = frozenset((
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "iota", "reshape",
    "broadcast", "transpose", "custom-call", "partition-id", "replica-id",
    "get-dimension-size", "domain", "opt-barrier", "rng-bit-generator",
))

_ELEMENTWISE = frozenset((
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "tanh", "rsqrt", "sqrt", "power", "log", "negate",
    "abs", "floor", "ceil", "round-nearest-even", "round-nearest-afz",
    "compare", "select", "convert", "and", "or", "not", "xor", "sine",
    "cosine", "clamp", "erf", "exponential-minus-one", "log-plus-one",
    "sign", "atan2", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "reduce-precision",
))

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

#: op classes that genuinely touch HBM on TPU (everything else is assumed
#: fused): matmuls, gathers/scatters (embeddings, MoE dispatch, KV-cache
#: updates), windowed ops, reductions crossing fusion boundaries.
_MEMORY_OPS = frozenset((
    "dot", "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
    "reduce", "reduce-window", "sort", "select-and-scatter", "convolution",
    "fft", "triangular-solve", "cholesky",
))

_OP_RE = re.compile(r"=\s*(?:\(.*?\)|[\w\[\],{}]+(?:\s|\{[\d,]*\})*)\s*"
                    r"([\w\-]+)\(")


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0          # fusion-aware (memory-bound op classes)
    bytes_all: float = 0.0      # pessimistic: every instruction's IO
    wire_bytes: float = 0.0
    collectives: List[Dict] = dataclasses.field(default_factory=list)

    def scaled(self, m: float) -> "HloCost":
        return HloCost(self.flops * m, self.bytes * m, self.bytes_all * m,
                       self.wire_bytes * m,
                       [dict(c, count=c.get("count", 1) * m,
                             wire_bytes=c["wire_bytes"] * m,
                             tensor_bytes=c["tensor_bytes"] * m)
                        for c in self.collectives])

    def add(self, o: "HloCost") -> None:
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_all += o.bytes_all
        self.wire_bytes += o.wire_bytes
        self.collectives.extend(o.collectives)


def _nelems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> int:
    return sum(_nelems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(text))


def _result_type_str(line: str) -> str:
    """The type expression between '=' and the op name's paren."""
    rhs = line.split("=", 1)[1]
    m = _OP_RE.search(line)
    if not m:
        return rhs
    idx = rhs.find(m.group(1) + "(")
    return rhs[:idx] if idx > 0 else rhs


def _op_of(line: str) -> Optional[str]:
    m = _OP_RE.search(line)
    return m.group(1) if m else None


def _group_size(line: str, total: int) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{(.*?)\}", line)
    if m:
        ids = [t for t in m.group(1).split(",") if t.strip()]
        if ids:
            return len(ids)
    return total


def analyze_hlo(hlo: str, total_devices: int) -> HloCost:
    # ---- pass 0: computations + symbol table -------------------------------
    comps: Dict[str, List[str]] = {}
    shapes: Dict[str, str] = {}      # %name -> result type string
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        h = _HEADER_RE.match(line)
        if h and not line.startswith(" "):
            cur = h.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        comps[cur].append(line)
        nm = _NAME_RE.match(line)
        if nm:
            shapes[nm.group(1)] = _result_type_str(line)

    if entry is None:
        entry = next(iter(comps), None)
        if entry is None:
            return HloCost()

    def operand_names(line: str) -> List[str]:
        op = _op_of(line)
        if op is None:
            return []
        rhs = line.split("=", 1)[1]
        start = rhs.find(op + "(") + len(op) + 1
        depth, i = 1, start
        while i < len(rhs) and depth:
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
            i += 1
        return _OPERAND_RE.findall(rhs[start:i - 1])

    def dot_flops(line: str) -> float:
        res_elems = sum(_nelems(d) for _, d in
                        _SHAPE_RE.findall(_result_type_str(line)))
        ops = operand_names(line)
        if not ops:
            return 0.0
        lhs_type = shapes.get(ops[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if not sm:
            return 0.0
        lhs_dims = [int(x) for x in sm.group(2).split(",") if x]
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        contracted = 1
        if cm and cm.group(1):
            for i in cm.group(1).split(","):
                if int(i) < len(lhs_dims):
                    contracted *= lhs_dims[int(i)]
        return 2.0 * res_elems * contracted

    def line_cost(line: str) -> Tuple[HloCost, Optional[str], bool]:
        op = _op_of(line)
        cost = HloCost()
        if op is None:
            return cost, None, False

        base = op.replace("-start", "")
        if base in _COLLECTIVES:
            t_bytes = _shapes_bytes(_result_type_str(line))
            if op.endswith("-start"):
                t_bytes //= 2           # (operand, result) tuple
            kind = "all-to-all" if base == "ragged-all-to-all" else base
            n = max(_group_size(line, total_devices), 1)
            ring = (n - 1) / n if n > 1 else 0.0
            factor = {"all-reduce": 2 * ring, "all-gather": ring,
                      "reduce-scatter": ring, "all-to-all": ring,
                      "collective-permute": 1.0}[kind]
            cost.wire_bytes = t_bytes * factor
            cost.bytes = 2.0 * t_bytes
            cost.bytes_all = 2.0 * t_bytes
            cost.collectives.append({"kind": kind, "tensor_bytes": t_bytes,
                                     "group": n, "count": 1,
                                     "wire_bytes": cost.wire_bytes})
            return cost, None, False
        if op.endswith("-done") or op in _FREE_OPS:
            return cost, None, False

        if op == "while":
            b = _BODY_RE.search(line)
            return cost, (b.group(1) if b else None), True

        callee = None
        if op in ("fusion", "call", "conditional", "map", "reduce",
                  "scatter", "sort", "reduce-window", "select-and-scatter",
                  "reduce-scatter", "async-start"):
            cm = _CALL_RE.search(line)
            callee = cm.group(1) if cm else None

        # IO bytes: result + operands (via symbol table).  ``bytes``
        # (the roofline memory term) only charges memory-bound op
        # classes — elementwise chains are assumed fused into their
        # producers/consumers, as the TPU compiler does; ``bytes_all``
        # keeps the pessimistic every-instruction total.
        res_b = _shapes_bytes(_result_type_str(line))
        opds = operand_names(line)
        opd_b = sum(_shapes_bytes(shapes.get(o, "")) for o in opds)
        cost.bytes_all = float(res_b + opd_b)
        if op in ("dynamic-slice", "gather"):
            # reads only the sliced/gathered region, not the source buffer
            cost.bytes = 2.0 * res_b
        elif op in ("dynamic-update-slice", "scatter"):
            # read-modify-write of the update region only (in-place on TPU)
            upd = (_shapes_bytes(shapes.get(opds[1], ""))
                   if len(opds) > 1 else res_b)
            if op == "scatter" and len(opds) > 2:
                upd = _shapes_bytes(shapes.get(opds[-1], ""))
            cost.bytes = 2.0 * upd
        elif op in _MEMORY_OPS:
            cost.bytes = float(res_b + opd_b)
        if op == "dot":
            cost.flops = dot_flops(line)
        elif op in _ELEMENTWISE:
            cost.flops = float(sum(_nelems(d) for _, d in
                                   _SHAPE_RE.findall(_result_type_str(line))))
        return cost, callee, False

    memo: Dict[str, HloCost] = {}

    def cost_of(name: str, stack=()) -> HloCost:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HloCost()
        total = HloCost()
        for line in comps[name]:
            c, callee, is_while = line_cost(line)
            total.add(c)
            if callee is not None and is_while:
                trips = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    cm = _COND_RE.search(line)
                    if cm and cm.group(1) in comps:
                        consts = [int(x) for l in comps[cm.group(1)]
                                  for x in _CONST_RE.findall(l)]
                        trips = max(consts or [1])
                total.add(cost_of(callee, stack + (name,)).scaled(trips))
            elif callee is not None:
                total.add(cost_of(callee, stack + (name,)))
        memo[name] = total
        return total

    result = cost_of(entry)
    agg: Dict[str, Dict] = {}
    for c in result.collectives:
        a = agg.setdefault(c["kind"], {"kind": c["kind"], "count": 0,
                                       "tensor_bytes": 0.0,
                                       "wire_bytes": 0.0})
        a["count"] += c.get("count", 1)
        a["tensor_bytes"] += c["tensor_bytes"]
        a["wire_bytes"] += c["wire_bytes"]
    result.collectives = sorted(agg.values(), key=lambda a: -a["wire_bytes"])
    return result


def per_op_breakdown(hlo: str, total_devices: int, top: int = 12):
    """Loop-corrected (bytes, flops) per op kind + the largest single
    contributors — the profiling view the §Perf loop reads."""
    from collections import defaultdict
    comps: Dict[str, List[str]] = {}
    shapes: Dict[str, str] = {}
    cur = None
    for line in hlo.splitlines():
        h = _HEADER_RE.match(line)
        if h and not line.startswith(" "):
            cur = h.group(1)
            comps[cur] = []
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        comps[cur].append(line)
        nm = _NAME_RE.match(line)
        if nm:
            shapes[nm.group(1)] = _result_type_str(line)

    m = re.search(r"^ENTRY\s+(%[\w.\-]+)", hlo, re.M)
    if not m:
        return {}, []
    bykind = defaultdict(lambda: [0.0, 0.0])   # op -> [bytes, flops]
    biggest = []

    def op_names(line, op):
        rhs = line.split("=", 1)[1]
        start = rhs.find(op + "(") + len(op) + 1
        depth, i = 1, start
        while i < len(rhs) and depth:
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
            i += 1
        return _OPERAND_RE.findall(rhs[start:i - 1])

    def walk(name, mult, stack=()):
        if name not in comps or name in stack:
            return
        for line in comps[name]:
            op = _op_of(line)
            if op is None:
                continue
            if op == "while":
                b = _BODY_RE.search(line)
                tm = _TRIP_RE.search(line)
                trips = int(tm.group(1)) if tm else 1
                if b:
                    walk(b.group(1), mult * trips, stack + (name,))
                continue
            cm = _CALL_RE.search(line)
            if cm and op in ("fusion", "call", "conditional", "map",
                             "reduce", "scatter", "sort"):
                walk(cm.group(1), mult, stack + (name,))
            res = _shapes_bytes(_result_type_str(line))
            opds = op_names(line, op)
            if op in ("dynamic-slice", "gather"):
                v = 2.0 * res
            elif op in ("dynamic-update-slice", "scatter"):
                upd = (_shapes_bytes(shapes.get(opds[1], ""))
                       if len(opds) > 1 else res)
                if op == "scatter" and len(opds) > 2:
                    upd = _shapes_bytes(shapes.get(opds[-1], ""))
                v = 2.0 * upd
            elif op.replace("-start", "") in _COLLECTIVES:
                v = 2.0 * res
                op = "collective:" + op.replace("-start", "")
            elif op in _MEMORY_OPS:
                v = float(res + sum(_shapes_bytes(shapes.get(o, ""))
                                    for o in opds))
            else:
                continue
            bykind[op][0] += mult * v
            if op == "dot":
                bykind[op][1] += mult * 0  # flops tracked elsewhere
            if mult * v > 0.2e9:
                biggest.append((mult * v, op, line.strip()[:200]))
    walk(m.group(1), 1.0)
    table = sorted(((k, v[0]) for k, v in bykind.items()),
                   key=lambda kv: -kv[1])
    return dict(table), sorted(biggest, reverse=True)[:top]
