"""Prefix index: committed KV pages keyed by page-aligned token chunks.

The reuse-factor move applied to cache *contents*: the block-table
indirection (paging.py) already lets one physical page appear in many
slots' tables, so a page holding the KV rows of a fully-committed,
page-aligned token chunk is a reusable library component — any later
request whose prompt starts with the same tokens can map it instead of
recomputing it.  This module is the host-side catalogue of those pages.

Keys are *hash chains*: for a prompt split into ``page_size``-token
chunks ``t_0, t_1, ...``, chunk ``g`` is keyed by

    key_g = sha256(key_{g-1} || t_g.tobytes()),   key_{-1} = ROOT

so a key commits to the entire token history up to and including its
chunk — two prompts share ``key_g`` only if they agree on the first
``(g+1) * page_size`` tokens.  Entries additionally store their chunk's
tokens and a link to the parent entry, and :meth:`match` re-verifies
tokens exactly on the walk down, so a (vanishingly unlikely) sha256
collision degrades to a cache miss, never to wrong KV.

The index stores only *host metadata* (page ids + keys); the pages it
references live in the engine's page pool with the index holding one
refcount each (owner = :data:`PREFIX_OWNER`).  Eviction is LRU over
entries whose page nobody else references, deepest-chunk-first within a
tie so a chain is always dismantled leaf-to-root — an interior chunk is
never dropped while a descendant remains matchable.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from .lifecycle import PriorityClass, coerce_priority

__all__ = ["PrefixIndex", "PREFIX_OWNER", "ROOT"]

#: Allocator owner tag for pages held by the index.  Publication
#: transfers a page's ownership from the computing slot to this
#: sentinel, keeping ``pages_of(slot)`` = "pages only this slot holds".
PREFIX_OWNER = "__prefix__"

#: Chain key of the empty prefix.
ROOT = b""


class _Entry:
    __slots__ = ("key", "parent", "tokens", "page", "depth", "used", "cls")

    def __init__(self, key: bytes, parent: bytes, tokens: np.ndarray,
                 page: int, depth: int, used: int,
                 cls: PriorityClass = PriorityClass.STANDARD):
        self.key = key
        self.parent = parent            # chain key of the previous chunk
        self.tokens = tokens            # this chunk's tokens (int32, page_size)
        self.page = page                # physical page id holding the KV rows
        self.depth = depth              # chunk index (0 = first page)
        self.used = used                # LRU tick of last match/publish
        self.cls = cls                  # class of the publishing request


class PrefixIndex:
    """Host-side map ``chain key -> committed KV page``.

    All token math is in int32; ``page_size`` must match the engine's
    page size (one chunk = one page of KV rows).  The index never talks
    to the device — callers move refcounts/ownership in the allocator
    and rewrite block tables; this class only remembers which physical
    page holds which token chunk.
    """

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.page_size = int(page_size)
        self._by_key: Dict[bytes, _Entry] = {}
        self._tick = 0                  # monotonic LRU clock (not wall time)

    # -- keys ---------------------------------------------------------------
    @staticmethod
    def chain_key(parent: bytes, tokens: np.ndarray) -> bytes:
        """``sha256(parent || tokens)`` over the chunk's int32 bytes."""
        return hashlib.sha256(
            parent + np.ascontiguousarray(tokens, np.int32).tobytes()
        ).digest()

    def keys_for(self, tokens: np.ndarray) -> List[bytes]:
        """Chain keys for every *full* chunk of ``tokens`` (a prompt of
        fewer than ``page_size`` tokens has no publishable chunk)."""
        toks = np.asarray(tokens, np.int32)
        keys, parent = [], ROOT
        for g in range(len(toks) // self.page_size):
            parent = self.chain_key(
                parent, toks[g * self.page_size:(g + 1) * self.page_size])
            keys.append(parent)
        return keys

    # -- queries ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._by_key)

    def __contains__(self, key: bytes) -> bool:
        return key in self._by_key

    def page_of(self, key: bytes) -> int:
        return self._by_key[key].page

    def pages(self) -> List[int]:
        """Every page the index currently holds a reference on."""
        return [e.page for e in self._by_key.values()]

    def match(self, tokens: np.ndarray) -> Tuple[int, List[int], bytes]:
        """Longest indexed prefix of ``tokens``, walked chunk by chunk.

        Returns ``(depth, pages, key)``: the number of matched full
        chunks, their physical pages in chunk order, and the chain key
        of the last matched chunk (``ROOT`` on a miss) — the parent a
        subsequent publication of chunk ``depth`` will extend.  Every
        hit re-verifies the stored tokens against the prompt, so a hash
        collision is a miss, not corruption.  Matched entries' LRU
        ticks are refreshed.
        """
        toks = np.asarray(tokens, np.int32)
        pages: List[int] = []
        parent = ROOT
        hits: List[_Entry] = []
        for g in range(len(toks) // self.page_size):
            chunk = toks[g * self.page_size:(g + 1) * self.page_size]
            key = self.chain_key(parent, chunk)
            e = self._by_key.get(key)
            if e is None or not np.array_equal(e.tokens, chunk):
                break
            pages.append(e.page)
            hits.append(e)
            parent = key
        self._tick += 1
        for e in hits:
            e.used = self._tick
        return len(pages), pages, parent

    # -- mutation -----------------------------------------------------------
    def put(self, key: bytes, parent: bytes, tokens: np.ndarray,
            page: int, depth: int, cls=None) -> None:
        """Register ``page`` as the committed KV of the chunk ``key``,
        remembering the publishing request's priority class (eviction
        dismantles less-important classes first).

        The caller must already hold a reference for the index (share +
        transfer to :data:`PREFIX_OWNER` in the allocator) — the index
        itself is bookkeeping only.  Double-publication of a key is a
        caller bug (probe with ``in`` / :meth:`touch` first)."""
        if key in self._by_key:
            raise ValueError("chain key already indexed")
        self._tick += 1
        # a private copy, never a view: callers pass slices of mutable
        # engine buffers (hist), and an aliased entry would silently
        # stop matching the moment the slot is recycled
        self._by_key[key] = _Entry(
            key, parent, np.array(tokens, np.int32, copy=True),
            int(page), int(depth), self._tick, coerce_priority(cls))

    def touch(self, key: bytes) -> bool:
        """Refresh ``key``'s LRU tick; False if not indexed."""
        e = self._by_key.get(key)
        if e is None:
            return False
        self._tick += 1
        e.used = self._tick
        return True

    def evict(self, allocator, want: int,
              protect: Optional[set] = None, floor=None) -> int:
        """Free up to ``want`` pages by dropping index entries,
        least-important class first, oldest first within a class
        (deepest-first within an LRU tie, so chains dismantle
        leaf-to-root).  Only entries whose page the index holds the
        *sole* reference on are eligible — a page mapped into any live
        slot (refcount > 1) or listed in ``protect`` stays.  ``floor``
        (a priority class) restricts eligibility to entries of that
        class or *less* important — a BATCH admission may never evict
        the REALTIME working set.  Returns the number of pages actually
        freed."""
        if want <= 0:
            return 0
        protect = protect or set()
        floor_v = None if floor is None else int(coerce_priority(floor))
        victims = sorted(
            (e for e in self._by_key.values()
             if allocator.refcount(e.page) == 1 and e.page not in protect
             and (floor_v is None or int(e.cls) >= floor_v)),
            key=lambda e: (-int(e.cls), e.used, -e.depth))
        freed = 0
        # One entry per page by construction, but a child may become
        # sole-referenced only mid-sweep; the sort order guarantees a
        # child is visited no later than its parent within a tie.
        for e in victims:
            if freed >= want:
                break
            del self._by_key[e.key]
            allocator.free([e.page])
            freed += 1
        return freed

    def drop(self, key: bytes, allocator) -> None:
        """Remove one entry and release its index reference."""
        e = self._by_key.pop(key)
        allocator.free([e.page])

    # -- snapshot / restore -------------------------------------------------
    def state(self) -> dict:
        return {
            "page_size": self.page_size,
            "tick": self._tick,
            "entries": [
                {"key": e.key, "parent": e.parent,
                 "tokens": e.tokens.copy(), "page": e.page,
                 "depth": e.depth, "used": e.used, "cls": e.cls.name}
                for e in self._by_key.values()],
        }

    def load_state(self, state: dict) -> None:
        if int(state["page_size"]) != self.page_size:
            raise ValueError("prefix index page_size mismatch")
        self._tick = int(state["tick"])
        self._by_key = {}
        for d in state["entries"]:
            # pre-quota snapshots carry no class: STANDARD, the same
            # default coerce_priority applies to unlabelled requests
            self._by_key[d["key"]] = _Entry(
                d["key"], d["parent"],
                np.ascontiguousarray(d["tokens"], np.int32),
                int(d["page"]), int(d["depth"]), int(d["used"]),
                PriorityClass[d.get("cls", "STANDARD")])
