"""Free-list page allocator for the paged KV cache (host-side).

The serving cache's de-specialization step (the hls4ml analogy: replace
the fixed, shape-specialized per-slot buffer with a generalized pool):
instead of every slot owning ``max_len`` KV rows, the engine owns a pool
of ``num_pages`` fixed-size pages and each request holds exactly the
pages its token budget needs.  Admission is then limited by *used*
tokens, not worst-case ones — the allocator answers "do the freed pages
cover this prompt?" in O(1) and hands pages out in O(pages).

Pages are *reference counted*: one physical page can back many logical
consumers (the reuse-factor move applied to cache memory — prefix
caching maps one stored prefix into every request that shares it).
``alloc``/``adopt`` create a page with refcount 1, :meth:`share` adds a
reference, and :meth:`free`/:meth:`spill` drop one — a page returns to
the free list only when its last reference is dropped.  A non-sharing
caller sees exactly the old free-list semantics (every count is 1 and
``free`` really frees).

The allocator is deliberately host-side and trivial.  Every
device-visible consequence of an allocation flows through the block
tables the engine writes into the cache pytree — the allocator itself
never touches device memory, so its invariants (no double assignment,
freed pages immediately reusable, no spurious OOM while ``free >=
need``, no page freed while references remain) are plain-Python
checkable (see tests/test_paged_serving.py and tests/test_prefix_cache.py
property sweeps).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from .lifecycle import PriorityClass, coerce_priority, normalize_class_quotas

__all__ = ["PageAllocator"]


class PageAllocator:
    """Refcounting LIFO free-list allocator over page ids ``0 .. num_pages-1``.

    A free list cannot fragment: any ``n <= len(free)`` request is
    satisfiable because pages are position-independent (the block table
    gives each request its own contiguous *logical* view over arbitrary
    *physical* page ids).  That is the property the dense layout lacks —
    a dense slot needs ``max_len`` contiguous rows whether or not the
    request uses them.

    Each allocated page has exactly one *owner tag* (who to charge it
    to — the engine uses slot indices, and the prefix index a sentinel)
    plus a refcount counting every logical holder.  Sharing does not
    move ownership; :meth:`transfer` does (the engine re-owns a page to
    the prefix index when it is published).
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 class_quotas=None):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        #: page id -> owner tag (engine: slot index); the double-assign guard
        self._owner: Dict[int, object] = {}
        #: owner tag -> pages in allocation order.  Kept in lockstep with
        #: ``_owner`` so :meth:`pages_of` is O(own pages), not an
        #: O(num_pages) scan — ``spill`` calls it per victim, and a heavy
        #: preemption sweep must not go quadratic in pool size.
        self._pages: Dict[object, List[int]] = {}
        #: page id -> reference count (>= 1 while allocated)
        self._ref: Dict[int, int] = {}
        #: per-class partition of the pool (empty dict = unpartitioned,
        #: byte-identical legacy behaviour).  A page is *charged* to the
        #: class that allocated it for its whole pool lifetime — sharing
        #: and ownership transfer (prefix publication) keep the charge,
        #: so a REALTIME-published prefix page keeps counting toward the
        #: REALTIME floor, which is exactly the working set the floor
        #: exists to protect.
        self.class_quotas = normalize_class_quotas(class_quotas)
        self._cls: Dict[int, Optional[PriorityClass]] = {}
        self._cls_used: Dict[PriorityClass, int] = {
            c: 0 for c in PriorityClass}
        #: floors round UP (the reservation is "at least this fraction"),
        #: caps round down but never to zero (a cap the class can never
        #: use at all would be a ban spelled as a bound)
        self._floor_pages: Dict[PriorityClass, int] = {}
        self._cap_pages: Dict[PriorityClass, int] = {}
        for c, q in self.class_quotas.items():
            if "floor" in q:
                self._floor_pages[c] = min(
                    self.num_pages,
                    int(math.ceil(q["floor"] * self.num_pages - 1e-9)))
            if "cap" in q:
                self._cap_pages[c] = max(
                    1, int(q["cap"] * self.num_pages + 1e-9))

    # -- queries ------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV rows (ceil division)."""
        return -(-max(int(tokens), 0) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def can_alloc(self, n: int, cls=None) -> bool:
        if n > len(self._free):
            return False
        if not self.class_quotas:
            return True
        return self.quota_violation({self._coerce_cls(cls): int(n)}) is None

    # -- class quotas -------------------------------------------------------
    def _coerce_cls(self, cls) -> Optional[PriorityClass]:
        """Charge class for an allocation: explicit class, else STANDARD
        when the pool is partitioned (defaulted traffic is the middle
        class, same convention as ``coerce_priority``), else ``None``
        (unpartitioned pools track nothing)."""
        if not self.class_quotas:
            return None
        return (PriorityClass.STANDARD if cls is None
                else coerce_priority(cls))

    def class_used(self, cls) -> int:
        """Pages currently charged to ``cls``."""
        return self._cls_used.get(coerce_priority(cls), 0)

    def cap_pages(self, cls) -> Optional[int]:
        """``cls``'s page cap (None = uncapped)."""
        return self._cap_pages.get(coerce_priority(cls))

    def floor_pages(self, cls) -> int:
        """Pages reserved for ``cls`` (0 = no reservation)."""
        return self._floor_pages.get(coerce_priority(cls), 0)

    def quota_violation(self, needs: Dict, *, freed: int = 0,
                        uncharge: Optional[Dict] = None) -> Optional[str]:
        """``None`` if per-class allocations ``needs`` fit every quota,
        else a message naming the violated constraint.

        ``needs`` maps class -> fresh pages wanted.  ``freed`` pages are
        known to return to the free list first (a recycle/preempt plan),
        with ``uncharge`` as the matching per-class charge decrements
        (see :meth:`release_credit`).  Two constraints:

        * **cap**: a capped class may not exceed its page bound;
        * **floor**: after the allocation, the free list must still
          cover every *other* class's unfilled reservation — the free
          pages behind a floor belong to that class's future, not to
          whoever asks first.
        """
        if not self.class_quotas:
            return None
        used = dict(self._cls_used)
        for c, n in (uncharge or {}).items():
            used[c] = used.get(c, 0) - int(n)
        total = 0
        for key, n in needs.items():
            c = self._coerce_cls(key)
            used[c] = used.get(c, 0) + int(n)
            total += int(n)
        free_after = len(self._free) + int(freed) - total
        for c, cap in self._cap_pages.items():
            if used.get(c, 0) > cap:
                return (f"class {c.name.lower()} over its page cap: "
                        f"{used[c]} > {cap} of {self.num_pages}")
        shortfall = sum(max(0, fp - used.get(c, 0))
                        for c, fp in self._floor_pages.items())
        if free_after < shortfall:
            return (f"allocation would break reserved class floors: "
                    f"{free_after} pages would stay free but "
                    f"{shortfall} are reserved")
        return None

    def quota_evict_want(self, cls, n: int,
                         planned: Optional[Dict] = None) -> int:
        """Pages of ``cls`` (or less important) that would have to
        leave the pool — freed AND uncharged — before ``n`` fresh pages
        for ``cls`` clear both quota constraints (0 = quotas are not
        the blocker).  Sizes the prefix-eviction sweep a quota-blocked
        admission head runs: a pool with plenty of free pages can still
        refuse a capped class whose *published* prefix pages hold its
        whole budget."""
        if not self.class_quotas:
            return 0
        used = dict(self._cls_used)
        total = 0
        for key, m in (planned or {}).items():
            c = self._coerce_cls(key)
            used[c] = used.get(c, 0) + int(m)
            total += int(m)
        c = self._coerce_cls(cls)
        used[c] = used.get(c, 0) + int(n)
        total += int(n)
        want = 0
        cap = self._cap_pages.get(c)
        if cap is not None and used[c] > cap:
            want = used[c] - cap
        free_after = len(self._free) - total
        shortfall = sum(max(0, fp - used.get(k, 0))
                        for k, fp in self._floor_pages.items())
        if free_after < shortfall:
            want = max(want, shortfall - free_after)
        return want

    def release_credit(self, pages) -> Tuple[int, Dict]:
        """``(pages that would return to the pool, per-class uncharges)``
        if one reference were dropped on each of ``pages`` — the credit
        an admission plan may count before it actually frees anything."""
        freed, uncharge = 0, {}
        for p in pages:
            if self._ref.get(int(p), 0) == 1:
                freed += 1
                c = self._cls.get(int(p))
                if c is not None:
                    uncharge[c] = uncharge.get(c, 0) + 1
        return freed, uncharge

    def pages_of(self, owner) -> List[int]:
        """The pages currently owned by ``owner``, in allocation order
        (the same order the engine's block table holds them).  O(own
        pages) via the per-owner list — never a pool-wide scan."""
        return list(self._pages.get(owner, ()))

    def refcount(self, page: int) -> int:
        """References held on ``page`` (0 = not allocated)."""
        return self._ref.get(int(page), 0)

    def shared_pages(self) -> int:
        """Number of allocated pages with more than one reference."""
        return sum(1 for r in self._ref.values() if r > 1)

    # -- alloc / free -------------------------------------------------------
    def alloc(self, n: int, owner=None, cls=None) -> List[int]:
        """Take ``n`` pages off the free list (raises if short), each
        with refcount 1, charged to ``cls`` when the pool is
        class-partitioned.

        Without quotas ``free_pages >= n`` is the complete admission
        condition — there is no fragmentation failure mode to account
        for.  With quotas the class constraints of
        :meth:`quota_violation` apply on top.
        """
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: need {n}, free {len(self._free)} "
                f"of {self.num_pages}")
        cls = self._coerce_cls(cls)
        if self.class_quotas:
            msg = self.quota_violation({cls: int(n)})
            if msg is not None:
                raise MemoryError(
                    f"class quota exceeded: {msg} (need {n} for "
                    f"{cls.name.lower()})")
        pages = [self._free.pop() for _ in range(n)]
        own = self._pages.setdefault(owner, [])
        for p in pages:
            assert p not in self._owner, f"page {p} double-assigned"
            self._owner[p] = owner
            self._ref[p] = 1
            own.append(p)
            self._charge(p, cls)
        return pages

    def _charge(self, page: int, cls: Optional[PriorityClass]) -> None:
        self._cls[page] = cls
        if cls is not None:
            self._cls_used[cls] += 1

    def _uncharge(self, page: int) -> None:
        cls = self._cls.pop(page, None)
        if cls is not None:
            self._cls_used[cls] -= 1

    def share(self, pages: List[int]) -> None:
        """Add one reference to each page (all must be allocated).

        Atomic: every id is validated before any count moves, so a
        failed share changes nothing.  Sharing never touches ownership
        or the free list — it is the O(pages) half of a prefix-cache
        hit (the other half is a block-table edit on the engine side).
        """
        pages = [int(p) for p in pages]
        for p in pages:
            if p not in self._owner:
                raise ValueError(f"page {p} is not allocated; cannot share")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page; a page whose count reaches zero
        returns to the pool (immediately reusable, O(pages)).

        Atomic: the whole list is validated before any reference moves,
        so a double-free (or a duplicate within the call) raises without
        half-freeing — the guard that keeps a preempt/restore cycle from
        ever putting one page on the free list twice.  A page still
        referenced elsewhere (prefix-shared) survives the call with its
        owner unchanged: *no page is freed while references remain*.
        """
        pages = [int(p) for p in pages]
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate page ids in free(): {pages}")
        for p in pages:
            if p not in self._owner:
                raise ValueError(f"page {p} is not allocated")
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] > 0:
                continue                      # other holders remain
            del self._ref[p]
            owner = self._owner.pop(p)
            self._pages[owner].remove(p)
            self._free.append(p)
            self._uncharge(p)

    def transfer(self, pages: List[int], owner) -> None:
        """Re-own allocated pages to ``owner`` (refcounts untouched).

        The publication primitive: a page entering the prefix index is
        charged to the index rather than the slot that computed it, so
        ``pages_of(slot)``/``spill(slot)`` keep meaning "pages only this
        slot holds".  Atomic like every other mutator."""
        pages = [int(p) for p in pages]
        for p in pages:
            if p not in self._owner:
                raise ValueError(f"page {p} is not allocated; "
                                 f"cannot transfer")
        dst = self._pages.setdefault(owner, [])
        for p in pages:
            old = self._owner[p]
            if old == owner:
                continue
            self._pages[old].remove(p)
            self._owner[p] = owner
            dst.append(p)

    # -- preempt / restore --------------------------------------------------
    def spill(self, owner) -> List[int]:
        """Drop ``owner``'s reference on every page it owns; returns
        them in allocation order.  The preemption primitive: the engine
        copies the returned pages' payload to host memory *before*
        calling this, then exclusively-held ids rejoin the free list
        exactly as a normal ``free`` would — a later :meth:`alloc` for
        the resumed request hands out whatever physical ids are free
        *then* (restore re-targets the payload, it does not pin
        physical ids)."""
        pages = self.pages_of(owner)
        self.free(pages)
        return pages

    def adopt(self, pages: List[int], owner=None, cls=None) -> None:
        """Claim *specific* free page ids for ``owner`` (refcount 1),
        charged to ``cls`` when the pool is class-partitioned.

        The restore-side primitive: re-attaching allocator state from an
        engine snapshot (or migrating pages between pools) must mark the
        exact ids a request held, not whatever the LIFO head offers.
        Atomic: every id is validated free (and unique) before any is
        claimed."""
        pages = [int(p) for p in pages]
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate page ids in adopt(): {pages}")
        free_set = set(self._free)
        for p in pages:
            if p in self._owner:
                raise ValueError(f"page {p} is already assigned")
            if p not in free_set:
                raise ValueError(f"page {p} is not a valid free page")
        cls = self._coerce_cls(cls)
        if self.class_quotas:
            msg = self.quota_violation({cls: len(pages)})
            if msg is not None:
                raise MemoryError(
                    f"class quota exceeded: {msg} (adopting "
                    f"{len(pages)} for {cls.name.lower()})")
        taken = set(pages)
        self._free = [p for p in self._free if p not in taken]
        own = self._pages.setdefault(owner, [])
        for p in pages:
            self._owner[p] = owner
            self._ref[p] = 1
            own.append(p)
            self._charge(p, cls)

    # -- snapshot / restore -------------------------------------------------
    def state(self) -> dict:
        """Host-copyable allocator state (free-list ORDER included —
        allocation determinism after a restore depends on it — plus
        per-page refcounts and the per-owner allocation order)."""
        return {"free": list(self._free), "owner": dict(self._owner),
                "ref": dict(self._ref),
                "pages": {o: list(ps) for o, ps in self._pages.items()
                          if ps},
                "cls": {p: (c.name if c is not None else None)
                        for p, c in self._cls.items()}}

    def load_state(self, state: dict) -> None:
        """Restore :meth:`state` output; validates the page-id partition
        (every id exactly once across free + owned) and that every
        allocated page carries at least one reference."""
        free, owner = list(state["free"]), dict(state["owner"])
        ids = free + list(owner)
        if sorted(ids) != list(range(self.num_pages)):
            raise ValueError("allocator state does not partition the pool")
        ref = dict(state.get("ref") or {p: 1 for p in owner})
        if sorted(ref) != sorted(owner) or any(r < 1 for r in ref.values()):
            raise ValueError("allocator refcounts do not cover the "
                             "allocated pages (every owned page needs "
                             ">= 1 reference)")
        pages = state.get("pages")
        if pages is None:
            # legacy snapshots: reconstruct per-owner allocation order
            # from the owner dict's insertion order (how pages_of used
            # to derive it)
            pages = {}
            for p, o in owner.items():
                pages.setdefault(o, []).append(p)
        else:
            pages = {o: list(ps) for o, ps in pages.items()}
            flat = sorted(p for ps in pages.values() for p in ps)
            if flat != sorted(owner):
                raise ValueError("allocator per-owner lists do not match "
                                 "the owner map")
        self._free, self._owner = free, owner
        self._ref, self._pages = ref, pages
        # class charges: legacy snapshots (pre-quota) carry none — their
        # pages restore unclassified, which under-counts floors/caps
        # until those requests retire (documented, conservative for the
        # restored requests themselves, never for the floor holders)
        cls_map = state.get("cls") or {}
        self._cls = {}
        self._cls_used = {c: 0 for c in PriorityClass}
        for p in owner:
            name = cls_map.get(p)
            self._charge(p, PriorityClass[name] if name else None)
