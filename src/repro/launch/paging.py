"""Free-list page allocator for the paged KV cache (host-side).

The serving cache's de-specialization step (the hls4ml analogy: replace
the fixed, shape-specialized per-slot buffer with a generalized pool):
instead of every slot owning ``max_len`` KV rows, the engine owns a pool
of ``num_pages`` fixed-size pages and each request holds exactly the
pages its token budget needs.  Admission is then limited by *used*
tokens, not worst-case ones — the allocator answers "do the freed pages
cover this prompt?" in O(1) and hands pages out in O(pages).

The allocator is deliberately host-side and trivial: a LIFO free list.
Every device-visible consequence of an allocation flows through the
block tables the engine writes into the cache pytree — the allocator
itself never touches device memory, so its invariants (no double
assignment, freed pages immediately reusable, no spurious OOM while
``free >= need``) are plain-Python checkable (see
tests/test_paged_serving.py property sweeps).
"""

from __future__ import annotations

from typing import Dict, List

__all__ = ["PageAllocator"]


class PageAllocator:
    """LIFO free-list allocator over page ids ``0 .. num_pages-1``.

    A free list cannot fragment: any ``n <= len(free)`` request is
    satisfiable because pages are position-independent (the block table
    gives each request its own contiguous *logical* view over arbitrary
    *physical* page ids).  That is the property the dense layout lacks —
    a dense slot needs ``max_len`` contiguous rows whether or not the
    request uses them.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        #: page id -> owner tag (engine: slot index); the double-assign guard
        self._owner: Dict[int, object] = {}

    # -- queries ------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` KV rows (ceil division)."""
        return -(-max(int(tokens), 0) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def pages_of(self, owner) -> List[int]:
        """The pages currently assigned to ``owner``, in allocation
        order (dict insertion order — the same order the engine's block
        table holds them)."""
        return [p for p, o in self._owner.items() if o == owner]

    # -- alloc / free -------------------------------------------------------
    def alloc(self, n: int, owner=None) -> List[int]:
        """Take ``n`` pages off the free list (raises if short).

        ``free_pages >= n`` is the complete admission condition — there
        is no fragmentation failure mode to account for.
        """
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: need {n}, free {len(self._free)} "
                f"of {self.num_pages}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert p not in self._owner, f"page {p} double-assigned"
            self._owner[p] = owner
        return pages

    def free(self, pages: List[int]) -> None:
        """Return pages to the pool; immediately reusable, O(pages).

        Atomic: the whole list is validated before any page is freed, so
        a double-free (or a duplicate within the call) raises without
        half-freeing — the guard that keeps a preempt/restore cycle from
        ever putting one page on the free list twice.
        """
        pages = list(pages)
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate page ids in free(): {pages}")
        for p in pages:
            if p not in self._owner:
                raise ValueError(f"page {p} is not allocated")
        for p in pages:
            del self._owner[p]
            self._free.append(p)

    # -- preempt / restore --------------------------------------------------
    def spill(self, owner) -> List[int]:
        """Free every page ``owner`` holds; returns them in allocation
        order.  The preemption primitive: the engine copies the returned
        pages' payload to host memory *before* calling this, then the
        ids rejoin the free list exactly as a normal ``free`` would —
        a later :meth:`alloc` for the resumed request hands out whatever
        physical ids are free *then* (restore re-targets the payload,
        it does not pin physical ids)."""
        pages = self.pages_of(owner)
        self.free(pages)
        return pages

    def adopt(self, pages: List[int], owner=None) -> None:
        """Claim *specific* free page ids for ``owner``.

        The restore-side primitive: re-attaching allocator state from an
        engine snapshot (or migrating pages between pools) must mark the
        exact ids a request held, not whatever the LIFO head offers.
        Atomic: every id is validated free (and unique) before any is
        claimed."""
        pages = list(pages)
        if len(set(pages)) != len(pages):
            raise ValueError(f"duplicate page ids in adopt(): {pages}")
        free_set = set(self._free)
        for p in pages:
            if p in self._owner:
                raise ValueError(f"page {p} is already assigned")
            if p not in free_set:
                raise ValueError(f"page {p} is not a valid free page")
        taken = set(pages)
        self._free = [p for p in self._free if p not in taken]
        for p in pages:
            self._owner[p] = owner

    # -- snapshot / restore -------------------------------------------------
    def state(self) -> dict:
        """Host-copyable allocator state (free-list ORDER included —
        allocation determinism after a restore depends on it)."""
        return {"free": list(self._free), "owner": dict(self._owner)}

    def load_state(self, state: dict) -> None:
        """Restore :meth:`state` output; validates the page-id partition
        (every id exactly once across free + owned)."""
        free, owner = list(state["free"]), dict(state["owner"])
        ids = free + list(owner)
        if sorted(ids) != list(range(self.num_pages)):
            raise ValueError("allocator state does not partition the pool")
        self._free, self._owner = free, owner
