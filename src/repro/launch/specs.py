"""Shape plans and ShapeDtypeStruct input specs for every dry-run cell.

The assigned shape grid (per-arch applicability is enforced here and the
skips documented in DESIGN.md §Arch-applicability):

    train_4k      train_step   seq 4096,    global_batch 256
    prefill_32k   prefill      seq 32768,   global_batch 32
    decode_32k    serve_step   kv 32768,    global_batch 128
    long_500k     serve_step   kv 524288,   global_batch 1   (ssm/hybrid only)

``input_specs`` returns weak-type-correct, shardable stand-ins — no device
allocation ever happens for the full configs (init/caches go through
``jax.eval_shape``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..data.pipeline import batch_struct
from ..models.api import get_family
from ..models.config import ModelConfig

__all__ = ["ShapePlan", "SHAPES", "applicable", "input_specs",
           "state_struct", "cache_struct", "microbatches_for"]


@dataclasses.dataclass(frozen=True)
class ShapePlan:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapePlan] = {
    "train_4k": ShapePlan("train_4k", "train", 4096, 256),
    "prefill_32k": ShapePlan("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapePlan("decode_32k", "decode", 32768, 128),
    "long_500k": ShapePlan("long_500k", "decode", 524288, 1),
}

#: archs whose state is sub-quadratic in context (run long_500k)
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    plan = SHAPES[shape]
    if plan.name == "long_500k" and cfg.family not in _LONG_OK_FAMILIES:
        return False, ("pure full-attention arch: 524k dense KV decode is "
                       "out of regime (skip per brief; DESIGN.md)")
    return True, ""


#: (arch-name, shape) -> gradient-accumulation microbatches for train_4k.
#: Sized so live activations fit 16 GB/chip HBM next to params+optimizer
#: (napkin math in EXPERIMENTS.md §Dry-run).
_MICROBATCHES = {
    "deepseek-v2-236b": 16,
    "command-r-35b": 8,
    "glm4-9b": 4,
    "yi-6b": 4,
    "llama-3.2-vision-11b": 4,
    "gemma-2b": 2,
    "olmoe-1b-7b": 2,
}


def microbatches_for(cfg: ModelConfig, shape: str, dp: int = 16) -> int:
    """Gradient-accumulation count, capped so every microbatch still
    spans the full data-parallel group (B/µb ≥ dp — otherwise the batch
    dimension stops sharding and activations replicate across ``dp``,
    measured as an 8× per-chip compute blowup on the 2-pod mesh)."""
    if SHAPES[shape].kind != "train":
        return 1
    mb = _MICROBATCHES.get(cfg.name, 1)
    return max(1, min(mb, SHAPES[shape].batch // max(dp, 1)))


def state_struct(cfg: ModelConfig, *, dtype=jnp.float32):
    """ShapeDtypeStruct pytree of the full train state (no allocation)."""
    from ..train.step import init_state
    return jax.eval_shape(
        lambda: init_state(jax.random.PRNGKey(0), cfg, dtype=dtype))


def params_struct(cfg: ModelConfig, *, dtype=jnp.float32):
    fam = get_family(cfg)
    return jax.eval_shape(
        lambda: fam.init(jax.random.PRNGKey(0), cfg, dtype=dtype))


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    fam = get_family(cfg)
    return jax.eval_shape(
        lambda: fam.init_cache(cfg, batch, max_len, dtype))


def input_specs(cfg: ModelConfig, shape: str, *, dtype=jnp.bfloat16):
    """Model-input ShapeDtypeStructs for one (arch × shape) cell.

    train  -> {"batch": …}
    prefill-> {"batch": …, "cache": …}
    decode -> {"tokens": (B,1), "pos": (B,), "cache": …}
    """
    plan = SHAPES[shape]
    act_dtype = jnp.bfloat16 if dtype == jnp.int8 else dtype
    if plan.kind == "train":
        return {"batch": batch_struct(cfg, plan.batch, plan.seq, act_dtype)}
    if plan.kind == "prefill":
        return {"batch": batch_struct(cfg, plan.batch, plan.seq, act_dtype),
                "cache": cache_struct(cfg, plan.batch, plan.seq, dtype)}
    # decode: one new token against a seq-long cache
    return {"tokens": jax.ShapeDtypeStruct((plan.batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((plan.batch,), jnp.int32),
            "cache": cache_struct(cfg, plan.batch, plan.seq, dtype)}
