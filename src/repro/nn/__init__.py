"""Pure-JAX model substrate: functional layers over pytree params."""

from .context import DEFAULT_CTX, QuantContext

__all__ = ["DEFAULT_CTX", "QuantContext"]
