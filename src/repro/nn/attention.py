"""Attention modules: GQA/MQA/MHA, cross-attention, MLA — prefill & decode.

Three execution regimes per module:

* **prefill / training** — full-sequence attention.  Dispatches to the
  flash Pallas kernel (``repro.kernels.attention``) unless the context
  routes softmax through constant tables (``ctx.use_lut``), in which case
  the einsum path with :func:`repro.nn.activations.softmax` is used so the
  paper's LUT-exp is exercised end to end.
* **decode** — single-token step against a pre-allocated KV cache
  (``dynamic_update_slice`` at ``pos``); O(S) einsums, no kernel needed.
  The *paged* decode regime scatters K/V through per-slot block tables
  instead and, on the kernel path, runs split-KV flash-decoding: the
  ``ctx.kv_split``/``ctx.pages_per_step`` knob partitions each slot's
  page chain into parallel online-softmax lanes merged by a
  log-sum-exp combine (``repro.kernels.flash_attention``).
* **cross** — encoder-decoder attention (whisper, llama-vision); KV come
  from the encoder stream and are position-encoding-free.

MLA (deepseek-v2) is implemented in its two canonical forms: *naive* for
prefill (materialize per-head K/V from the latent, use flash attention)
and *absorbed* for decode (score directly against the 512-dim latent
cache + shared 64-dim RoPE key — the cache is (S, 576) per token
regardless of the 128 heads, which is MLA's entire point).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.qtypes import QTensor
from ..dist.constrain import constrain
from .activations import softmax
from .context import DEFAULT_CTX, QuantContext
from .linear import linear, linear_init
from .norms import rmsnorm, rmsnorm_init
from .rope import apply_rope


def _constrain_heads(t: jnp.ndarray, role: str = "q") -> jnp.ndarray:
    """Pin (B, H, S, D): TP on heads when divisible; fallbacks depend on
    the ``sp_attn`` perf flag.

    Head-count sharding is the Megatron-native layout.  When heads don't
    divide the model axis (MQA/GQA with kv ≤ 8 on 16-way TP):

    * baseline: head-dim sharding (attention contractions become psums of
      full logits — measured pathological for MQA at 32k, see §Perf H2);
    * ``sp_attn``: sequence-parallel — queries shard their *seq* axis,
      K/V replicate (they are small precisely because Hkv is small), and
      every chunk's logits stay local.
    """
    from ..dist.constrain import current_mesh
    from ..dist.options import flags
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return t
    tp = mesh.shape["model"]
    if t.shape[1] % tp == 0:
        return constrain(t, "dp", "tp", None, None)
    if flags().sp_attn and t.shape[2] > 1:
        if role == "q":
            return constrain(t, "dp", None, "tp", None)
        return constrain(t, "dp", None, None, None)   # replicate K/V
    return constrain(t, "dp", None, None, "tp")

__all__ = ["AttnDims", "gqa_init", "gqa_apply", "gqa_cache_spec",
           "gqa_paged_cache_spec", "gqa_project_kv", "MLADims", "mla_init",
           "mla_apply", "mla_cache_spec", "mla_paged_cache_spec"]


def gqa_project_kv(p, kv_src: jnp.ndarray, d: "AttnDims",
                   ctx: "QuantContext" = DEFAULT_CTX, *, path: str = "attn"):
    """Project cross-attention K/V once (prefill) → (B, Hkv, Skv, Dh)."""
    b, skv, _ = kv_src.shape
    k = linear(p["wk"], kv_src, ctx, path=f"{path}/wk")
    v = linear(p["wv"], kv_src, ctx, path=f"{path}/wv")
    k = k.reshape(b, skv, d.n_kv_heads, d.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, skv, d.n_kv_heads, d.head_dim).transpose(0, 2, 1, 3)
    return k, v


# ===========================================================================
# GQA / MQA / MHA / cross-attention
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0   # glm4 uses 0.5
    use_rope: bool = True        # whisper uses absolute embeddings instead
    qkv_bias: bool = False       # glm4 uses qkv bias
    causal: bool = True


def gqa_init(rng, d: AttnDims, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    return {
        "wq": linear_init(ks[0], d.d_model, d.n_heads * d.head_dim,
                          bias=d.qkv_bias, dtype=dtype),
        "wk": linear_init(ks[1], d.d_model, d.n_kv_heads * d.head_dim,
                          bias=d.qkv_bias, dtype=dtype),
        "wv": linear_init(ks[2], d.d_model, d.n_kv_heads * d.head_dim,
                          bias=d.qkv_bias, dtype=dtype),
        "wo": linear_init(ks[3], d.n_heads * d.head_dim, d.d_model,
                          dtype=dtype),
    }


def gqa_cache_spec(d: AttnDims, batch: int, max_len: int, dtype=jnp.bfloat16):
    """KV cache pytree: K and V of shape (B, Hkv, S_max, Dh).

    ``dtype=jnp.int8`` selects the quantized cache: int8 payload plus
    per-(token, head) bf16 scales — the paper's parametric quantization
    applied to the serving cache (2× HBM capacity/traffic on the K/V
    stream vs bf16).
    """
    shape = (batch, d.n_kv_heads, max_len, d.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if dtype == jnp.int8:
        sshape = (batch, d.n_kv_heads, max_len, 1)
        cache["k_scale"] = jnp.zeros(sshape, jnp.bfloat16)
        cache["v_scale"] = jnp.zeros(sshape, jnp.bfloat16)
    return cache


def gqa_paged_cache_spec(d: AttnDims, batch: int, num_pages: int,
                         page_size: int, table_width: int,
                         dtype=jnp.bfloat16):
    """Paged KV cache: a shared pool of fixed-size pages + block tables.

    The de-specialized layout (vs :func:`gqa_cache_spec`'s per-slot
    ``max_len`` buffers): K/V rows live in ``num_pages`` pages of
    ``page_size`` tokens each, shared by every slot, and
    ``block_table[b, j]`` names the physical page holding slot ``b``'s
    logical tokens ``[j*page_size, (j+1)*page_size)``.  One extra
    *trash page* (physical index ``num_pages``) absorbs writes from
    lanes with no allocation — dead lanes' held-token decode writes and
    chunked-prefill margin writes land there instead of needing
    per-slot margin rows.  Unset table entries point at it.

    ``dtype=jnp.int8`` pages the quantized cache: int8 payload pages
    plus per-(token, head) bf16 scale pages, exactly mirroring the
    dense int8 layout so paged and dense serving quantize identically.
    """
    shape = (num_pages + 1, d.n_kv_heads, page_size, d.head_dim)
    pages = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if dtype == jnp.int8:
        sshape = (num_pages + 1, d.n_kv_heads, page_size, 1)
        pages["k_scale"] = jnp.zeros(sshape, jnp.bfloat16)
        pages["v_scale"] = jnp.zeros(sshape, jnp.bfloat16)
    return {"pages": pages,
            "block_table": jnp.full((batch, table_width), num_pages,
                                    jnp.int32)}


def _page_coords(bt: jnp.ndarray, pos: jnp.ndarray, s: int, page_size: int):
    """(physical page, in-page row) for tokens written at pos..pos+s-1.

    Positions beyond the table clamp to its last entry — engine layouts
    size the table to cover every reachable position, so the clamp only
    guards compiler-visible out-of-range lanes (it can never alias a
    live page: clamped entries are trash-page defaults).
    """
    tpos = pos[:, None] + jnp.arange(s)[None, :]            # (B, s)
    idx = jnp.clip(tpos // page_size, 0, bt.shape[1] - 1)
    return jnp.take_along_axis(bt, idx, axis=1), tpos % page_size


def _paged_write(pages: jnp.ndarray, page: jnp.ndarray, row: jnp.ndarray,
                 u: jnp.ndarray) -> jnp.ndarray:
    """Scatter new tokens' K/V into their pages.

    ``pages`` (P, Hkv, ps, X); ``page``/``row`` (B, s); ``u``
    (B, Hkv, s, X).  Distinct lanes never share a (page, row) pair —
    the allocator hands each slot disjoint pages — except on the trash
    page, whose contents are never observed.
    """
    return pages.at[page, :, row].set(
        u.transpose(0, 2, 1, 3).astype(pages.dtype))


def _paged_gather(pages: jnp.ndarray, bt: jnp.ndarray) -> jnp.ndarray:
    """Materialize a slot-contiguous view (B, Hkv, NP*ps, X) of the pages.

    The jnp lowering (CPU/ref path): physically gathers the block
    table's pages in logical order.  The Pallas kernel
    (:func:`repro.kernels.flash_attention.paged_attention_pallas`)
    instead DMAs pages on demand and never materializes this view.
    """
    g = pages[bt]                                  # (B, NP, Hkv, ps, X)
    b, np_, h, ps, x = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, h, np_ * ps, x)


def _quantize_kv(u: jnp.ndarray):
    """(B, H, s, Dh) → int8 payload + per-(token, head) scale."""
    amax = jnp.max(jnp.abs(u.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(u.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _einsum_attention(q, k, v, *, causal: bool, ctx: QuantContext,
                      mask: Optional[jnp.ndarray] = None):
    """(B,Hq,Sq,D) × (B,Hkv,Skv,D) attention with GQA folding, f32 softmax.

    ``mask``: optional (B, Sq, Skv) boolean visibility mask; when given it
    replaces the static causal mask (cache/decode regime).
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                     # MLA: dv != dh is legal
    g = hq // hkv
    cd = ctx.compute_dtype               # bf16 operands, f32 accumulation
    qg = q.reshape(b, hkv, g, sq, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(cd), k.astype(cd),
                        preferred_element_type=jnp.float32) * (dh ** -0.5)
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    elif causal and sq > 1:
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        cmask = qpos >= jnp.arange(skv)[None, :]
        logits = jnp.where(cmask[None, None, None], logits, -1e30)
    w = softmax(logits, ctx, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(cd), v.astype(cd),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def _cache_mask(pos: jnp.ndarray, s: int, max_len: int,
                causal: bool) -> jnp.ndarray:
    """(B, s, max_len) visibility for queries written at pos..pos+s-1."""
    qpos = pos[:, None] + jnp.arange(s)[None, :]          # (B, s)
    kvpos = jnp.arange(max_len)[None, None, :]
    if causal:
        return kvpos <= qpos[:, :, None]
    return kvpos < (pos[:, None, None] + s)


#: above this many query positions, prefill/train attention switches from
#: the monolithic einsum (O(Sq·Skv) live logits) to the chunked scan.
CHUNK_THRESHOLD = 2048


def _chunked_attention(q, k, v, *, causal: bool, ctx: QuantContext,
                       chunk: int = 512):
    """Memory-bounded attention: ``lax.scan`` over query chunks.

    The GSPMD-friendly twin of the flash Pallas kernel (einsums partition
    over batch/heads; the scan keeps live logits at (B, H, chunk, Skv)).
    Each chunk is wrapped in ``jax.checkpoint`` so the backward pass
    recomputes one chunk's logits at a time instead of storing Sq·Skv —
    same memory shape as flash attention's recompute strategy.
    """
    b, hq, sq, dh = q.shape
    skv = k.shape[2]
    pad = (-sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = q.shape[2] // chunk
    qs = q.reshape(b, hq, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    q_off = skv - sq

    @jax.checkpoint
    def chunk_fn(q_c, idx):
        out = _einsum_attention_chunk(q_c, k, v, idx, chunk, q_off,
                                      causal, ctx)
        return out

    def body(_, x):
        q_c, idx = x
        return None, chunk_fn(q_c, idx)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(nc)))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, nc * chunk, -1)
    return out[:, :, :sq]


def _einsum_attention_chunk(q_c, k, v, idx, chunk, q_off, causal, ctx):
    b, hq, bq, dh = q_c.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    cd = ctx.compute_dtype               # bf16 operands, f32 accumulation
    qg = q_c.reshape(b, hkv, g, bq, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(cd), k.astype(cd),
                        preferred_element_type=jnp.float32) * (dh ** -0.5)
    qpos = q_off + idx * chunk + jnp.arange(bq)
    if causal:
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = softmax(logits, ctx, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(cd), v.astype(cd),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, bq, dv).astype(q_c.dtype)


def gqa_apply(p, x: jnp.ndarray, d: AttnDims, ctx: QuantContext = DEFAULT_CTX,
              *, positions: Optional[jnp.ndarray] = None,
              kv_input: Optional[jnp.ndarray] = None,
              cached_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cache=None, cache_pos: Optional[jnp.ndarray] = None,
              path: str = "attn") -> Tuple[jnp.ndarray, Optional[dict]]:
    """Self- or cross-attention over ``x`` (B, S, D_model).

    ``kv_input``: encoder stream for cross-attention (keys/values source).
    ``cached_kv``: precomputed cross K/V (B, Hkv, Skv, Dh) — decode path
    reuses the prefill-time projections instead of recomputing them.
    ``cache``/``cache_pos``: decode regime — update the cache at
    ``cache_pos`` and attend over the prefix.  Returns (y, new_cache).
    """
    b, s, _ = x.shape
    if cached_kv is not None:
        q = linear(p["wq"], x, ctx, path=f"{path}/wq")
        q = q.reshape(b, s, d.n_heads, d.head_dim).transpose(0, 2, 1, 3)
        k, v = cached_kv
        y = _einsum_attention(q, k, v, causal=False, ctx=ctx)
        y = y.transpose(0, 2, 1, 3).reshape(b, s, d.n_heads * d.head_dim)
        return linear(p["wo"], y, ctx, path=f"{path}/wo"), None

    kv_src = kv_input if kv_input is not None else x
    skv = kv_src.shape[1]

    q = linear(p["wq"], x, ctx, path=f"{path}/wq")
    q = q.reshape(b, s, d.n_heads, d.head_dim)
    k = linear(p["wk"], kv_src, ctx, path=f"{path}/wk")
    k = k.reshape(b, skv, d.n_kv_heads, d.head_dim)
    v = linear(p["wv"], kv_src, ctx, path=f"{path}/wv")
    v = v.reshape(b, skv, d.n_kv_heads, d.head_dim)

    if d.use_rope and kv_input is None:
        if positions is None:
            positions = jnp.arange(s)[None, :]
            if cache_pos is not None:
                positions = positions + cache_pos[:, None]
        q = apply_rope(q.transpose(0, 2, 1, 3), positions[:, None],
                       theta=d.rope_theta, fraction=d.rope_fraction
                       ).transpose(0, 2, 1, 3)
        k = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None],
                       theta=d.rope_theta, fraction=d.rope_fraction
                       ).transpose(0, 2, 1, 3)

    q = _constrain_heads(q.transpose(0, 2, 1, 3), "q")  # (B, Hq, S, Dh)
    k = _constrain_heads(k.transpose(0, 2, 1, 3), "kv")
    v = _constrain_heads(v.transpose(0, 2, 1, 3), "kv")

    new_cache = None
    if cache is not None and "pages" in cache:
        # paged decode / chunked prefill: scatter K/V into the slot's
        # pages (write-before-attend), then attend through the block
        # table.  No per-slot margin rows exist — out-of-allocation
        # writes land on the trash page via the table defaults.
        pages, bt = cache["pages"], cache["block_table"]
        zeros = jnp.zeros((b,), jnp.int32) if cache_pos is None else cache_pos
        page, row = _page_coords(bt, zeros, s, pages["k"].shape[2])
        cd = ctx.compute_dtype
        if "k_scale" in pages:          # int8 pages + scale pages
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            pages = {"k": _paged_write(pages["k"], page, row, kq),
                     "v": _paged_write(pages["v"], page, row, vq),
                     "k_scale": _paged_write(pages["k_scale"], page, row, ks),
                     "v_scale": _paged_write(pages["v_scale"], page, row, vs)}
            ck = (_paged_gather(pages["k"], bt).astype(cd)
                  * _paged_gather(pages["k_scale"], bt).astype(cd))
            cv = (_paged_gather(pages["v"], bt).astype(cd)
                  * _paged_gather(pages["v_scale"], bt).astype(cd))
            mask = _cache_mask(zeros, s, ck.shape[2], d.causal)
            y = _einsum_attention(q, ck, cv, causal=False, ctx=ctx, mask=mask)
        else:
            pages = {"k": _paged_write(pages["k"], page, row, k),
                     "v": _paged_write(pages["v"], page, row, v)}
            use_kernel = (ctx.backend == "pallas"
                          and jax.default_backend() == "tpu") \
                or ctx.force_paged_kernel
            if use_kernel and d.causal:
                # TPU path: block-table-indexed flash kernel — pages are
                # DMA'd on demand, the contiguous view never exists.
                # ctx.kv_split / ctx.pages_per_step ride through here:
                # the kernel partitions the block table into parallel
                # flash-decoding lanes (None = cost-model auto).
                # ``force_paged_kernel`` drives the same kernel in
                # interpret mode off-TPU (CPU conformance suites).
                from ..kernels.ops import paged_attention
                y = paged_attention(q, pages["k"], pages["v"], bt, zeros,
                                    kv_split=ctx.kv_split,
                                    pages_per_step=ctx.pages_per_step,
                                    backend="pallas")
            else:
                ck = _paged_gather(pages["k"], bt)
                cv = _paged_gather(pages["v"], bt)
                mask = _cache_mask(zeros, s, ck.shape[2], d.causal)
                y = _einsum_attention(q, ck, cv, causal=False, ctx=ctx,
                                      mask=mask)
        new_cache = {"pages": pages, "block_table": bt}
    elif cache is not None:
        # decode (s == 1) or chunked prefill: write K/V at cache_pos
        zeros = jnp.zeros((b,), jnp.int32) if cache_pos is None else cache_pos
        def write(c, u):
            return jax.vmap(lambda cc, uu, i: jax.lax.dynamic_update_slice(
                cc, uu.astype(cc.dtype), (0, i, 0)))(c, u, zeros)

        quantized = "k_scale" in cache
        if quantized:  # int8 cache: quantize the new tokens' K/V
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            new_cache = {"k": write(cache["k"], kq),
                         "v": write(cache["v"], vq),
                         "k_scale": write(cache["k_scale"], ks),
                         "v_scale": write(cache["v_scale"], vs)}
            ck = (new_cache["k"].astype(ctx.compute_dtype)
                  * new_cache["k_scale"].astype(ctx.compute_dtype))
            cv = (new_cache["v"].astype(ctx.compute_dtype)
                  * new_cache["v_scale"].astype(ctx.compute_dtype))
        else:
            ck = write(cache["k"], k)
            cv = write(cache["v"], v)
            new_cache = {"k": ck, "v": cv}
        from ..dist.options import flags
        from ..dist.constrain import current_mesh
        mesh = current_mesh()
        if (flags().seq_kv and mesh is not None
                and "model" in mesh.axis_names
                and d.n_kv_heads % mesh.shape["model"] != 0):
            # §Perf H3: sequence-sharded cache; queries replicate (tiny)
            ck = constrain(ck, "dp", None, "tp", None)
            cv = constrain(cv, "dp", None, "tp", None)
            q = constrain(q, "dp", None, None, None)
        mask = _cache_mask(zeros, s, ck.shape[2], d.causal)
        y = _einsum_attention(q, ck, cv, causal=False, ctx=ctx, mask=mask)
    else:
        causal = d.causal and kv_input is None
        if ctx.backend == "pallas" and jax.default_backend() == "tpu":
            # TPU execution path: the flash Pallas kernel (wrapped in
            # shard_map over batch/head shards by the serving launcher)
            from ..kernels.ops import attention as flash
            y = flash(q, k, v, causal=causal, backend=ctx.backend)
        elif max(s, skv) > CHUNK_THRESHOLD:
            y = _chunked_attention(q, k, v, causal=causal, ctx=ctx)
        else:
            y = _einsum_attention(q, k, v, causal=causal, ctx=ctx)

    y = y.transpose(0, 2, 1, 3).reshape(b, s, d.n_heads * d.head_dim)
    return linear(p["wo"], y, ctx, path=f"{path}/wo"), new_cache


# ===========================================================================
# MLA (deepseek-v2 multi-head latent attention)
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class MLADims:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def mla_init(rng, d: MLADims, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    h = d.n_heads
    return {
        "wq_a": linear_init(ks[0], d.d_model, d.q_lora_rank, dtype=dtype),
        "q_norm": rmsnorm_init(d.q_lora_rank, dtype),
        "wq_b": linear_init(ks[1], d.q_lora_rank, h * d.qk_dim, dtype=dtype),
        "wkv_a": linear_init(ks[2], d.d_model,
                             d.kv_lora_rank + d.qk_rope_dim, dtype=dtype),
        "kv_norm": rmsnorm_init(d.kv_lora_rank, dtype),
        "wkv_b": linear_init(ks[3], d.kv_lora_rank,
                             h * (d.qk_nope_dim + d.v_head_dim), dtype=dtype),
        "wo": linear_init(ks[4], h * d.v_head_dim, d.d_model, dtype=dtype),
    }


def mla_cache_spec(d: MLADims, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Latent cache: compressed KV (B, S, kv_lora) + shared RoPE key.

    int8 requests fall back to bf16: the MLA latent *is* the cache
    compression (576 B/token vs GQA's KB/token), and the normed latent is
    precision-sensitive (§Arch-applicability).
    """
    if dtype == jnp.int8:
        dtype = jnp.bfloat16
    return {"ckv": jnp.zeros((batch, max_len, d.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, d.qk_rope_dim), dtype)}


def mla_paged_cache_spec(d: MLADims, batch: int, num_pages: int,
                         page_size: int, table_width: int,
                         dtype=jnp.bfloat16):
    """Paged MLA latent cache: (P+1, page_size, kv_lora / rope) pages.

    Same pool/table/trash-page scheme as :func:`gqa_paged_cache_spec`;
    the latent has no head axis, so a page row is one token's compressed
    KV.  int8 falls back to bf16 exactly as the dense spec does (the
    latent *is* the compression)."""
    if dtype == jnp.int8:
        dtype = jnp.bfloat16
    return {"pages": {
                "ckv": jnp.zeros((num_pages + 1, page_size,
                                  d.kv_lora_rank), dtype),
                "krope": jnp.zeros((num_pages + 1, page_size,
                                    d.qk_rope_dim), dtype)},
            "block_table": jnp.full((batch, table_width), num_pages,
                                    jnp.int32)}


def _mla_qkv(p, x, d: MLADims, ctx, positions, path):
    b, s, _ = x.shape
    h = d.n_heads
    q = linear(p["wq_b"], rmsnorm(p["q_norm"],
                                  linear(p["wq_a"], x, ctx, path=f"{path}/wq_a")),
               ctx, path=f"{path}/wq_b").reshape(b, s, h, d.qk_dim)
    q_nope, q_rope = q[..., :d.qk_nope_dim], q[..., d.qk_nope_dim:]
    q_rope = apply_rope(q_rope.transpose(0, 2, 1, 3), positions[:, None],
                        theta=d.rope_theta).transpose(0, 2, 1, 3)

    kv_a = linear(p["wkv_a"], x, ctx, path=f"{path}/wkv_a")
    ckv = rmsnorm(p["kv_norm"], kv_a[..., :d.kv_lora_rank])
    krope = apply_rope(kv_a[..., None, d.kv_lora_rank:].transpose(0, 2, 1, 3),
                       positions[:, None], theta=d.rope_theta
                       ).transpose(0, 2, 1, 3)[:, :, 0]   # (B, S, rope_dim)
    return q_nope, q_rope, ckv, krope


def mla_apply(p, x: jnp.ndarray, d: MLADims, ctx: QuantContext = DEFAULT_CTX,
              *, positions: Optional[jnp.ndarray] = None,
              cache=None, cache_pos: Optional[jnp.ndarray] = None,
              path: str = "attn") -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, _ = x.shape
    h = d.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :] + (
            cache_pos[:, None] if cache_pos is not None else 0)
    q_nope, q_rope, ckv, krope = _mla_qkv(p, x, d, ctx, positions, path)
    # wkv_b is consumed raw (reshaped into absorbed-form einsums, not via
    # linear()); a pre-quantized QTensor from ptq_params is dequantized
    # once here — still zero calibrate/round work per forward.
    w_b = p["wkv_b"]["w"]
    if isinstance(w_b, QTensor):
        w_b = w_b.dequantize(ctx.compute_dtype)
    wkv_b = w_b.reshape(d.kv_lora_rank, h,
                        d.qk_nope_dim + d.v_head_dim)
    w_uk = wkv_b[..., :d.qk_nope_dim]       # (lora, H, qk_nope)
    w_uv = wkv_b[..., d.qk_nope_dim:]       # (lora, H, v_dim)

    if cache is None:
        # ---- prefill / training: naive form, per-head K/V materialized
        k_nope = jnp.einsum("bsl,lhd->bshd", ckv.astype(jnp.float32),
                            w_uk.astype(jnp.float32)).astype(x.dtype)
        v = jnp.einsum("bsl,lhd->bshd", ckv.astype(jnp.float32),
                       w_uv.astype(jnp.float32)).astype(x.dtype)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(krope[:, :, None],
                                      (b, s, h, d.qk_rope_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        qT, kT, vT = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        qT = _constrain_heads(qT, "q")
        kT = _constrain_heads(kT, "kv")
        vT = _constrain_heads(vT, "kv")
        if ctx.backend == "pallas" and jax.default_backend() == "tpu":
            from ..kernels.ops import attention as flash
            # flash kernel wants dv == dqk: zero-pad V and slice after
            pad = d.qk_dim - d.v_head_dim
            vp = jnp.pad(vT, ((0, 0), (0, 0), (0, 0), (0, pad)))
            y = flash(qT, kT, vp, causal=True,
                      softmax_scale=d.qk_dim ** -0.5, backend=ctx.backend)
            y = y[..., :d.v_head_dim]
        elif s > CHUNK_THRESHOLD:
            y = _chunked_attention(qT, kT, vT, causal=True, ctx=ctx)
        else:
            y = _einsum_attention(qT, kT, vT, causal=True, ctx=ctx)
        y = y.transpose(0, 2, 1, 3).reshape(b, s, h * d.v_head_dim)
        return linear(p["wo"], y, ctx, path=f"{path}/wo"), None

    # ---- decode: absorbed form against the latent cache -------------------
    zeros = jnp.zeros((b,), jnp.int32) if cache_pos is None else cache_pos
    if "pages" in cache:
        # paged latent: scatter this chunk's rows into the slot's pages,
        # score against the gathered logical view (write-before-attend)
        pages, bt = cache["pages"], cache["block_table"]
        page, row = _page_coords(bt, zeros, s, pages["ckv"].shape[1])
        pages = {"ckv": pages["ckv"].at[page, row].set(
                     ckv.astype(pages["ckv"].dtype)),
                 "krope": pages["krope"].at[page, row].set(
                     krope.astype(pages["krope"].dtype))}
        cckv = pages["ckv"][bt].reshape(b, -1, d.kv_lora_rank)
        ckrope = pages["krope"][bt].reshape(b, -1, d.qk_rope_dim)
        new_cache = {"pages": pages, "block_table": bt}
    else:
        cckv = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u.astype(c.dtype), (i, 0)))(cache["ckv"], ckv, zeros)
        ckrope = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
            c, u.astype(c.dtype), (i, 0)))(cache["krope"], krope, zeros)
        new_cache = {"ckv": cckv, "krope": ckrope}

    # absorb W_uk into the query: q_abs (B, s, H, lora)
    cd = ctx.compute_dtype
    q_abs = jnp.einsum("bshd,lhd->bshl", q_nope.astype(cd),
                       w_uk.astype(cd),
                       preferred_element_type=jnp.float32)
    logits = (jnp.einsum("bshl,btl->bhst", q_abs.astype(cd),
                         cckv.astype(cd),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", q_rope.astype(cd),
                           ckrope.astype(cd),
                           preferred_element_type=jnp.float32)
              ) * (d.qk_dim ** -0.5)
    mask = _cache_mask(zeros, s, cckv.shape[1], True)      # (B, s, T)
    logits = jnp.where(mask[:, None], logits, -1e30)       # (B, H, s, T)
    w = softmax(logits, ctx, axis=-1)
    lat = jnp.einsum("bhst,btl->bshl", w.astype(cd), cckv.astype(cd),
                     preferred_element_type=jnp.float32)
    y = jnp.einsum("bshl,lhd->bshd", lat.astype(cd), w_uv.astype(cd),
                   preferred_element_type=jnp.float32)
    y = y.reshape(b, s, h * d.v_head_dim).astype(x.dtype)
    return linear(p["wo"], y, ctx, path=f"{path}/wo"), new_cache
