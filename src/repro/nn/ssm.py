"""Mamba-2 (SSD — state-space duality) blocks: chunked train/prefill path
and O(1)-state decode path.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060) splits the sequence
into chunks of ``Q`` tokens: within a chunk the recurrence is expanded into
an attention-like quadratic form (MXU-friendly batched einsums); across
chunks a low-rank state (P heads × N state × hd head-dim) is carried by an
associative scan — O(S·Q) work instead of O(S²), and the cross-chunk scan
is log-depth.

Sharding: heads ``P`` shard over the ``model`` axis (all einsums below are
contraction-free over P), batch over ``data``/``pod``.  The recurrence
state is the *decode cache*: (B, P, N, hd) per layer, independent of
context length — which is why ``long_500k`` runs for SSM/hybrid archs while
pure-attention archs skip it (DESIGN.md §Arch-applicability).

Numerics: the state recurrence runs in float32 regardless of the
quantization context (documented §Arch-applicability caveat); in/out
projections and the conv are ordinary quantizable linears; ``dt`` goes
through the LUT softplus when ``ctx.use_lut``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .activations import act_fn
from .context import DEFAULT_CTX, QuantContext
from .linear import linear, linear_init
from .norms import rmsnorm

__all__ = ["SSMDims", "mamba2_init", "mamba2_apply", "mamba2_decode_step",
           "mamba2_state_spec"]


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # hd
    expand: int = 2
    n_groups: int = 1           # G (B/C parameter groups)
    d_conv: int = 4
    chunk: int = 256            # Q — SSD chunk length

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba2_init(rng, d: SSMDims, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    di, p_heads = d.d_inner, d.n_heads
    in_dim = 2 * di + 2 * d.n_groups * d.d_state + p_heads
    return {
        "in_proj": linear_init(ks[0], d.d_model, in_dim, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d.d_conv, d.conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d.conv_dim,), dtype),
        "A_log": jnp.zeros((p_heads,), jnp.float32),      # A = -exp(A_log)
        "D": jnp.ones((p_heads,), jnp.float32),
        "dt_bias": jnp.zeros((p_heads,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": linear_init(ks[3], di, d.d_model, dtype=dtype),
    }


def mamba2_state_spec(d: SSMDims, batch: int, dtype=jnp.float32):
    """Decode cache: depthwise-conv window + SSM recurrence state."""
    return {
        "conv": jnp.zeros((batch, d.d_conv - 1, d.conv_dim), dtype),
        "ssm": jnp.zeros((batch, d.n_heads, d.d_state, d.head_dim), dtype),
    }


def _causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray,
                           b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, C); w: (K, C) depthwise causal conv along S."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4): unrolled taps, no conv primitive
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out + b


def _split_zxbcdt(zxbcdt, d: SSMDims):
    di, gn = d.d_inner, d.n_groups * d.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn:]
    return z, xbc, dt


def mamba2_apply(p, x: jnp.ndarray, d: SSMDims,
                 ctx: QuantContext = DEFAULT_CTX, *, path: str = "ssm",
                 initial_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence SSD. x: (B, S, Dm) with S % chunk == 0.

    Returns (y, final_ssm_state) — the state seeds chunked prefill→decode.
    """
    bsz, s, _ = x.shape
    q = min(d.chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    ph, hd, n, g = d.n_heads, d.head_dim, d.d_state, d.n_groups

    zxbcdt = linear(p["in_proj"], x, ctx, path=f"{path}/in_proj")
    z, xbc_raw, dt = _split_zxbcdt(zxbcdt, d)
    conv_tail = xbc_raw[:, -(d.d_conv - 1):]   # decode conv window seed
    xbc = act_fn("silu", _causal_depthwise_conv(
        xbc_raw.astype(jnp.float32), p["conv_w"].astype(jnp.float32),
        p["conv_b"].astype(jnp.float32)), ctx, path=f"{path}/conv_act")

    from ..dist.constrain import constrain
    xh = xbc[..., :d.d_inner].reshape(bsz, s, ph, hd).astype(jnp.float32)
    b_ = xbc[..., d.d_inner:d.d_inner + g * n].reshape(bsz, s, g, n)
    c_ = xbc[..., d.d_inner + g * n:].reshape(bsz, s, g, n)
    # heads per group (G=1 ⇒ broadcast over all heads)
    b_ = jnp.repeat(b_, ph // g, axis=2).astype(jnp.float32)  # (B,S,P,N)
    c_ = jnp.repeat(c_, ph // g, axis=2).astype(jnp.float32)
    # TP over SSD heads: every einsum below is elementwise in P
    xh = constrain(xh, "dp", None, "tp", None)
    b_ = constrain(b_, "dp", None, "tp", None)
    c_ = constrain(c_, "dp", None, "tp", None)

    dt = act_fn("softplus", dt.astype(jnp.float32) + p["dt_bias"], ctx,
                path=f"{path}/dt")                             # (B,S,P)
    a = -jnp.exp(p["A_log"])                                   # (P,)
    da = dt * a                                                # (B,S,P)

    # ---- chunk ------------------------------------------------------------
    def ch(t):  # (B, S, ...) -> (B, nc, Q, ...)
        return t.reshape(bsz, nc, q, *t.shape[2:])
    xh_c, b_c, c_c, dt_c, da_c = map(ch, (xh, b_, c_, dt, da))
    ca = jnp.cumsum(da_c, axis=2)                              # (B,nc,Q,P)

    # ---- intra-chunk (attention-like quadratic form) -----------------------
    # att[i, j] = (C_i · B_j) * exp(ca_i - ca_j) * dt_j   for i >= j
    scores = jnp.einsum("bcipn,bcjpn->bcijp", c_c, b_c)
    decay = jnp.exp(ca[:, :, :, None, :] - ca[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((q, q), bool))
    att = jnp.where(mask[None, None, :, :, None], scores * decay, 0.0)
    y_intra = jnp.einsum("bcijp,bcjp,bcjph->bciph", att, dt_c, xh_c)

    # ---- chunk states ------------------------------------------------------
    decay_out = jnp.exp(ca[:, :, -1:, :] - ca)                 # (B,nc,Q,P)
    s_c = jnp.einsum("bcjpn,bcjp,bcjph->bcpnh", b_c, dt_c * decay_out, xh_c)

    # ---- inter-chunk associative recurrence: h_c = g_c·h_{c-1} + s_c -------
    g_c = jnp.exp(ca[:, :, -1, :])[..., None, None]            # (B,nc,P,1,1)
    if initial_state is not None:
        s_c = s_c.at[:, 0].add(g_c[:, 0] * initial_state.astype(jnp.float32))

    def combine(l, r):
        gl, sl = l
        gr, sr = r
        return gl * gr, gr * sl + sr

    g_all, h_all = jax.lax.associative_scan(combine, (g_c, s_c), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_all[:, :1]), h_all[:, :-1]], axis=1)
    if initial_state is not None:
        # h_all already includes the seed via s_c[0]; h_prev[0] is the seed
        h_prev = h_prev.at[:, 0].set(initial_state.astype(jnp.float32))

    y_inter = jnp.einsum("bcipn,bcpnh,bcip->bciph", c_c, h_prev, jnp.exp(ca))

    y = (y_intra + y_inter).reshape(bsz, s, ph, hd)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, s, d.d_inner)

    # gated RMSNorm, then output projection
    y = rmsnorm(p["norm"], y * act_fn("silu", z.astype(jnp.float32), ctx,
                                      path=f"{path}/gate"))
    out = linear(p["out_proj"], y.astype(x.dtype), ctx,
                 path=f"{path}/out_proj")
    final_state = {"conv": conv_tail.astype(jnp.float32),
                   "ssm": h_all[:, -1]}
    return out, final_state


def mamba2_decode_step(p, x: jnp.ndarray, state, d: SSMDims,
                       ctx: QuantContext = DEFAULT_CTX, *,
                       path: str = "ssm"):
    """One-token step. x: (B, 1, Dm); state from :func:`mamba2_state_spec`.

    Returns (y (B, 1, Dm), new_state).  O(1) in context length.
    """
    bsz = x.shape[0]
    ph, hd, n, g = d.n_heads, d.head_dim, d.d_state, d.n_groups

    zxbcdt = linear(p["in_proj"], x, ctx, path=f"{path}/in_proj")
    z, xbc, dt = _split_zxbcdt(zxbcdt[:, 0], d)                # (B, ...)

    window = jnp.concatenate(
        [state["conv"], xbc[:, None].astype(state["conv"].dtype)], axis=1)
    conv_out = (jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                           p["conv_w"].astype(jnp.float32))
                + p["conv_b"].astype(jnp.float32))
    xbc_t = act_fn("silu", conv_out, ctx, path=f"{path}/conv_act")
    new_conv = window[:, 1:]

    xh = xbc_t[..., :d.d_inner].reshape(bsz, ph, hd).astype(jnp.float32)
    b_ = xbc_t[..., d.d_inner:d.d_inner + g * n].reshape(bsz, g, n)
    c_ = xbc_t[..., d.d_inner + g * n:].reshape(bsz, g, n)
    b_ = jnp.repeat(b_, ph // g, axis=1).astype(jnp.float32)   # (B,P,N)
    c_ = jnp.repeat(c_, ph // g, axis=1).astype(jnp.float32)

    dt = act_fn("softplus", dt.astype(jnp.float32) + p["dt_bias"], ctx,
                path=f"{path}/dt")                             # (B,P)
    ga = jnp.exp(dt * -jnp.exp(p["A_log"]))[..., None, None]   # (B,P,1,1)
    upd = jnp.einsum("bp,bpn,bph->bpnh", dt, b_, xh)
    h = ga * state["ssm"].astype(jnp.float32) + upd            # (B,P,N,hd)

    y = jnp.einsum("bpn,bpnh->bph", c_, h)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, d.d_inner)
    y = rmsnorm(p["norm"], y * act_fn("silu", z.astype(jnp.float32), ctx,
                                      path=f"{path}/gate"))
    out = linear(p["out_proj"], y[:, None].astype(x.dtype), ctx,
                 path=f"{path}/out_proj")
    return out, {"conv": new_conv, "ssm": h.astype(state["ssm"].dtype)}
