"""Execution context threading the paper's knobs through the model stack.

:class:`QuantContext` is how the de-specialized library reaches every
layer: which numeric mode the matmuls run in, whether activations go
through constant tables, which backend lowers the hot ops, and the
``reuse_factor``.  It is a frozen dataclass (hashable) so jitted step
functions can close over it as static configuration.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from ..core.precision import PrecisionPolicy
from ..core.qtypes import FixedPointType

__all__ = ["QuantContext", "DEFAULT_CTX"]

_MODES = ("none", "fake", "int8")


@dataclasses.dataclass(frozen=True)
class QuantContext:
    """Numeric execution configuration for one forward/backward pass.

    mode:
      * ``none`` — matmuls in ``compute_dtype`` (paper-faithful float path).
      * ``fake`` — straight-through fake quantization of weights (+
        activations if the policy says so): QAT / PTQ-accuracy simulation.
      * ``int8`` — dynamic-range integer execution on the MXU path via the
        ``qmatmul`` kernel (weights pre-quantized or quantized on the fly).
    use_lut:
      route non-trivial activations (gelu/silu/softplus/softmax-exp)
      through trace-time constant tables instead of transcendentals.
    reuse_factor:
      the paper's parallelism/resource knob.  1 = fully parallel.  Higher
      values serialize: layer-scan stays rolled (unroll = max(8 //
      reuse_factor, 1)) and kernel block K is divided accordingly.
    backend:
      kernel backend override (None = registry default; "ref" | "pallas").
    """

    mode: str = "none"
    policy: PrecisionPolicy = PrecisionPolicy()
    act_qtype: Optional[FixedPointType] = None
    use_lut: bool = False
    table_n: int = 1024
    table_indexing: str = "interp"
    reuse_factor: int = 1
    backend: Optional[str] = None
    compute_dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.float32
    softmax_exact_divide: bool = True
    respect_user_type: bool = False   # de-specialized softmax-table fix
    #: 8 → int8 KV cache with per-(token, head) scales (paper's
    #: quantization aimed at the dominant decode memory term); None = the
    #: cache dtype passed to init_cache (bf16 default).
    kv_cache_bits: Optional[int] = None
    #: split-KV paged attention — the kernel-side reuse-factor pair.
    #: ``kv_split`` cuts each slot's block table into that many parallel
    #: flash-decoding partitions (merged by a log-sum-exp combine);
    #: ``pages_per_step`` is the multi-page DMA tile per grid step.
    #: None = resolve from the cached cost model
    #: (:func:`repro.kernels.flash_attention.choose_kv_split`); 1/1 is
    #: byte-for-byte the pre-split kernel.
    kv_split: Optional[int] = None
    pages_per_step: Optional[int] = None
    #: route the paged f32 decode path through the Pallas kernel even
    #: off-TPU (interpret mode) — the CPU conformance hook that lets the
    #: engine suites drive the real block-table kernel end to end; never
    #: set in production serving (interpret mode is orders of magnitude
    #: slower than the gather/einsum CPU path).
    force_paged_kernel: bool = False

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}")
        if self.reuse_factor < 1:
            raise ValueError("reuse_factor >= 1")
        for knob in ("kv_split", "pages_per_step"):
            v = getattr(self, knob)
            if v is not None and v < 1:
                raise ValueError(f"{knob} must be >= 1 (or None = auto)")

    @property
    def scan_unroll(self) -> int:
        return max(8 // self.reuse_factor, 1)


DEFAULT_CTX = QuantContext()
