"""Token embedding + (optionally tied) LM head."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .context import DEFAULT_CTX, QuantContext

__all__ = ["embedding_init", "embed", "unembed"]


def embedding_init(rng, vocab: int, d: int, *, dtype=jnp.float32):
    tbl = jax.random.normal(rng, (vocab, d), jnp.float32) * (d ** -0.5)
    return {"table": tbl.astype(dtype)}


def embed(p, tokens: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX, *,
          scale_by_dim: bool = False) -> jnp.ndarray:
    """tokens (B, S) int32 → (B, S, D).  ``scale_by_dim``: gemma's √d."""
    tbl = p["table"].astype(ctx.compute_dtype)
    y = jnp.take(tbl, tokens, axis=0)
    if scale_by_dim:
        y = y * jnp.asarray(tbl.shape[-1] ** 0.5, y.dtype)
    return y


def unembed(p, x: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX) -> jnp.ndarray:
    """(B, S, D) → logits (B, S, V) against the (tied) embedding table."""
    tbl = p["table"].astype(ctx.compute_dtype)
    return jnp.einsum("...d,vd->...v", x.astype(ctx.compute_dtype), tbl)
