"""Transformer / SSM block assemblies and the layer-scan machinery.

Blocks are init/apply function pairs over plain pytrees.  Stacks of
identical blocks are built with ``vmap(init)`` (stacked params, leading L
axis) and executed with ``lax.scan`` — this keeps the HLO size O(1) in
depth (critical for 512-device compiles) and is where the paper's
``reuse_factor`` meets the graph: ``ctx.scan_unroll`` controls how many
layers unroll per scan step.  Activation rematerialization wraps the block
body per the config (none / dots / full).
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from .activations import act_fn
from .attention import (AttnDims, gqa_apply, gqa_cache_spec, gqa_init,
                        mla_apply, mla_cache_spec, mla_init)
from .context import DEFAULT_CTX, QuantContext
from .linear import linear, linear_init
from .moe import MoEDims, moe_apply, moe_init
from .norms import layernorm, layernorm_init, rmsnorm, rmsnorm_init
from .ssm import SSMDims, mamba2_apply, mamba2_decode_step, mamba2_init

__all__ = ["mlp_init", "mlp_apply", "dense_block_init", "dense_block_apply",
           "moe_block_init", "moe_block_apply", "cross_block_init",
           "cross_block_apply", "mamba_block_init", "mamba_block_apply",
           "stack_init", "scan_apply", "norm_init", "norm_apply",
           "moe_dims_of"]


# -- norms dispatched on config --------------------------------------------
def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    return (rmsnorm_init(d) if cfg.norm_type == "rmsnorm"
            else layernorm_init(d))


def norm_apply(cfg: ModelConfig, p, x):
    if cfg.norm_type == "rmsnorm":
        return rmsnorm(p, x, eps=cfg.norm_eps, plus_one=cfg.norm_plus_one)
    return layernorm(p, x, eps=cfg.norm_eps)


# -- MLP ---------------------------------------------------------------------
def mlp_init(rng, d_model: int, d_ff: int, *, gated: bool = True,
             dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    p = {"up": linear_init(ks[0], d_model, d_ff, dtype=dtype),
         "down": linear_init(ks[1], d_ff, d_model, dtype=dtype)}
    if gated:
        p["gate"] = linear_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(p, x, act: str, ctx: QuantContext = DEFAULT_CTX, *,
              path: str = "mlp"):
    """Gated (SwiGLU-style) or plain MLP.

    The activation is handed to ``linear()`` so the int8+LUT path fuses
    it (with the bias) into the qmatmul epilogue — dense→activation in
    one kernel launch; other paths apply the identical ``act_fn``.
    """
    if "gate" in p:
        up = linear(p["up"], x, ctx, path=f"{path}/up")
        g = linear(p["gate"], x, ctx, path=f"{path}/gate", act=act,
                   act_path=f"{path}/act")
        h = g * up
    else:
        h = linear(p["up"], x, ctx, path=f"{path}/up", act=act,
                   act_path=f"{path}/act")
    return linear(p["down"], h, ctx, path=f"{path}/down")


# -- dense transformer block -------------------------------------------------
def dense_block_init(rng, cfg: ModelConfig, *, causal: bool = True,
                     dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    dims = cfg.attn_dims(causal=causal)
    p = {"ln1": norm_init(cfg), "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                                gated=cfg.mlp_gated,
                                                dtype=dtype)}
    if cfg.attn_kind == "mla":
        p["attn"] = mla_init(ks[0], cfg.mla, dtype=dtype)
    else:
        p["attn"] = gqa_init(ks[0], dims, dtype=dtype)
    if not cfg.parallel_block:
        p["ln2"] = norm_init(cfg)
    return p


def dense_block_apply(p, x, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX,
                      *, causal: bool = True, positions=None, cache=None,
                      cache_pos=None, path: str = "block"):
    dims = cfg.attn_dims(causal=causal)
    h = norm_apply(cfg, p["ln1"], x)
    if cfg.attn_kind == "mla":
        a, new_cache = mla_apply(p["attn"], h, cfg.mla, ctx,
                                 positions=positions, cache=cache,
                                 cache_pos=cache_pos, path=f"{path}/attn")
    else:
        a, new_cache = gqa_apply(p["attn"], h, dims, ctx,
                                 positions=positions, cache=cache,
                                 cache_pos=cache_pos, path=f"{path}/attn")
    if cfg.parallel_block:  # command-r: attn and MLP share the same norm
        m = mlp_apply(p["mlp"], h, cfg.mlp_act, ctx, path=f"{path}/mlp")
        return x + a + m, new_cache
    x = x + a
    m = mlp_apply(p["mlp"], norm_apply(cfg, p["ln2"], x), cfg.mlp_act, ctx,
                  path=f"{path}/mlp")
    return x + m, new_cache


# -- MoE block ----------------------------------------------------------------
def moe_dims_of(cfg: ModelConfig) -> MoEDims:
    m = cfg.moe
    return MoEDims(d_model=cfg.d_model, d_ff=m.d_ff_expert,
                   n_experts=m.n_experts, top_k=m.top_k,
                   capacity_factor=m.capacity_factor,
                   renormalize=m.renormalize, act=cfg.mlp_act,
                   routed_scale=m.routed_scale)


def moe_block_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {"ln1": norm_init(cfg), "ln2": norm_init(cfg),
         "moe": moe_init(ks[1], moe_dims_of(cfg), dtype=dtype)}
    if cfg.attn_kind == "mla":
        p["attn"] = mla_init(ks[0], cfg.mla, dtype=dtype)
    else:
        p["attn"] = gqa_init(ks[0], cfg.attn_dims(), dtype=dtype)
    if cfg.moe.n_shared:
        p["shared"] = mlp_init(ks[2], cfg.d_model,
                               cfg.moe.n_shared * cfg.moe.d_ff_expert,
                               gated=True, dtype=dtype)
    return p


def moe_block_apply(p, x, cfg: ModelConfig, ctx: QuantContext = DEFAULT_CTX,
                    *, positions=None, cache=None, cache_pos=None,
                    path: str = "moe_block"):
    h = norm_apply(cfg, p["ln1"], x)
    if cfg.attn_kind == "mla":
        a, new_cache = mla_apply(p["attn"], h, cfg.mla, ctx,
                                 positions=positions, cache=cache,
                                 cache_pos=cache_pos, path=f"{path}/attn")
    else:
        a, new_cache = gqa_apply(p["attn"], h, cfg.attn_dims(), ctx,
                                 positions=positions, cache=cache,
                                 cache_pos=cache_pos, path=f"{path}/attn")
    x = x + a
    h2 = norm_apply(cfg, p["ln2"], x)
    y, aux = moe_apply(p["moe"], h2, moe_dims_of(cfg), ctx,
                       path=f"{path}/moe", dropless=cache is not None)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], h2, cfg.mlp_act, ctx,
                          path=f"{path}/shared")
    return x + y, new_cache, aux


# -- cross-attention block (vlm / encdec decoder) ------------------------------
def cross_block_init(rng, cfg: ModelConfig, *, gated: bool = False,
                     dtype=jnp.float32):
    """Self-attn-free cross block (llama-vision style when ``gated``)."""
    ks = jax.random.split(rng, 3)
    p = {"ln1": norm_init(cfg),
         "attn": gqa_init(ks[0], cfg.attn_dims(causal=False), dtype=dtype),
         "ln2": norm_init(cfg),
         "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.mlp_gated,
                         dtype=dtype)}
    if gated:
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    return p


def cross_block_apply(p, x, kv, cfg: ModelConfig,
                      ctx: QuantContext = DEFAULT_CTX, *,
                      path: str = "cross"):
    a, _ = gqa_apply(p["attn"], norm_apply(cfg, p["ln1"], x),
                     cfg.attn_dims(causal=False), ctx, kv_input=kv,
                     path=f"{path}/attn")
    if "gate_attn" in p:
        a = a * jnp.tanh(p["gate_attn"]).astype(a.dtype)
    x = x + a
    m = mlp_apply(p["mlp"], norm_apply(cfg, p["ln2"], x), cfg.mlp_act, ctx,
                  path=f"{path}/mlp")
    if "gate_mlp" in p:
        m = m * jnp.tanh(p["gate_mlp"]).astype(m.dtype)
    return x + m


# -- mamba block ----------------------------------------------------------------
def mamba_block_init(rng, cfg: ModelConfig, *, dtype=jnp.float32):
    return {"ln": norm_init(cfg),
            "ssm": mamba2_init(rng, cfg.ssm, dtype=dtype)}


def mamba_block_apply(p, x, cfg: ModelConfig,
                      ctx: QuantContext = DEFAULT_CTX, *, state=None,
                      decode: bool = False, path: str = "mamba"):
    h = norm_apply(cfg, p["ln"], x)
    if decode:
        y, new_state = mamba2_decode_step(p["ssm"], h, state, cfg.ssm, ctx,
                                          path=f"{path}/ssm")
    else:
        y, new_state = mamba2_apply(p["ssm"], h, cfg.ssm, ctx,
                                    path=f"{path}/ssm")
    return x + y, new_state


# -- stacks: vmapped init + scanned apply ---------------------------------------
def stack_init(rng, n: int, init_fn: Callable):
    """Stacked params for ``n`` identical blocks (leading L axis)."""
    keys = jax.random.split(rng, n)
    return jax.vmap(init_fn)(keys)


def _remat_wrap(fn: Callable, remat: str) -> Callable:
    if remat == "none":
        return fn
    if remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "full": save nothing


def scan_apply(stacked, x, body: Callable, *, remat: str = "full",
               unroll: int = 1, carry_aux: bool = False,
               per_layer=None):
    """Run ``body(params_l, x, per_layer_l) -> (x', y_l)`` over the stack.

    ``per_layer``: optional pytree with leading L axis scanned alongside
    params (e.g. a KV cache).  Returns (x_final, stacked_ys, aux_sum).
    """
    from ..dist.constrain import constrain
    body_r = _remat_wrap(body, remat)

    def step(carry, layer):
        x, aux = carry
        params_l, extra_l = layer
        if x.ndim == 3:  # pin the residual stream's batch sharding
            x = constrain(x, "dp", None, None)
        x2, y, a = body_r(params_l, x, extra_l)
        if x2.ndim == 3:
            x2 = constrain(x2, "dp", None, None)
        return (x2, aux + a), y

    init = (x, jnp.zeros((), jnp.float32))
    (xf, aux), ys = jax.lax.scan(step, init, (stacked, per_layer),
                                 unroll=unroll)
    return xf, ys, aux
