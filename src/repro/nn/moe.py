"""Mixture-of-Experts FFN: top-k token-choice routing, capacity dispatch,
expert parallelism over the ``model`` mesh axis.

Cluster-scale notes (how this maps at 512 chips):

* Experts are sharded over the ``model`` axis (EP): expert weights are
  (E, D, F) with E split 16-ways; GSPMD turns the dispatch/combine
  gathers into all-to-all-style collectives over ``model``.
* Dispatch avoids the classic (tokens, E, C) one-hot einsum — which is
  O(T·E·C) memory — in favour of scatter/gather against an (E·C, D)
  capacity buffer: position-in-expert comes from a cumsum over slots,
  overflowing tokens are *dropped* (standard capacity-factor semantics)
  by routing them to a dummy slot.
* The router runs in float32 regardless of the quantization context
  (routing decisions are precision-sensitive — §Arch-applicability),
  while expert FFNs follow the per-layer policy like any dense layer.

Supports deepseek-v2 (softmax→top-k→renormalize, shared experts ride
outside this module) and olmoe (softmax→top-k, no renorm).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.qtypes import QTensor
from .activations import act_fn
from .context import DEFAULT_CTX, QuantContext

__all__ = ["MoEDims", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    d_ff: int               # per-expert hidden dim
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    renormalize: bool = True    # deepseek renormalizes top-k gate weights
    act: str = "silu"
    routed_scale: float = 1.0   # deepseek-v2 routed_scaling_factor

    def capacity(self, tokens_per_group: int) -> int:
        c = int(tokens_per_group * self.top_k * self.capacity_factor
                / self.n_experts)
        return max(c, self.top_k)


def moe_init(rng, d: MoEDims, *, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    e, dm, f = d.n_experts, d.d_model, d.d_ff
    s_in, s_out = dm ** -0.5, f ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (dm, e), jnp.float32) * s_in
                   ).astype(jnp.float32),  # router always f32
        "w_gate": (jax.random.normal(ks[1], (e, dm, f), jnp.float32) * s_in
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, dm, f), jnp.float32) * s_in
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, dm), jnp.float32) * s_out
                   ).astype(dtype),
    }


def moe_apply(p, x: jnp.ndarray, d: MoEDims,
              ctx: QuantContext = DEFAULT_CTX, *, path: str = "moe",
              dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (y, aux_loss).  Groups = batch rows (B is the
    dispatch group axis, so capacity is per-sequence and the buffer stays
    data-parallel-sharded).

    ``dropless=True`` (serving): capacity rises to min(S, 4·S·k/E) — exact
    droplessness whenever E ≲ 4k (all smoke/consistency regimes), 4×
    balance headroom at scale, so chunked prefill + decode matches a
    monolithic pass.  Training keeps capacity-factor dropping (standard).
    """
    b, s, dm = x.shape
    e, k = d.n_experts, d.top_k
    if dropless:
        cap = min(s, max(k, -(-4 * s * k // e)))
    else:
        cap = d.capacity(s)

    # ---- routing (f32) ----------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)              # (B, S, k)
    if d.renormalize:
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    gates = gates * d.routed_scale

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                               # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, e), axis=2), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # ---- position-in-expert via cumsum over flattened slots ---------------
    idx_f = idx.reshape(b, s * k)                                    # slots
    onehot = jax.nn.one_hot(idx_f, e, dtype=jnp.int32)               # (B,T,E)
    pos = jnp.cumsum(onehot, axis=1) - 1                             # (B,T,E)
    pos_in_e = jnp.take_along_axis(pos, idx_f[..., None], axis=2)[..., 0]
    keep = pos_in_e < cap
    # dropped tokens go to a dummy trailing slot
    slot = jnp.where(keep, idx_f * cap + pos_in_e, e * cap)          # (B,T)

    # ---- dispatch: tokens into the (E*C, D) capacity buffer ---------------
    from ..dist.constrain import constrain
    from ..dist.options import flags
    tok = jnp.repeat(jnp.arange(s), k)                               # (T,)
    x_slot = jnp.take(x, tok, axis=1)                                # (B,T,D)
    n_slots = e * cap + 1                      # trailing slot = dropped
    onehot = None
    if flags().moe_einsum:
        # §Perf H5: one-hot einsum dispatch — partitions over (dp, slots)
        # with zero collectives; the scatter form makes GSPMD replicate
        # the global capacity buffer and all-reduce it every layer.
        cd = ctx.compute_dtype
        onehot = jax.nn.one_hot(slot, n_slots, dtype=cd)             # (B,T,S)
        onehot = constrain(onehot, "dp", None, "tp")
        buf = jnp.einsum("bts,btd->bsd", onehot, x_slot.astype(cd),
                         preferred_element_type=jnp.float32
                         ).astype(x.dtype)
    else:
        buf = jnp.zeros((b, n_slots, dm), x.dtype)
        bidx = jnp.arange(b)[:, None]
        buf = buf.at[bidx, slot].add(x_slot, mode="drop")
        if flags().moe_local:
            # §Perf H5b: the scatter's indices are batch-local — pin the
            # buffer (dp, replicated) so GSPMD keeps it local instead of
            # replicating + all-reducing the global buffer
            buf = constrain(buf, "dp", None, None)
    xe = buf[:, :-1].reshape(b, e, cap, dm)                          # (B,E,C,D)
    if flags().moe_local:
        xe = constrain(xe, "dp", None, None, None)   # sliced per EP shard
    else:
        xe = constrain(xe, "dp", "tp", None, None)   # EP: experts on `model`

    # ---- expert FFN (SwiGLU), experts sharded over `model` ----------------
    # Pre-quantized (QTensor) expert banks from ptq_params are consumed
    # without any per-forward calibrate/round: dequantize is one fused
    # multiply against the stored per-channel scales.  (Batched per-expert
    # int8 qmatmul dispatch is a follow-up; the dense path here already
    # pays zero quantization work per step.)
    cd = ctx.compute_dtype
    w_gate, w_up, w_down = (
        w.dequantize(cd) if isinstance(w, QTensor) else w.astype(cd)
        for w in (p["w_gate"], p["w_up"], p["w_down"]))
    h_g = jnp.einsum("becd,edf->becf", xe.astype(cd), w_gate)
    h_u = jnp.einsum("becd,edf->becf", xe.astype(cd), w_up)
    h = act_fn(d.act, h_g, ctx, path=f"{path}/act") * h_u
    ye = jnp.einsum("becf,efd->becd", h.astype(cd), w_down)
    ye = constrain(ye, "dp", "tp", None, None)

    # ---- combine: slots back to tokens, weighted by the gate --------------
    yb = ye.reshape(b, e * cap, dm)
    if flags().moe_local:
        # §Perf H5b: one explicit EP all-gather of expert outputs
        yb = constrain(yb, "dp", None, None)
    yb = jnp.concatenate([yb, jnp.zeros((b, 1, dm), yb.dtype)], axis=1)
    if onehot is not None:  # §Perf H5: einsum combine (transpose of dispatch)
        yb = constrain(yb, "dp", "tp", None)
        y_slot = jnp.einsum("bts,bsd->btd", onehot,
                            yb.astype(onehot.dtype),
                            preferred_element_type=jnp.float32)
    else:
        y_slot = jnp.take_along_axis(yb, slot[..., None], axis=1)    # (B,T,D)
    y_slot = y_slot * (gates.reshape(b, s * k, 1).astype(y_slot.dtype)
                       * keep[..., None].astype(y_slot.dtype))
    y = jnp.sum(y_slot.reshape(b, s, k, dm), axis=2)
    return y.astype(x.dtype), aux
