"""Linear layers with pluggable numerics (the heart of the quantized path).

``linear()`` consults the :class:`~repro.nn.context.QuantContext`:

* ``none``  — einsum in ``compute_dtype`` (bf16 MXU path).
* ``fake``  — straight-through fake-quant of weights (and activations if a
  type is set): numerically simulates the paper's ``ac_fixed``/minifloat
  deployment while staying in float storage (QAT & accuracy studies).
* ``int8``  — dynamic-range integer execution: per-row activation scales,
  per-column weight scales, int8×int8→int32 on the MXU via the
  ``qmatmul`` Pallas kernel (HBM traffic halves vs bf16 — the deployment
  path).

**Pre-quantized weights**: ``p["w"]`` may be a
:class:`~repro.core.qtypes.QTensor` produced offline by
:func:`repro.core.quantize.ptq_params`.  Under ``int8`` the payload and
scales feed ``qmatmul`` directly — zero ``calibrate_scale``/``round`` ops
on the weight per forward call (only the activation is quantized
dynamically).  Under other modes the QTensor is dequantized once into the
compute dtype.  This is the hls4ml deployment contract: quantize at model
conversion, not per inference.

**Fused epilogue**: passing ``act=`` (with ``ctx.use_lut``) fuses the
bias add and the LUT activation into the qmatmul kernel's final K step —
linear + bias + activation in ONE kernel launch / HBM pass (the paper's
dense→activation dataflow fusion).  When the fused path does not apply,
``act`` falls back to :func:`repro.nn.activations.act_fn` with identical
numerics.

Per-layer heterogeneity comes from ``ctx.policy.resolve(path)`` — the
hls4ml per-layer config dict, de-specialized.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.precision import LayerPrecision
from ..core.quantize import calibrate_scale, fake_quant
from ..core.qtypes import FixedPointType, MiniFloatType, QTensor
from ..core.tables import GATED_FORMS, TableSpec
from .context import DEFAULT_CTX, QuantContext

__all__ = ["linear_init", "linear"]

#: activations the fused LUT epilogue supports (relu is cheaper exact;
#: softplus needs the piecewise-exact asymptote outside the table domain).
_FUSABLE_ACTS = ("sigmoid", "tanh", "gelu", "silu")


def linear_init(rng, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None):
    std = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(rng, (d_in, d_out), jnp.float32) * std
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def _act_table(act: str, ctx: QuantContext,
               path: str) -> Tuple[TableSpec, bool]:
    """TableSpec + gated flag matching act_fn's LUT selection exactly."""
    from .activations import _LUT_DOMAIN  # table domains live with act_fn
    prec = ctx.policy.resolve(path)
    n = prec.table_n or ctx.table_n
    qt = prec.table_qtype
    lo, hi = _LUT_DOMAIN[act]
    gated = act in GATED_FORMS
    fn = GATED_FORMS[act] if gated else act
    return TableSpec(fn, n, lo, hi, qt, ctx.table_indexing), gated


def _int8_matmul(x2: jnp.ndarray, wq: jnp.ndarray, sw: jnp.ndarray,
                 qt: FixedPointType, ctx: QuantContext, *,
                 bias=None, act_spec=None, act_gated=False) -> jnp.ndarray:
    """(T, K) @ (K, N) through the int8 MXU path (+ fused epilogue).

    The weight arrives already quantized (payload ``wq``, per-column
    scales ``sw``); only the activation is quantized here (per-row
    dynamic scale — it changes every call, the weight does not).
    """
    from ..kernels.ops import qmatmul  # local: kernels import nn-free core

    sx = calibrate_scale(x2, qt, channel_axes=(0,))          # (T, 1)
    xq = jnp.clip(jnp.round(x2 / sx), qt.int_min, qt.int_max).astype(qt.dtype)
    return qmatmul(xq, wq, sx, sw, bias=bias, act_spec=act_spec,
                   act_gated=act_gated, out_dtype=ctx.compute_dtype,
                   backend=ctx.backend)


def _quantize_weight(w: jnp.ndarray, qt: FixedPointType):
    """Dynamic per-column weight quantization (the non-PTQ fallback)."""
    sw = calibrate_scale(w, qt, channel_axes=(1,))           # (1, N)
    wq = jnp.clip(jnp.round(w / sw), qt.int_min, qt.int_max).astype(qt.dtype)
    return wq, sw


def linear(p, x: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX, *,
           path: str = "", act: Optional[str] = None,
           act_path: Optional[str] = None) -> jnp.ndarray:
    """Apply ``act(x @ w (+ b))`` under the context's numeric mode.

    ``act``: optional activation name fused into the kernel epilogue when
    the int8 LUT path applies, applied via ``act_fn`` otherwise.
    ``act_path``: policy-resolution path for the activation (defaults to
    ``f"{path}/act"``), so fused and unfused paths resolve identically.
    """
    w = p["w"]
    prec: LayerPrecision = ctx.policy.resolve(path)
    prequant = isinstance(w, QTensor)
    mode = ctx.mode
    if not prequant and prec.weights is None and mode != "none":
        mode = "none"

    # which int8 weight feed applies?
    wq = sw = qt = None
    if mode == "int8":
        if prequant and isinstance(w.qtype, FixedPointType) \
                and w.qtype.width <= 8:
            qt = w.qtype                       # PTQ artifact: ready to run
            wq, sw = w.data, w.scale.reshape(1, -1)
        elif not prequant and isinstance(prec.weights, FixedPointType) \
                and prec.weights.width <= 8:
            qt = prec.weights                  # dynamic: quantize per call

    bias = p.get("b")
    act_done = bias_done = False
    if qt is not None:
        t_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        if wq is None:
            wq, sw = _quantize_weight(w.astype(jnp.float32), qt)
        fuse_act = act in _FUSABLE_ACTS and ctx.use_lut
        spec, gated = (_act_table(act, ctx, act_path or f"{path}/act")
                       if fuse_act else (None, False))
        fb = None if bias is None else bias.astype(jnp.float32)
        y = _int8_matmul(x2, wq, sw, qt, ctx, bias=fb, act_spec=spec,
                         act_gated=gated)
        y = y.reshape(*t_shape, wq.shape[-1])
        bias_done, act_done = True, fuse_act
    else:
        if prequant:
            w = w.dequantize(ctx.compute_dtype)
        if mode == "fake" and prec.weights is not None:
            w = fake_quant(w.astype(jnp.float32), prec.weights)
        if mode == "fake" and prec.activations is not None:
            x = fake_quant(x.astype(jnp.float32), prec.activations)
        y = jnp.einsum("...k,kn->...n", x.astype(ctx.compute_dtype),
                       w.astype(ctx.compute_dtype))
    if bias is not None and not bias_done:
        y = y + bias.astype(y.dtype)
    if act is not None and not act_done:
        from .activations import act_fn
        y = act_fn(act, y, ctx, path=act_path or f"{path}/act")
    return y
