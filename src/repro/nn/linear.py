"""Linear layers with pluggable numerics (the heart of the quantized path).

``linear()`` consults the :class:`~repro.nn.context.QuantContext`:

* ``none``  — einsum in ``compute_dtype`` (bf16 MXU path).
* ``fake``  — straight-through fake-quant of weights (and activations if a
  type is set): numerically simulates the paper's ``ac_fixed``/minifloat
  deployment while staying in float storage (QAT & accuracy studies).
* ``int8``  — dynamic-range integer execution: per-row activation scales,
  per-column weight scales, int8×int8→int32 on the MXU via the
  ``qmatmul`` Pallas kernel (HBM traffic halves vs bf16 — the deployment
  path).

Per-layer heterogeneity comes from ``ctx.policy.resolve(path)`` — the
hls4ml per-layer config dict, de-specialized.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.precision import LayerPrecision
from ..core.quantize import calibrate_scale, fake_quant
from ..core.qtypes import FixedPointType, MiniFloatType
from .context import DEFAULT_CTX, QuantContext

__all__ = ["linear_init", "linear"]


def linear_init(rng, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None):
    std = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(rng, (d_in, d_out), jnp.float32) * std
               ).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def _int8_matmul(x2: jnp.ndarray, w: jnp.ndarray, qt: FixedPointType,
                 ctx: QuantContext) -> jnp.ndarray:
    """(T, K) @ (K, N) through the int8 MXU path."""
    from ..kernels.ops import qmatmul  # local: kernels import nn-free core

    sx = calibrate_scale(x2, qt, channel_axes=(0,))          # (T, 1)
    xq = jnp.clip(jnp.round(x2 / sx), qt.int_min, qt.int_max).astype(qt.dtype)
    sw = calibrate_scale(w, qt, channel_axes=(1,))           # (1, N)
    wq = jnp.clip(jnp.round(w / sw), qt.int_min, qt.int_max).astype(qt.dtype)
    return qmatmul(xq, wq, sx, sw, out_dtype=ctx.compute_dtype,
                   backend=ctx.backend)


def linear(p, x: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX, *,
           path: str = "") -> jnp.ndarray:
    """Apply ``x @ w (+ b)`` under the context's numeric mode."""
    w = p["w"]
    prec: LayerPrecision = ctx.policy.resolve(path)
    mode = ctx.mode if (prec.weights is not None or ctx.mode == "none") else "none"

    if mode == "int8" and isinstance(prec.weights, FixedPointType) \
            and prec.weights.width <= 8:
        t_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        y = _int8_matmul(x2, w.astype(jnp.float32), prec.weights, ctx)
        y = y.reshape(*t_shape, w.shape[-1])
    else:
        if mode == "fake" and prec.weights is not None:
            w = fake_quant(w.astype(jnp.float32), prec.weights)
        if mode == "fake" and prec.activations is not None:
            x = fake_quant(x.astype(jnp.float32), prec.activations)
        y = jnp.einsum("...k,kn->...n", x.astype(ctx.compute_dtype),
                       w.astype(ctx.compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y
