"""Activation dispatch: exact transcendentals or the paper's LUT path."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.tables import TableSpec, softmax_table_policy, table_softmax
from .context import DEFAULT_CTX, QuantContext

__all__ = ["act_fn", "softmax"]

_EXACT = {
    "relu": jax.nn.relu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "softplus": jax.nn.softplus,
}

_LUT_DOMAIN = {"gelu": (-8.0, 8.0), "silu": (-10.0, 10.0),
               "tanh": (-6.0, 6.0), "sigmoid": (-10.0, 10.0),
               "softplus": (-16.0, 16.0), "relu": (-8.0, 8.0)}


def act_fn(name: str, x: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX, *,
           path: str = "") -> jnp.ndarray:
    """Apply activation ``name`` under the context (exact or table-based)."""
    if not ctx.use_lut or name == "relu":
        return _EXACT[name](x)
    from ..kernels.ops import lut_activation as lut_op  # backend-dispatched

    prec = ctx.policy.resolve(path)
    n = prec.table_n or ctx.table_n
    qt = prec.table_qtype
    lo, hi = _LUT_DOMAIN[name]
    if name in ("gelu", "silu"):
        gate = "gelu_gate" if name == "gelu" else "silu_gate"
        spec = TableSpec(gate, n, lo, hi, qt, ctx.table_indexing)
        return (x * lut_op(x, spec, backend=ctx.backend)).astype(x.dtype)
    if name == "softplus":
        spec = TableSpec(name, n, lo, hi, qt, ctx.table_indexing)
        y = lut_op(x, spec, backend=ctx.backend)
        return jnp.where(x >= hi, x, y).astype(x.dtype)
    spec = TableSpec(name, n, lo, hi, qt, ctx.table_indexing)
    return lut_op(x, spec, backend=ctx.backend).astype(x.dtype)


def softmax(x: jnp.ndarray, ctx: QuantContext = DEFAULT_CTX,
            axis: int = -1) -> jnp.ndarray:
    """Softmax — exact, or through the paper's exp/invert constant tables."""
    if not ctx.use_lut:
        return jax.nn.softmax(x, axis=axis)
    pol = softmax_table_policy(ctx.act_qtype,
                               respect_user_type=ctx.respect_user_type,
                               n=ctx.table_n,
                               exact_divide=ctx.softmax_exact_divide,
                               indexing=ctx.table_indexing)
    return table_softmax(x, axis=axis, policy=pol)
