"""Normalization layers (RMSNorm / LayerNorm), f32 statistics."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm"]


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jnp.ndarray, *, eps: float = 1e-6,
            plus_one: bool = False) -> jnp.ndarray:
    """RMS normalization.  ``plus_one`` follows gemma's (1 + scale) form."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if plus_one:
        scale = 1.0 + scale
    return (y * scale).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
