"""Rotary position embeddings (full / partial rotary, configurable theta).

Frequencies are computed at trace time (NumPy) — the same "constexpr"
discipline as the activation tables: the inv-freq vector is an HLO
constant, never a traced computation.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

__all__ = ["rope_frequencies", "apply_rope"]


@functools.lru_cache(maxsize=64)
def rope_frequencies(rot_dim: int, theta: float) -> np.ndarray:
    """inv_freq (rot_dim // 2,) as a trace-time constant."""
    return (1.0 / (theta ** (np.arange(0, rot_dim, 2, dtype=np.float64)
                             / rot_dim))).astype(np.float32)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *,
               theta: float = 10000.0, fraction: float = 1.0) -> jnp.ndarray:
    """Rotate the leading ``fraction`` of the head dim of ``x``.

    x: (..., S, D) — rotation pairs split as [even, odd] halves (the
    llama/neox convention).  positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    rot = int(d * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    inv_freq = jnp.asarray(rope_frequencies(rot, theta))
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)

    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype)], axis=-1)
    if rot < d:
        out = jnp.concatenate([out, xp], axis=-1)
    return out
