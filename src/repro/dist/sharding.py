"""Sharding rules: rank/path heuristics → guarded PartitionSpecs.

The rules are deliberately structural (rank + path keywords), not
per-model: every family's parameter tree flows through the same few
cases, and :func:`guard_spec` drops any assignment whose dimension does
not divide the mesh-axis size — so smoke-scale shapes lower on any mesh
and production shapes get the full FSDP×TP layout.

Layout summary (mesh axes ``data`` / ``model``, plus optional ``pod``):

* 2-D weights ``(d_in, d_out)`` — FSDP on ``d_in`` (data), TP on
  ``d_out`` (model).
* stacked 3-D ``(L, d_in, d_out)`` — leading layer axis replicated
  (it is scanned), then as 2-D.
* 4-D MoE banks ``(L, E, D, F)`` — expert parallelism: ``E`` on model.
* embedding tables ``(V, D)`` — vocab on model (matches the logits
  constrain), feature on data.
* batches — leading batch axis on the data axes.
* caches — leading axis is the stacked layer axis (replicated), batch
  on data.

:class:`~repro.core.qtypes.QTensor` leaves (pre-quantized weights) get
the weight rule on their payload and a separately-guarded spec for the
scale (whose size-1 reduced axes must stay unsharded) — emitted as a
QTensor *of specs*, so the spec tree mirrors the parameter tree and
``named``/``device_put`` shard payload and scale independently.  The
payload keeps full FSDP×TP sharding; only the scale's broadcast axes
replicate.
"""

from __future__ import annotations

from typing import Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.qtypes import QTensor

__all__ = ["guard_spec", "param_specs", "batch_specs", "cache_specs",
           "named"]


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def guard_spec(spec: P, dims: Sequence[int], mesh) -> P:
    """Drop spec axes whose mesh-axis size does not divide the dim."""
    out = []
    for i, d in enumerate(dims):
        axis = spec[i] if i < len(spec) else None
        if axis is not None and int(d) % _axis_size(mesh, axis) != 0:
            axis = None
        out.append(axis)
    return P(*out)


def _dp(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _tp(mesh):
    return "model" if "model" in mesh.axis_names else None


def _is_spec_leaf(x) -> bool:
    return isinstance(x, QTensor)


def _param_rule(path: Sequence[str], shape, mesh) -> P:
    ndim = len(shape)
    dp, tp = _dp(mesh), _tp(mesh)
    joined = "/".join(path).lower()
    if ndim <= 1:
        return P()
    if "embed" in joined or path[-1:] == ("table",):
        # (V, D): vocab on model (logits shard the same way), D on data
        return guard_spec(P(*([None] * (ndim - 2) + [tp, dp])), shape, mesh)
    if ndim == 2:
        return guard_spec(P(dp, tp), shape, mesh)
    if ndim == 3:           # stacked (L, d_in, d_out)
        return guard_spec(P(None, dp, tp), shape, mesh)
    # 4-D+ stacked expert banks (L, E, D, F): expert parallelism
    return guard_spec(P(*([None, tp] + [None] * (ndim - 2))), shape, mesh)


def param_specs(params, mesh):
    """PartitionSpec pytree matching ``params``.

    QTensor leaves become a QTensor of specs (payload spec + scale
    spec), preserving the tree structure ``device_put`` expects while
    keeping the payload fully sharded and only the scale's size-1
    broadcast axes replicated.
    """
    def rule(path, leaf):
        keys = tuple(_key_str(k) for k in path)
        if isinstance(leaf, QTensor):
            spec = _param_rule(keys, leaf.data.shape, mesh)
            return QTensor(spec, guard_spec(spec, leaf.scale.shape, mesh),
                           leaf.qtype)
        return _param_rule(keys, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params,
                                            is_leaf=_is_spec_leaf)


def batch_specs(batch, mesh):
    """Shard the leading (batch) axis of every leaf over the data axes."""
    dp = _dp(mesh)

    def rule(leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return P()
        return guard_spec(P(*([dp] + [None] * (len(leaf.shape) - 1))),
                          leaf.shape, mesh)

    return jax.tree_util.tree_map(rule, batch)


def cache_specs(cache, mesh):
    """Cache leaves are stacked (L, B, ...): L replicated, B on data.

    Paged-cache leaves (under a ``pages`` subtree, plus ``block_table``
    leaves) are replicated: the page pool is one shared resource — any
    slot's table may name any physical page, so there is no batch axis
    to split it over.  (Sharding the pool over the *model* axis via the
    Hkv head dim is the natural next step and is deliberately left to
    the sharding PR this layout exists to enable.)
    """
    dp = _dp(mesh)

    def rule(path, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) < 2:
            return P()
        keys = tuple(str(getattr(k, "key", k)) for k in path)
        if "pages" in keys or keys[-1:] == ("block_table",):
            return P()
        return guard_spec(
            P(*([None, dp] + [None] * (len(leaf.shape) - 2))),
            leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, cache)


def named(specs, mesh):
    """PartitionSpec pytree → NamedSharding pytree for ``device_put``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def _key_str(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)
