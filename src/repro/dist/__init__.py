"""Distribution layer: mesh context, sharding rules, perf flags,
compressed collectives.

Single-host degradation is a first-class requirement: every entry point
is a no-op (or replicated) when no mesh is active, so the same model code
runs on a laptop CPU and a 512-chip pod without branches at call sites.
"""

from .constrain import constrain, current_mesh, use_mesh
from .options import PerfFlags, flags, set_flags
from .sharding import (batch_specs, cache_specs, guard_spec, named,
                       param_specs)

__all__ = [
    "constrain", "current_mesh", "use_mesh",
    "PerfFlags", "flags", "set_flags",
    "batch_specs", "cache_specs", "guard_spec", "named", "param_specs",
]
