"""Mesh context + logical-axis sharding constraints.

Model code never names mesh axes directly: it pins tensors with the
*logical* labels ``"dp"`` (data parallel) and ``"tp"`` (tensor/model
parallel), which resolve against whatever mesh is ambiently active —
``("pod", "data")`` and ``"model"`` on a multi-pod mesh, ``("data",)``
and ``"model"`` on a single-pod mesh, and to nothing at all when no mesh
is active (single-host tests), in which case :func:`constrain` is the
identity.  This is the de-specialized version of hard-coding a layout:
the same forward function lowers correctly under every mesh shape.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["use_mesh", "current_mesh", "constrain"]

_state = threading.local()

#: logical label -> candidate mesh axis names, in precedence order.
_LOGICAL_AXES = {
    "dp": ("pod", "data"),
    "tp": ("model",),
}


def current_mesh() -> Optional[jax.sharding.Mesh]:
    """The ambiently active mesh, or None (single-host / no context)."""
    stack = getattr(_state, "meshes", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh):
    """Activate ``mesh`` for every :func:`constrain` call in scope."""
    stack = getattr(_state, "meshes", None)
    if stack is None:
        stack = _state.meshes = []
    stack.append(mesh)
    try:
        yield mesh
    finally:
        stack.pop()


def _resolve_axis(label, mesh):
    """Map a logical label to the mesh axes it spans (possibly a tuple)."""
    if label is None:
        return None
    if label in _LOGICAL_AXES:
        axes = tuple(a for a in _LOGICAL_AXES[label]
                     if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    return label if label in mesh.axis_names else None


def constrain(t: jax.Array, *labels) -> jax.Array:
    """Pin ``t`` to the sharding described by per-axis logical ``labels``.

    ``labels`` align with ``t``'s leading axes (missing trailing labels =
    replicated).  Axes whose size does not divide the resolved mesh-axis
    size are silently dropped to replicated (the divisibility guard), so
    smoke-scale shapes never fail to lower.  Identity when no mesh is
    active.
    """
    mesh = current_mesh()
    if mesh is None:
        return t
    from .sharding import guard_spec
    resolved = [_resolve_axis(lb, mesh) for lb in labels[:t.ndim]]
    spec = guard_spec(P(*resolved), t.shape, mesh)
    if all(a is None for a in spec):
        return t
    return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))
