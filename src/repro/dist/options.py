"""Global performance flags (the §Perf hypothesis switches).

Each flag gates one measured optimization; the dry-run driver flips them
via ``--opt`` and records the active set in every artifact so perf
results are attributable.  Defaults are all-off (the baseline lowering).
"""

from __future__ import annotations

import dataclasses

__all__ = ["PerfFlags", "flags", "set_flags"]


@dataclasses.dataclass(frozen=True)
class PerfFlags:
    #: pin per-microbatch grads + accumulator to the param sharding
    #: (reduce-scatter instead of full all-reduce) — §Perf H1
    grad_specs: bool = False
    #: sequence-parallel attention when heads don't divide TP — §Perf H2
    sp_attn: bool = False
    #: sequence-sharded KV cache for small-Hkv decode — §Perf H3
    seq_kv: bool = False
    #: one-hot einsum MoE dispatch/combine instead of scatter — §Perf H5
    moe_einsum: bool = False
    #: batch-local MoE capacity buffer (no global replication) — §Perf H5b
    moe_local: bool = False

    @classmethod
    def all_on(cls) -> "PerfFlags":
        return cls(**{f.name: True for f in dataclasses.fields(cls)})


_FLAGS = PerfFlags()


def flags() -> PerfFlags:
    """The currently active flag set."""
    return _FLAGS


def set_flags(f: PerfFlags) -> None:
    global _FLAGS
    _FLAGS = f
