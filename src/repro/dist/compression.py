"""Compressed cross-pod gradient reduction (quantized psum ± error
feedback).

The paper's narrow-operand thesis applied to the interconnect: the
cross-pod gradient all-reduce moves int8 payloads instead of f32.  Here
the compression is *numerics-faithful emulation* — each shard round-trips
its contribution through the quantized format before the reduction, so
accuracy results transfer even though XLA still moves floats on CPU
hosts.

``quantized_psum_ef`` adds error feedback: the local quantization
residual is carried to the next step, which removes the constant bias of
plain quantization (the running mean of reduced values converges to the
exact reduction).
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.5 re-exports at top level
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map  # noqa: F401

from ..core.qtypes import FixedPointType

__all__ = ["quantized_psum", "quantized_psum_ef",
           "make_pod_sharded_grad_fn", "shard_map"]


def _round_trip(x: jnp.ndarray, qtype: FixedPointType) -> jnp.ndarray:
    """Round-trip ``x`` through ``qtype`` with a dynamic per-tensor scale."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / qtype.int_max
    q = jnp.clip(jnp.round(x / scale), qtype.int_min, qtype.int_max)
    return q * scale


def quantized_psum(x: jnp.ndarray, axis_name: str,
                   qtype: FixedPointType) -> jnp.ndarray:
    """psum where every shard's contribution is quantized to ``qtype``."""
    return jax.lax.psum(_round_trip(x, qtype), axis_name)


def quantized_psum_ef(x: jnp.ndarray, residual: jnp.ndarray,
                      axis_name: str, qtype: FixedPointType
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback variant: returns (psum, new_residual)."""
    t = x + residual
    q = _round_trip(t, qtype)
    return jax.lax.psum(q, axis_name), t - q


def make_pod_sharded_grad_fn(grad_fn: Callable, mesh, *,
                             in_specs, out_specs,
                             qtype: FixedPointType = None) -> Callable:
    """Wrap ``grad_fn(params, batch) -> (grads, metrics)`` in a shard_map
    that is manual over the ``pod`` axis: each pod computes grads on its
    batch shard, then the cross-pod mean runs through the quantized psum.
    Remaining mesh axes stay automatic (GSPMD partitions inside the pod).
    """
    npod = mesh.shape["pod"]
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def inner(params, batch):
        grads, metrics = grad_fn(params, batch)
        inv = 1.0 / npod

        def reduce_leaf(g):
            if qtype is None:
                return jax.lax.psum(g, "pod") * inv
            return quantized_psum(g, "pod", qtype) * inv

        grads = jax.tree_util.tree_map(reduce_leaf, grads)
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.psum(m, "pod") * inv, metrics)
        return grads, metrics

    try:
        return shard_map(inner, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False, auto=auto)
    except TypeError:  # newer shard_map: auto axes are implicit
        return shard_map(inner, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)
