"""yi-6b — dense llama-arch GQA [arXiv:2403.04652; hf].

32L, d_model 4096, 32 heads (GQA kv=4), d_ff 11008, vocab 64000.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="lm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab=64000,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
)
