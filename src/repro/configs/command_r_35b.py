"""command-r-35b — dense GQA, parallel attention/FFN block, no biases
[hf:CohereForAI/c4ai-command-r-v01; unverified — config taken verbatim
from the assignment brief, noted in DESIGN.md §Limitations].

40L, d_model 8192, 64 heads (GQA kv=8), d_ff 22528, vocab 256000.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="lm",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab=256000,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="layernorm",
    norm_eps=1e-5,
    parallel_block=True,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
)
