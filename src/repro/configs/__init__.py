"""Assigned architecture configs (``--arch <id>``) + the paper's own MLP.

Each module defines ``CONFIG: ModelConfig`` with the exact published
dimensions (sources cited per-file).  ``get_config(name)`` resolves ids;
``list_archs()`` enumerates them.  Reduced smoke variants come from
``CONFIG.smoke()``.
"""

from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig

__all__ = ["get_config", "list_archs", "ARCH_IDS"]

ARCH_IDS = [
    "yi-6b",
    "gemma-2b",
    "glm4-9b",
    "command-r-35b",
    "whisper-base",
    "mamba2-370m",
    "deepseek-v2-236b",
    "olmoe-1b-7b",
    "llama-3.2-vision-11b",
    "zamba2-1.2b",
    "jet-mlp",          # the paper's canonical hls4ml use case
]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULE_FOR[name]}", __package__)
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCH_IDS)
