"""zamba2-1.2b — hybrid: Mamba-2 backbone with ONE shared transformer
block re-applied every 6 layers [arXiv:2411.15242; hf].

38 mamba layers, d_model 2048, ssm_state 64; shared block: 32 heads
(MHA kv=32), d_ff 8192; vocab 32000.  Upstream concatenates the original
embedding into the shared block and applies per-use LoRA deltas — we use a
plain residual with exact sharing (DESIGN.md §deviations).
"""

from ..models.config import ModelConfig
from ..nn.ssm import SSMDims

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    mlp_act="gelu",
    mlp_gated=True,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    tie_embeddings=True,
    shared_attn_every=6,
    ssm=SSMDims(d_model=2048, d_state=64, head_dim=64, expand=2,
                n_groups=1, d_conv=4, chunk=256),
)
