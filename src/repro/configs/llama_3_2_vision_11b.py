"""llama-3.2-vision-11b — VLM: dense GQA text stack with gated cross-attn
image layers every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision;
unverified].  Vision frontend is a STUB per the brief: ``input_specs``
provides precomputed patch embeddings.

40L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 128256.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    rope_theta=500_000.0,
    tie_embeddings=False,
    cross_attn_every=5,
    n_img_tokens=1601,      # one 560×560 tile → 1601 patch tokens
)
