"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356;
unverified].  Conv frontend is a STUB per the brief: ``input_specs``
provides precomputed frame embeddings (B, S_enc, 512).

6+6L, d_model 512, 8 heads (MHA: kv=8), d_ff 2048, vocab 51865.
LayerNorm, plain GeLU MLP, learned decoder positions, sinusoidal encoder
positions.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51865,
    mlp_act="gelu",
    mlp_gated=False,
    norm_type="layernorm",
    norm_eps=1e-5,
    pos_type="learned",
    max_position=32768,      # decoder learned-position table (stressed shapes)
    enc_len_cap=4096,
    tie_embeddings=True,
)
