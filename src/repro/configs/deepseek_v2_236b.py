"""deepseek-v2-236b — MoE with multi-head latent attention
[arXiv:2405.04434; hf].

60L, d_model 5120, 128 heads, MLA kv_lora 512 (+64 rope), q_lora 1536;
MoE: 160 routed experts top-6 (d_ff_expert 1536) + 2 shared, first layer
dense (d_ff 12288), vocab 102400.  routed_scaling_factor 16 with top-k
renormalization off in upstream v2; we keep renormalize=True +
routed_scale 1.0 (equivalent magnitude; DESIGN.md notes the deviation).
"""

from ..models.config import ModelConfig, MoEConfig
from ..nn.attention import MLADims

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="lm",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,           # informational; MLA dims below drive attention
    d_ff=12288,             # the leading dense layer's FFN
    vocab=102400,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    tie_embeddings=False,
    attn_kind="mla",
    mla=MLADims(d_model=5120, n_heads=128, q_lora_rank=1536,
                kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                  first_k_dense=1, renormalize=True,
                  capacity_factor=1.25, aux_loss_weight=0.003),
)
