"""gemma-2b — dense, GeGLU, MQA (kv=1), head_dim 256 [arXiv:2403.08295; hf].

18L, d_model 2048, 8 heads, d_ff 16384, vocab 256000.  Embeddings tied and
scaled by √d; RMSNorm uses the (1 + scale) form.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="lm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    mlp_act="gelu",
    mlp_gated=True,
    norm_type="rmsnorm",
    norm_eps=1e-6,
    norm_plus_one=True,
    embed_scale=True,
    tie_embeddings=True,
)
