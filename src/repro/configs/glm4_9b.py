"""glm4-9b — dense GQA with qkv bias and partial rotary
[hf:THUDM/glm-4-9b; hf].

40L, d_model 4096, 32 heads (GQA kv=2), d_ff 13696, vocab 151552.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="lm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=151552,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    norm_eps=1.5625e-07,
    qkv_bias=True,
    rope_fraction=0.5,
    tie_embeddings=False,
)
