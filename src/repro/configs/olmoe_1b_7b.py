"""olmoe-1b-7b — MoE, 64 experts top-8 [arXiv:2409.02060; hf].

16L, d_model 2048, 16 heads (MHA: kv=16), d_ff_expert 1024, vocab 50304.
"""

from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="lm",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,              # informational; all FFNs are MoE
    vocab=50304,
    mlp_act="silu",
    mlp_gated=True,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    tie_embeddings=False,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024, n_shared=0,
                  first_k_dense=0, renormalize=False,
                  capacity_factor=1.25, aux_loss_weight=0.01),
)
