"""mamba2-370m — attention-free SSD state-space model [arXiv:2405.21060;
unverified].

48L, d_model 1024, ssm_state 128, vocab 50280.  d_inner = 2048,
head_dim 64 → 32 SSD heads.
"""

from ..models.config import ModelConfig
from ..nn.ssm import SSMDims

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    vocab=50280,
    norm_type="rmsnorm",
    norm_eps=1e-5,
    tie_embeddings=True,
    ssm=SSMDims(d_model=1024, d_state=128, head_dim=64, expand=2,
                n_groups=1, d_conv=4, chunk=256),
)
