"""jet-mlp — the paper's canonical hls4ml use case: the 3-hidden-layer
fully-connected jet-tagging classifier from the original hls4ml
publication (Duarte et al., JINST 13 (2018)): 16 → 64 → 32 → 32 → 5.

Not part of the assigned 10-arch pool; used by the paper-claim benchmarks
(quantization accuracy, LUT softmax) and the training example.  Encoded
as a ModelConfig for uniformity but consumed by ``repro.models.mlp``.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jet-mlp",
    family="mlp",
    n_layers=3,
    d_model=64,             # widest hidden layer
    vocab=5,                # output classes
    d_ff=16,                # input features
    norm_type="rmsnorm",
    tie_embeddings=False,
)

#: hidden layer widths, input features, classes — the exact hls4ml model
HIDDEN = (64, 32, 32)
N_FEATURES = 16
N_CLASSES = 5
