"""AdamW with decoupled weight decay and global-norm clipping.

States (m, v) mirror the parameter pytree, so the parameter sharding specs
apply verbatim to the optimizer state — the ZeRO-style sharded-optimizer
property falls out of FSDP×TP parameter sharding for free.

``dtype`` lets the second moment be carried in bf16 at scale (a §Perf
memory lever recorded in EXPERIMENTS.md); default keeps both in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    m_dtype: Optional[jnp.dtype] = None      # None = param dtype
    v_dtype: Optional[jnp.dtype] = None


def adamw_init(params, cfg: OptConfig = OptConfig()):
    def zeros_like(p, dt):
        return jnp.zeros(p.shape, dt or p.dtype)

    return {
        "m": jax.tree_util.tree_map(lambda p: zeros_like(p, cfg.m_dtype),
                                    params),
        "v": jax.tree_util.tree_map(lambda p: zeros_like(p, cfg.v_dtype),
                                    params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, opt_state, params, lr, cfg: OptConfig = OptConfig()):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** count)
        vhat = v2 / (1 - cfg.b2 ** count)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return (p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype))

    out = jax.tree_util.tree_map(upd, grads, opt_state["m"],
                                 opt_state["v"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm}
