"""Optimizers and schedules (pure JAX, pytree states, fully shardable)."""

from .adamw import adamw_init, adamw_update, OptConfig
from .schedule import cosine_warmup

__all__ = ["adamw_init", "adamw_update", "OptConfig", "cosine_warmup"]
