"""Parametric arbitrary-precision data types (the ``ac_types`` analogue).

The paper replaces Xilinx ``ap_types`` with a modified open-source
``ac_types`` library so that (a) types are parametric in width/format,
(b) they can be evaluated at compile time (constexpr-compatible), and
(c) they are portable across HLS backends.

On TPU the analogue is a *software-defined numeric format* carried in a
narrow storage dtype and executed either on the VPU (elementwise) or the
MXU (int8 matmul with int32 accumulation).  Two families are provided:

* :class:`FixedPointType` — ``ac_fixed<W, I, S, Q, O>`` semantics: a
  binary-point format with ``width`` total bits, ``int_bits`` integer bits,
  configurable rounding (``Q``) and overflow (``O``) behaviour.
* :class:`MiniFloatType` — the paper's "custom floating-point data types":
  arbitrary (exponent, mantissa) splits, IEEE-like or extended-range
  (OCP fp8) semantics.

Both are frozen dataclasses so they can key dictionaries (per-layer
precision policies) and be closed over by jitted functions as static data.
All quantization math is pure ``jnp`` and differentiable via the
straight-through estimator in :mod:`repro.core.quantize`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FixedPointType",
    "MiniFloatType",
    "QTensor",
    "storage_dtype",
    # canonical instances
    "AC_FIXED_16_6",
    "AC_FIXED_18_8",
    "AC_FIXED_8_3",
    "E4M3",
    "E5M2",
]

_ROUNDING_MODES = ("rnd_even", "rnd", "trn")
_OVERFLOW_MODES = ("sat", "wrap")


def storage_dtype(width: int) -> jnp.dtype:
    """Narrowest signed integer dtype that can carry ``width`` bits."""
    if width <= 8:
        return jnp.int8
    if width <= 16:
        return jnp.int16
    if width <= 32:
        return jnp.int32
    raise ValueError(f"fixed-point width {width} > 32 unsupported")


@dataclasses.dataclass(frozen=True)
class FixedPointType:
    """``ac_fixed``-style parametric fixed-point format.

    value = stored_integer * 2**(int_bits - width)

    ``int_bits`` counts the sign bit when ``signed`` (matching ac_fixed).
    ``rounding``: ``rnd_even`` (round half to even — default, matches the
    MXU requantization path), ``rnd`` (round half away from zero, the
    ``AC_RND`` analogue), ``trn`` (truncate toward -inf, ``AC_TRN``).
    ``overflow``: ``sat`` (saturate, ``AC_SAT``) or ``wrap`` (two's
    complement wraparound, ``AC_WRAP``).
    """

    width: int
    int_bits: int
    signed: bool = True
    rounding: str = "rnd_even"
    overflow: str = "sat"

    def __post_init__(self):
        if self.width < 1:
            raise ValueError("width must be >= 1")
        if self.rounding not in _ROUNDING_MODES:
            raise ValueError(f"rounding must be one of {_ROUNDING_MODES}")
        if self.overflow not in _OVERFLOW_MODES:
            raise ValueError(f"overflow must be one of {_OVERFLOW_MODES}")

    # ---- static format properties -------------------------------------
    @property
    def frac_bits(self) -> int:
        return self.width - self.int_bits

    @property
    def lsb(self) -> float:
        """Value of one unit in the last place (the quantization step)."""
        return float(2.0 ** (self.int_bits - self.width))

    @property
    def int_min(self) -> int:
        return -(1 << (self.width - 1)) if self.signed else 0

    @property
    def int_max(self) -> int:
        return (1 << (self.width - 1)) - 1 if self.signed else (1 << self.width) - 1

    @property
    def min_value(self) -> float:
        return self.int_min * self.lsb

    @property
    def max_value(self) -> float:
        return self.int_max * self.lsb

    @property
    def dtype(self) -> jnp.dtype:
        return storage_dtype(self.width)

    # ---- quantization --------------------------------------------------
    def _round(self, y: jnp.ndarray) -> jnp.ndarray:
        if self.rounding == "rnd_even":
            return jnp.round(y)
        if self.rounding == "rnd":
            return jnp.trunc(y + jnp.copysign(0.5, y))
        return jnp.floor(y)  # trn

    def to_int(self, x: jnp.ndarray) -> jnp.ndarray:
        """Quantize real values to the stored-integer representation."""
        y = self._round(jnp.asarray(x, jnp.float32) / self.lsb)
        if self.overflow == "sat":
            y = jnp.clip(y, self.int_min, self.int_max)
        else:  # two's-complement wraparound
            span = float(1 << self.width)
            y = jnp.mod(y - self.int_min, span) + self.int_min
        return y.astype(self.dtype)

    def from_int(self, i: jnp.ndarray) -> jnp.ndarray:
        return i.astype(jnp.float32) * self.lsb

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        """Round-trip a real tensor through this format (values stay f32)."""
        return self.from_int(self.to_int(x))

    def np_quantize(self, x: np.ndarray) -> np.ndarray:
        """NumPy twin of :meth:`quantize` for trace-time (constexpr) use."""
        y = np.asarray(x, np.float64) / self.lsb
        if self.rounding == "rnd_even":
            y = np.round(y)
        elif self.rounding == "rnd":
            y = np.trunc(y + np.copysign(0.5, y))
        else:
            y = np.floor(y)
        if self.overflow == "sat":
            y = np.clip(y, self.int_min, self.int_max)
        else:
            span = float(1 << self.width)
            y = np.mod(y - self.int_min, span) + self.int_min
        return (y * self.lsb).astype(np.float32)

    def short_name(self) -> str:
        s = "s" if self.signed else "u"
        return f"fx{s}{self.width}_{self.int_bits}"


@dataclasses.dataclass(frozen=True)
class MiniFloatType:
    """Custom floating-point format with ``exp_bits``/``man_bits`` split.

    ``ieee_inf=True`` reserves the all-ones exponent for inf/NaN (IEEE
    semantics, e.g. E5M2).  ``ieee_inf=False`` uses the extended OCP-style
    range where the top exponent carries normal values (e.g. E4M3: max
    finite 448).  Values are emulated in float32: quantization rounds the
    mantissa to ``man_bits`` at the value's (clamped) exponent, which also
    reproduces gradual underflow through subnormals.
    """

    exp_bits: int
    man_bits: int
    bias: Optional[int] = None
    ieee_inf: bool = True

    def __post_init__(self):
        if self.exp_bits < 2 or self.exp_bits > 8:
            raise ValueError("exp_bits must be in [2, 8]")
        if self.man_bits < 0 or self.man_bits > 23:
            raise ValueError("man_bits must be in [0, 23]")

    @property
    def _bias(self) -> int:
        return self.bias if self.bias is not None else (1 << (self.exp_bits - 1)) - 1

    @property
    def max_exp(self) -> int:
        """Largest usable unbiased exponent."""
        top = (1 << self.exp_bits) - (2 if self.ieee_inf else 1)
        return top - self._bias

    @property
    def min_normal_exp(self) -> int:
        return 1 - self._bias

    @property
    def max_value(self) -> float:
        if self.ieee_inf:
            frac = 2.0 - 2.0 ** (-self.man_bits)
        else:  # all-ones exponent usable, only one NaN encoding: drop one ulp
            frac = 2.0 - 2.0 ** (-self.man_bits) * (2.0 if self.man_bits > 0 else 1.0)
        return float(frac * 2.0**self.max_exp)

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.min_normal_exp - self.man_bits))

    @property
    def width(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        x = jnp.asarray(x, jnp.float32)
        a = jnp.abs(x)
        # floor(log2(a)) via frexp: a = mant * 2**e, mant in [0.5, 1)
        _, e = jnp.frexp(a)
        e_unb = e - 1
        eff = jnp.maximum(e_unb, self.min_normal_exp)
        # ldexp, not exp2: XLA CPU's exp2 is approximate (~5e-7 rel) and
        # breaks exact power-of-two quanta / idempotence
        quantum = jnp.ldexp(jnp.float32(1.0), eff - self.man_bits)
        q = jnp.round(a / quantum) * quantum
        # rounding can bump the exponent (e.g. 1.111|1 -> 10.00); that is
        # still representable unless it exceeds max_value: saturate-to-finite
        q = jnp.minimum(q, self.max_value)
        return jnp.where(a == 0, 0.0, jnp.copysign(q, x))

    def np_quantize(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        a = np.abs(x).astype(np.float64)
        with np.errstate(divide="ignore"):
            _, e = np.frexp(a)
        e_unb = e - 1
        eff = np.maximum(e_unb, self.min_normal_exp)
        quantum = np.exp2((eff - self.man_bits).astype(np.float64))
        q = np.where(quantum > 0, np.round(a / np.where(quantum == 0, 1, quantum)) * quantum, 0.0)
        q = np.minimum(q, self.max_value)
        return np.where(a == 0, 0.0, np.copysign(q, x)).astype(np.float32)

    def short_name(self) -> str:
        return f"e{self.exp_bits}m{self.man_bits}"


@jax.tree_util.register_pytree_node_class
class QTensor:
    """A quantized tensor: integer payload + per-channel (or scalar) scale.

    Used by the dynamic-range int8 path (MXU matmuls): ``value ≈ data *
    scale`` with ``data`` in the type's storage dtype.  ``scale`` broadcasts
    against ``data`` (scalar, or shaped for per-channel axes).
    """

    def __init__(self, data: jnp.ndarray, scale: jnp.ndarray, qtype: FixedPointType):
        self.data = data
        self.scale = scale
        self.qtype = qtype

    @property
    def shape(self):
        return self.data.shape

    @property
    def dtype(self):
        return self.data.dtype

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        return (self.data.astype(jnp.float32) * self.scale).astype(dtype)

    def tree_flatten(self):
        return (self.data, self.scale), self.qtype

    @classmethod
    def tree_unflatten(cls, qtype, children):
        return cls(children[0], children[1], qtype)

    def __repr__(self):
        return f"QTensor({self.data.shape}, {self.qtype.short_name()})"


# Canonical instances -----------------------------------------------------
#: hls4ml's classic default model type.
AC_FIXED_16_6 = FixedPointType(16, 6)
#: The paper's softmax-table type (sized for a Xilinx 18k BRAM).
AC_FIXED_18_8 = FixedPointType(18, 8)
#: Aggressive edge-inference type.
AC_FIXED_8_3 = FixedPointType(8, 3)
#: OCP fp8 formats (E4M3 uses the extended range, max finite 448).
E4M3 = MiniFloatType(4, 3, ieee_inf=False)
E5M2 = MiniFloatType(5, 2, ieee_inf=True)
