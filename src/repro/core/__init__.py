"""Core of the reproduction: the paper's de-specialized component library.

* :mod:`repro.core.qtypes`    — parametric fixed-point / minifloat formats
* :mod:`repro.core.tables`    — trace-time constant tables ("constexpr")
* :mod:`repro.core.quantize`  — PTQ / QAT / dynamic-range quantizers
* :mod:`repro.core.precision` — per-layer heterogeneous precision policies
* :mod:`repro.core.registry`  — backend-pluggable op registry
"""

from .precision import FP32_PRECISION, LayerPrecision, PrecisionPolicy
from .qtypes import (AC_FIXED_8_3, AC_FIXED_16_6, AC_FIXED_18_8, E4M3, E5M2,
                     FixedPointType, MiniFloatType, QTensor, storage_dtype)
from .quantize import (calibrate_scale, dequantize_params, fake_quant,
                       ptq_params, quantize_dynamic)
from .registry import (current_backend, get_impl, list_ops, register_op,
                       set_default_backend, use_backend)
from .tables import (ConstexprTable, SoftmaxTablePolicy, TableSpec, get_table,
                     lut_activation, register_compute, softmax_table_policy,
                     table_lookup, table_softmax)

__all__ = [
    "FP32_PRECISION", "LayerPrecision", "PrecisionPolicy",
    "AC_FIXED_8_3", "AC_FIXED_16_6", "AC_FIXED_18_8", "E4M3", "E5M2",
    "FixedPointType", "MiniFloatType", "QTensor", "storage_dtype",
    "calibrate_scale", "dequantize_params", "fake_quant", "ptq_params",
    "quantize_dynamic",
    "current_backend", "get_impl", "list_ops", "register_op",
    "set_default_backend", "use_backend",
    "ConstexprTable", "SoftmaxTablePolicy", "TableSpec", "get_table",
    "lut_activation", "register_compute", "softmax_table_policy",
    "table_lookup", "table_softmax",
]
