"""Per-layer heterogeneous precision policies (the hls4ml config dict).

hls4ml exposes "a data type for the whole model or on a per-layer basis".
:class:`PrecisionPolicy` reproduces that interface against arbitrary
parameter paths: a default :class:`LayerPrecision` plus ordered
fnmatch-style pattern overrides, resolved most-specific-last.

This is also where the paper's §Arch-applicability caveats are enforced in
code: e.g. an SSM recurrence or a MoE router can be pinned to fp32 while
the surrounding projections run int8 — per-layer heterogeneity is exactly
the paper's point.
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Optional, Sequence, Tuple, Union

from .qtypes import FixedPointType, MiniFloatType

__all__ = ["LayerPrecision", "PrecisionPolicy", "FP32_PRECISION"]

QType = Union[FixedPointType, MiniFloatType, None]


@dataclasses.dataclass(frozen=True)
class LayerPrecision:
    """Quantization assignment for one layer (None = keep float)."""

    weights: QType = None
    activations: QType = None
    #: activation-table length/format override (None = module default)
    table_n: Optional[int] = None
    table_qtype: QType = None


FP32_PRECISION = LayerPrecision()


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Default precision + ordered (pattern, LayerPrecision) overrides.

    ``resolve(path)`` returns the last matching override (patterns are
    fnmatch globs over '/'-joined parameter paths), else the default —
    matching hls4ml's model-then-layer configuration granularity.
    """

    default: LayerPrecision = FP32_PRECISION
    overrides: Tuple[Tuple[str, LayerPrecision], ...] = ()

    def resolve(self, path: str) -> LayerPrecision:
        hit = self.default
        for pattern, prec in self.overrides:
            if fnmatch.fnmatch(path, pattern):
                hit = prec
        return hit

    def with_override(self, pattern: str, prec: LayerPrecision) -> "PrecisionPolicy":
        return dataclasses.replace(self, overrides=self.overrides + ((pattern, prec),))

    @staticmethod
    def uniform(weights: QType, activations: QType = None) -> "PrecisionPolicy":
        return PrecisionPolicy(default=LayerPrecision(weights=weights,
                                                      activations=activations))
