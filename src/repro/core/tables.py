"""Trace-time constant table generation — the ``constexpr`` analogue.

The paper's central concrete artifact: hls4ml built activation-function
lookup tables with a C++ loop that *only Vivado HLS* recognized and folded
into BRAM constants; the paper replaces it with portable ``constexpr``
evaluation (a class template taking a static ``compute()`` method and a
length ``N``, plus the constexpr math library *gcem*).

The XLA analogue of "compile time" is *trace time*: anything computed in
Python/NumPy while building the jaxpr is embedded in the HLO as a literal
constant.  Relying on XLA to constant-fold a traced loop of transcendentals
would be exactly the fragile backend-specific pattern the paper removes —
so tables here are built eagerly in NumPy (:class:`TableSpec` +
:func:`get_table`), quantized to their target format with the *NumPy twin*
of the qtype (``np_quantize``, our "gcem"), and only then handed to JAX.

Faithfulness notes (validated in benchmarks/bench_lut_tables.py):

* The hls4ml softmax silently overrides the user's default fixed-point type
  with **1024-entry tables of 18-bit values** (sized to fill one Xilinx 18k
  BRAM).  ``softmax_table_policy`` reproduces that override, and exposes
  ``respect_user_type=True`` — the de-specialized behaviour the paper
  advocates.
* hls4ml tables f(x) directly and indexes by truncation.  We keep that as
  ``indexing="trunc"`` / gate-free mode for the faithful baseline, and add
  ``indexing="interp"`` (linear interpolation) plus *gated* forms for
  unbounded activations (silu/gelu table the bounded gate, multiply by x),
  which keep the table bounded and the asymptotics exact — part of the
  "more efficient accelerators" the paper targets.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .qtypes import AC_FIXED_18_8, FixedPointType, MiniFloatType

__all__ = [
    "TableSpec",
    "ConstexprTable",
    "get_table",
    "register_compute",
    "table_lookup",
    "lut_activation",
    "table_softmax",
    "softmax_table_policy",
    "COMPUTE_FNS",
    "GATED_FORMS",
]

QType = Union[FixedPointType, MiniFloatType, None]

# --------------------------------------------------------------------------
# The "static compute() method" registry — trace-time (NumPy) math only.
# --------------------------------------------------------------------------
COMPUTE_FNS: Dict[str, Callable[[np.ndarray], np.ndarray]] = {}


def register_compute(name: str):
    def deco(fn):
        COMPUTE_FNS[name] = fn
        return fn
    return deco


@register_compute("sigmoid")
def _sigmoid(x):  # numerically-stable logistic
    return np.where(x >= 0, 1.0 / (1.0 + np.exp(-x)), np.exp(x) / (1.0 + np.exp(x)))


@register_compute("tanh")
def _tanh(x):
    return np.tanh(x)


@register_compute("exp")
def _exp(x):
    return np.exp(x)


@register_compute("invert")
def _invert(x):
    return 1.0 / np.maximum(x, 1e-12)


@register_compute("silu")
def _silu(x):
    return x * _sigmoid(x)


@register_compute("gelu")
def _gelu(x):  # tanh approximation, as used by gemma et al.
    return 0.5 * x * (1.0 + np.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


@register_compute("gelu_gate")
def _gelu_gate(x):  # bounded gate: gelu(x) = x * gelu_gate(x)
    return 0.5 * (1.0 + np.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


@register_compute("silu_gate")
def _silu_gate(x):  # bounded gate: silu(x) = x * sigmoid(x)
    return _sigmoid(x)


@register_compute("softplus")
def _softplus(x):
    return np.logaddexp(0.0, x)


@register_compute("erf")
def _erf(x):
    # constexpr-style erf (Abramowitz & Stegun 7.1.26) — avoids scipy,
    # mirroring the paper's swap of std::math for a self-contained gcem.
    t = 1.0 / (1.0 + 0.3275911 * np.abs(x))
    y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                - 0.284496736) * t + 0.254829592) * t * np.exp(-x * x)
    return np.sign(x) * y


@register_compute("relu")
def _relu(x):
    return np.maximum(x, 0.0)


#: Activations with exact gated forms: f(x) = x * gate(x), gate bounded.
GATED_FORMS = {"silu": "silu_gate", "gelu": "gelu_gate"}

_INDEXING = ("trunc", "nearest", "interp")


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """Fully static description of a constant table (hashable cache key)."""

    fn: str                      # key into COMPUTE_FNS
    n: int = 1024                # table length (hls4ml default: 1024)
    lo: float = -8.0             # input domain [lo, hi)
    hi: float = 8.0
    qtype: QType = None          # value quantization (None = float32)
    indexing: str = "trunc"      # trunc | nearest | interp

    def __post_init__(self):
        if self.fn not in COMPUTE_FNS:
            raise KeyError(f"unknown compute fn {self.fn!r}; register it first")
        if self.n < 2:
            raise ValueError("table length must be >= 2")
        if not self.hi > self.lo:
            raise ValueError("need hi > lo")
        if self.indexing not in _INDEXING:
            raise ValueError(f"indexing must be one of {_INDEXING}")

    @property
    def step(self) -> float:
        return (self.hi - self.lo) / self.n


class ConstexprTable:
    """An ``N``-entry constant array evaluated at trace time.

    Mirrors the paper's class template: it takes the ``compute()`` method
    (via ``spec.fn``) and the length ``N`` (``spec.n``) and produces the
    populated constant array — here a NumPy array that becomes an HLO
    literal when first used inside a traced function.
    """

    def __init__(self, spec: TableSpec):
        self.spec = spec
        knots = spec.lo + spec.step * np.arange(spec.n, dtype=np.float64)
        vals = COMPUTE_FNS[spec.fn](knots).astype(np.float32)
        if spec.qtype is not None:
            vals = spec.qtype.np_quantize(vals)
        #: trace-time ("constexpr") values; read-only.
        self.np_values: np.ndarray = vals
        self.np_values.setflags(write=False)

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return table_lookup(x, jnp.asarray(self.np_values), self.spec.lo,
                            self.spec.hi, self.spec.indexing)

    def __repr__(self):
        return f"ConstexprTable({self.spec})"


@functools.lru_cache(maxsize=256)
def get_table(spec: TableSpec) -> ConstexprTable:
    """Build (or fetch the cached) constant table for ``spec``."""
    return ConstexprTable(spec)


# --------------------------------------------------------------------------
# Reference lookup (pure jnp).  The Pallas VMEM-resident kernel lives in
# repro.kernels.lut_activation and is numerics-matched to this function.
# --------------------------------------------------------------------------
def table_lookup(x: jnp.ndarray, values: jnp.ndarray, lo: float, hi: float,
                 indexing: str = "trunc") -> jnp.ndarray:
    """Map ``x`` into the table domain and gather (optionally interpolate)."""
    n = values.shape[0]
    step = (hi - lo) / n
    pos = (x.astype(jnp.float32) - lo) / step
    if indexing == "interp":
        # values[i] = f(lo + i*step); interpolate between adjacent knots.
        pos = jnp.clip(pos, 0.0, n - 1.0)
        i0 = jnp.floor(pos)
        frac = pos - i0
        i0 = i0.astype(jnp.int32)
        i1 = jnp.minimum(i0 + 1, n - 1)
        return values[i0] * (1.0 - frac) + values[i1] * frac
    if indexing == "nearest":
        idx = jnp.clip(jnp.round(pos), 0, n - 1).astype(jnp.int32)
    else:  # trunc — hls4ml-faithful
        idx = jnp.clip(jnp.floor(pos), 0, n - 1).astype(jnp.int32)
    return values[idx]


def lut_activation(x: jnp.ndarray, fn: str, *, n: int = 1024,
                   lo: float = -8.0, hi: float = 8.0, qtype: QType = None,
                   indexing: str = "interp", gated: bool = True) -> jnp.ndarray:
    """Apply activation ``fn`` via a trace-time constant table.

    ``gated=True`` uses the exact gated form for unbounded activations
    (silu/gelu): f(x) = x * gate_table(x).  ``gated=False`` tables f
    directly (hls4ml-faithful; saturates for |x| > hi).
    """
    if gated and fn in GATED_FORMS:
        gate = get_table(TableSpec(GATED_FORMS[fn], n, lo, hi, qtype, indexing))
        return x * gate(x)
    if fn == "softplus":
        # softplus(x) -> x for large x; keep the asymptote exact.
        t = get_table(TableSpec(fn, n, lo, hi, qtype, indexing))
        return jnp.where(x >= hi, x, t(x))
    t = get_table(TableSpec(fn, n, lo, hi, qtype, indexing))
    return t(x)


# --------------------------------------------------------------------------
# Softmax — reproducing (and de-specializing) the hls4ml implementation.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SoftmaxTablePolicy:
    n: int = 1024
    qtype: QType = AC_FIXED_18_8
    exp_lo: float = -16.0
    exp_hi: float = 0.0
    inv_hi: float = 64.0          # hls4ml invert-table domain cap
    exact_divide: bool = True     # improved mode: exact div after LUT exp
    indexing: str = "trunc"


def softmax_table_policy(user_qtype: QType = None, *,
                         respect_user_type: bool = False,
                         n: int = 1024, exact_divide: bool = True,
                         indexing: str = "trunc") -> SoftmaxTablePolicy:
    """The paper-documented override: softmax tables are 1024×18-bit fixed
    point (filling one Xilinx 18k BRAM) *regardless* of the user's model
    type — unless ``respect_user_type`` asks for the de-specialized fix.
    """
    qtype = user_qtype if respect_user_type else AC_FIXED_18_8
    return SoftmaxTablePolicy(n=n, qtype=qtype, exact_divide=exact_divide,
                              indexing=indexing)


def table_softmax(x: jnp.ndarray, axis: int = -1,
                  policy: Optional[SoftmaxTablePolicy] = None) -> jnp.ndarray:
    """Softmax whose exp (and optionally 1/x) come from constant tables.

    ``exact_divide=False`` is the fully hls4ml-faithful path: the reduction
    sum is inverted through a second table over (0, inv_hi] — accurate only
    while the row sum stays inside the table domain.  The improved default
    keeps the LUT exp (the expensive transcendental) and divides exactly.
    """
    p = policy or SoftmaxTablePolicy()
    exp_t = get_table(TableSpec("exp", p.n, p.exp_lo, p.exp_hi, p.qtype, p.indexing))
    z = x - jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    z = jnp.maximum(z, p.exp_lo)  # saturate into table domain
    e = exp_t(z)
    s = jnp.sum(e, axis=axis, keepdims=True)
    if p.exact_divide:
        return e / s
    inv_t = get_table(TableSpec("invert", p.n, 1.0 / p.n, p.inv_hi, p.qtype, p.indexing))
    return e * inv_t(jnp.minimum(s, p.inv_hi))
