"""Quantizers: PTQ, fake-quant QAT (straight-through), dynamic-range int8.

Three quantization modes, mirroring the paper's usage tiers:

* **static fixed point** (``ac_fixed`` semantics): binary-point scale fixed
  by the type — the paper-faithful mode.  :func:`fake_quant`.
* **dynamic-range fixed point**: scale calibrated from data (per-tensor or
  per-channel max-abs), integer payload carried in a :class:`QTensor` and
  executed on the MXU int8 path.  :func:`quantize_dynamic` /
  :func:`ptq_params`.
* **minifloat** (custom floating point): :func:`fake_quant` with a
  :class:`~repro.core.qtypes.MiniFloatType`.

All fake-quant ops are differentiable via the straight-through estimator
(identity gradient inside the representable range, zero outside — the
standard clipping STE), so the same machinery serves QAT.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from .qtypes import FixedPointType, MiniFloatType, QTensor

__all__ = [
    "fake_quant",
    "quantize_dynamic",
    "calibrate_scale",
    "ptq_params",
    "dequantize_params",
]

QType = Union[FixedPointType, MiniFloatType]


# --------------------------------------------------------------------------
# Straight-through fake quantization (QAT + paper-faithful static PTQ).
# --------------------------------------------------------------------------
@jax.custom_vjp
def _ste_round_trip(x: jnp.ndarray, lo: float, hi: float, q: jnp.ndarray):
    # q is the already-quantized value; lo/hi bound the representable range.
    del x, lo, hi
    return q


def _ste_fwd(x, lo, hi, q):
    return q, (x, lo, hi)


def _ste_bwd(res, g):
    x, lo, hi = res
    mask = ((x >= lo) & (x <= hi)).astype(g.dtype)
    return (g * mask, None, None, None)


_ste_round_trip.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: jnp.ndarray, qtype: QType) -> jnp.ndarray:
    """Round-trip ``x`` through ``qtype`` with straight-through gradients."""
    if isinstance(qtype, FixedPointType):
        lo, hi = qtype.min_value, qtype.max_value
    else:
        hi = qtype.max_value
        lo = -hi
    q = qtype.quantize(x)
    return _ste_round_trip(x, lo, hi, q.astype(x.dtype))


# --------------------------------------------------------------------------
# Dynamic-range integer quantization (the MXU execution path).
# --------------------------------------------------------------------------
def calibrate_scale(x: jnp.ndarray, qtype: FixedPointType,
                    channel_axes: Sequence[int] = ()) -> jnp.ndarray:
    """Max-abs scale so the observed range maps onto the integer range.

    ``channel_axes`` are the axes *kept* (per-channel); all others reduce.
    Returned scale broadcasts against ``x`` (kept axes retain their size).
    """
    reduce_axes = tuple(a for a in range(x.ndim) if a not in
                        tuple(a % x.ndim for a in channel_axes))
    amax = jnp.max(jnp.abs(x), axis=reduce_axes, keepdims=True)
    amax = jnp.maximum(amax, 1e-12)
    return (amax / qtype.int_max).astype(jnp.float32)


def quantize_dynamic(x: jnp.ndarray, qtype: FixedPointType,
                     channel_axes: Sequence[int] = (),
                     scale: Optional[jnp.ndarray] = None) -> QTensor:
    """Quantize with a calibrated (or provided) scale into a QTensor."""
    if scale is None:
        scale = calibrate_scale(x, qtype, channel_axes)
    data = jnp.clip(jnp.round(x / scale), qtype.int_min, qtype.int_max)
    return QTensor(data.astype(qtype.dtype), scale, qtype)


# --------------------------------------------------------------------------
# Whole-pytree PTQ (the hls4ml "convert a trained model" flow).
# --------------------------------------------------------------------------
#: leaf keys that feed matmul consumers (nn.linear / nn.moe) and can
#: therefore carry a QTensor.  Everything else — embedding tables
#: (gathered, not matmul'd), routers (precision-sensitive, §Arch),
#: depthwise conv filters, norms, biases — stays a dense float array.
_MATMUL_WEIGHT_KEYS = frozenset({"w", "w_gate", "w_up", "w_down"})


def _is_weight(path: Tuple, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False  # biases / scales / norms stay high precision
    joined = "/".join(str(p) for p in path).lower()
    if "embed" in joined or "router" in joined:
        return False
    name = str(path[-1]) if path else ""
    return name in _MATMUL_WEIGHT_KEYS


def _weight_channel_axes(ndim: int) -> Tuple[int, ...]:
    """Keep every axis except the contraction axis (-2).

    A weight is (..., d_in, d_out): per-out-channel scales with all
    leading (layer-stack / expert) axes kept, so stacked QTensor params
    slice cleanly under ``lax.scan`` (data and scale share the leading
    L axis).
    """
    return tuple(a for a in range(ndim) if a != ndim - 2)


def ptq_params(params, policy, *,
               channel_axes: Optional[Sequence[int]] = None,
               predicate=_is_weight):
    """Post-training-quantize a parameter pytree.

    ``policy`` is a :class:`repro.core.precision.PrecisionPolicy` (or a
    single qtype applied uniformly).  Weight matrices become
    :class:`QTensor`; everything else passes through.  Mirrors hls4ml's
    model conversion: the trained float model in, a quantized deployable
    artifact out.  The result feeds :func:`repro.nn.linear.linear`
    directly — serving quantizes weights ONCE here, never per forward.

    ``channel_axes`` (axes *kept* by the scale) defaults to "all but the
    contraction axis": per-out-channel scales that also keep any leading
    layer-stack / expert axes, so stacked params remain scannable.
    """
    from .precision import PrecisionPolicy  # local import to avoid a cycle

    def quant_leaf(path, leaf):
        if not predicate(path, leaf):
            return leaf
        if isinstance(policy, PrecisionPolicy):
            qt = policy.resolve("/".join(str(p) for p in path)).weights
        else:
            qt = policy
        if qt is None:
            return leaf
        if isinstance(qt, MiniFloatType):
            return qt.quantize(leaf)
        axes = (channel_axes if channel_axes is not None
                else _weight_channel_axes(leaf.ndim))
        return quantize_dynamic(leaf, qt, channel_axes=axes)

    return jax.tree_util.tree_map_with_path(
        lambda p, l: quant_leaf(tuple(_path_key(k) for k in p), l), params)


def dequantize_params(qparams, dtype=jnp.float32):
    """Inverse of :func:`ptq_params` (for accuracy-loss measurement)."""
    return jax.tree_util.tree_map(
        lambda l: l.dequantize(dtype) if isinstance(l, QTensor) else l,
        qparams, is_leaf=lambda l: isinstance(l, QTensor))


def _path_key(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)
