"""Weight pruning — the paper's §III "weights compression".

hls4ml enforces sparsity during training and relies on the HLS backend to
eliminate zero-weight multipliers.  On TPU, unstructured zeros buy nothing
on the dense MXU — the de-specialized translation keeps the paper's
*training-time sparsity enforcement* but produces **structured** masks the
hardware can exploit:

* ``magnitude_mask`` — global unstructured top-k (the hls4ml-faithful
  form; useful for accuracy studies and for backends that do eliminate
  zeros),
* ``nm_mask`` — N:M structured sparsity (keep N largest of every M
  consecutive weights along the reduction dim — the form sparse tensor
  units accelerate),
* ``apply_masks`` / ``enforce`` — masked-training hook: re-apply masks to
  params after every optimizer step so sparsity survives training,
  exactly the paper's "enforcing sparsity in the training phase".
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

__all__ = ["magnitude_mask", "nm_mask", "make_masks", "apply_masks",
           "sparsity"]


def magnitude_mask(w: jnp.ndarray, sparsity_target: float) -> jnp.ndarray:
    """Boolean keep-mask zeroing the smallest |w| fraction."""
    k = int(round(w.size * (1.0 - sparsity_target)))
    k = max(k, 1)
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[-k]
    return jnp.abs(w) >= thresh


def nm_mask(w: jnp.ndarray, n: int = 2, m: int = 4) -> jnp.ndarray:
    """N:M structured mask along the leading (reduction) axis.

    Requires w.shape[0] % m == 0; keeps the n largest of each group of m.
    """
    d_in = w.shape[0]
    assert d_in % m == 0, (d_in, m)
    groups = w.reshape(d_in // m, m, *w.shape[1:])
    a = jnp.abs(groups)
    # rank within each group of m; keep the top n
    order = jnp.argsort(a, axis=1)
    ranks = jnp.argsort(order, axis=1)
    keep = ranks >= (m - n)
    return keep.reshape(w.shape)


def make_masks(params, *, sparsity_target: float = 0.5,
               structured: Optional[tuple] = None,
               min_ndim: int = 2) -> Dict:
    """Mask pytree for every weight matrix (None for passthrough leaves)."""
    def one(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < min_ndim:
            return None
        if structured is not None:
            n, m = structured
            if leaf.shape[0] % m == 0:
                return nm_mask(leaf, n, m)
            return None
        return magnitude_mask(leaf, sparsity_target)

    return jax.tree_util.tree_map(one, params)


def apply_masks(params, masks):
    """Zero out pruned weights (call after each optimizer step)."""
    return jax.tree_util.tree_map(
        lambda p, m: p if m is None else p * m.astype(p.dtype),
        params, masks, is_leaf=lambda x: x is None)


def sparsity(params) -> float:
    """Fraction of exactly-zero weight entries across matrix leaves."""
    zeros = total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        if hasattr(leaf, "ndim") and leaf.ndim >= 2:
            zeros += int(jnp.sum(leaf == 0))
            total += leaf.size
    return zeros / max(total, 1)
