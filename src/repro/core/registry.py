"""Backend-pluggable op registry — the de-specialization mechanism.

The paper's thesis: the component library must not bake in one backend's
idioms.  Here every performance-critical op is *defined once* by name and
carries multiple lowerings:

* ``ref``    — pure ``jnp`` (the "portable C++"); always present, is the
  numerics oracle.
* ``pallas`` — the TPU-specialized kernel (``pl.pallas_call`` + BlockSpec).
* further backends (``pallas_interpret`` for CPU validation) register the
  same way — this is how Bambu slots in next to Vivado in the paper.

Selection: explicit argument > ambient ``use_backend(...)`` context >
global default.  Unknown (op, backend) pairs fall back to ``ref`` when
``allow_fallback`` — portability means degrading to the portable
implementation, never failing.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Callable, Dict, Optional

__all__ = ["register_op", "get_impl", "use_backend", "current_backend",
           "set_default_backend", "list_ops"]

_OPS: Dict[str, Dict[str, Callable]] = {}
_state = threading.local()
_DEFAULT_BACKEND = "ref"


def register_op(name: str, backend: str = "ref"):
    """Decorator: register ``fn`` as the ``backend`` lowering of op ``name``."""
    def deco(fn):
        _OPS.setdefault(name, {})[backend] = fn
        return fn
    return deco


def set_default_backend(backend: str) -> None:
    global _DEFAULT_BACKEND
    _DEFAULT_BACKEND = backend


def current_backend() -> str:
    return getattr(_state, "backend", None) or _DEFAULT_BACKEND


@contextlib.contextmanager
def use_backend(backend: str):
    """Ambiently select a backend for all ops in scope."""
    prev = getattr(_state, "backend", None)
    _state.backend = backend
    try:
        yield
    finally:
        _state.backend = prev


def get_impl(name: str, backend: Optional[str] = None, *,
             allow_fallback: bool = True) -> Callable:
    if name not in _OPS:
        raise KeyError(f"op {name!r} is not registered")
    b = backend or current_backend()
    impls = _OPS[name]
    if b in impls:
        return impls[b]
    if allow_fallback and "ref" in impls:
        return impls["ref"]
    raise KeyError(f"op {name!r} has no {b!r} lowering and fallback is off "
                   f"(available: {sorted(impls)})")


def list_ops() -> Dict[str, list]:
    return {k: sorted(v) for k, v in _OPS.items()}
