"""Paper claim: constexpr-built constant tables replicate activation
functions with bounded error, at a fraction of the runtime-math cost
(§III/§IV-A, incl. the 1024×18-bit softmax table).

Reports, per (function × table size × value type × indexing):
  * max/mean absolute error against float64 math,
  * flops per element for LUT vs transcendental from compiled HLO,
and reproduces the softmax-table override accuracy profile.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qtypes import AC_FIXED_18_8, FixedPointType
from repro.core.tables import (COMPUTE_FNS, SoftmaxTablePolicy, TableSpec,
                               get_table, table_lookup, table_softmax)
from repro.launch.hlo_analysis import analyze_hlo


def _flops_per_elem(fn, x):
    c = jax.jit(fn).lower(x).compile()
    a = analyze_hlo(c.as_text(), 1)
    return a.flops / x.size


def run():
    rows = []
    x = jnp.asarray(np.linspace(-7.9, 7.9, 1 << 16).astype(np.float32))

    for name in ("sigmoid", "tanh", "gelu_gate", "exp"):
        lo, hi = (-16.0, 0.0) if name == "exp" else (-8.0, 8.0)
        xs = x if name != "exp" else jnp.asarray(
            np.linspace(-15.9, -0.1, 1 << 16).astype(np.float32))
        ref = COMPUTE_FNS[name](np.asarray(xs, np.float64))
        for n in (256, 1024, 4096):
            for qt, qname in ((None, "f32"), (AC_FIXED_18_8, "fx18_8")):
                for idx in ("trunc", "interp"):
                    spec = TableSpec(name, n, lo, hi, qt, idx)
                    y = table_lookup(xs, jnp.asarray(get_table(spec)
                                                     .np_values),
                                     lo, hi, idx)
                    err = np.abs(np.asarray(y, np.float64) - ref)
                    rows.append({
                        "bench": "lut_tables",
                        "name": f"{name}/n{n}/{qname}/{idx}",
                        "max_err": float(err.max()),
                        "mean_err": float(err.mean()),
                    })

    # flops: LUT gather vs transcendental (compiled, per element)
    spec = TableSpec("sigmoid", 1024, -8.0, 8.0, None, "trunc")
    t = jnp.asarray(get_table(spec).np_values)
    f_lut = _flops_per_elem(
        lambda v: table_lookup(v, t, -8.0, 8.0, "trunc"), x)
    f_exact = _flops_per_elem(lambda v: jax.nn.sigmoid(v), x)
    rows.append({"bench": "lut_tables", "name": "flops_per_elem/lut",
                 "value": f_lut})
    rows.append({"bench": "lut_tables", "name": "flops_per_elem/exact",
                 "value": f_exact})

    # softmax: override (18-bit) vs user-type vs exact — the §III finding
    z = jnp.asarray(np.random.RandomState(0).randn(64, 128) * 4)
    exact = jax.nn.softmax(z, -1)
    for pname, pol in [
            ("override_18bit", SoftmaxTablePolicy()),
            ("user_8bit", SoftmaxTablePolicy(qtype=FixedPointType(8, 3))),
            ("faithful_invert", SoftmaxTablePolicy(exact_divide=False)),
            ("interp_f32", SoftmaxTablePolicy(qtype=None,
                                              indexing="interp"))]:
        y = table_softmax(z, policy=pol)
        rows.append({"bench": "lut_tables",
                     "name": f"softmax/{pname}",
                     "max_err": float(jnp.abs(y - exact).max())})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
