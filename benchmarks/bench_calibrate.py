"""Knob-grid calibration sweep: the autotuner's training data.

rule4ml fits its latency estimators from a corpus of *measured*
designs; this bench builds the serving engine's equivalent corpus.  It
walks the admissible ``(pages_per_step, kv_split)`` grid of a few
paged-attention geometries, times the XLA schedule lowering of each
point (the same lowering ``run_long_context`` compares — on CPU it
measures the *schedule*: serial tile-chain length with partitions
batched per step — see that bench's rationale), and least-squares-fits
the shared feature basis of :mod:`repro.launch.autotune`.

Outputs:

* ``BENCH_calibrate.json`` rows — one per measured grid point with the
  full shape/knob key, so the fit is reproducible from the artifact
  alone and the trajectory accumulates like every other bench, and
* ``AUTOTUNE.json`` at the repo root — the committed fit
  (``autotune.save_artifact``), which ``--autotune fitted`` engines
  load at construction.

The acceptance gate is deliberately about *ranking*, not absolute
walltime (rule4ml's lesson: the model only has to order knob points):
the fit must explain the sweep (R² bound) and the point it ranks best
must measure within a small factor of the measured-best point.
"""

import itertools
import time

import jax.numpy as jnp
import numpy as np

#: grid geometries: long-chain MQA (the split's reason to exist), a
#: grouped-KV mid-size table, and a short-chain shape that should pin
#: to small splits — enough spread to identify every feature weight.
_SHAPES = (
    # (pages, page_size, hq, hkv, batch, d)
    (64, 8, 4, 1, 4, 64),
    (32, 8, 4, 2, 2, 64),
    (16, 16, 4, 1, 8, 64),
)


def _measure_point(pages, page_size, hq, hkv, batch, d, kv_split,
                   pages_per_step, iters, repeats=3):
    """Walltime of one grid point in µs/call.

    ``run_long_context``'s timing discipline: each timed region issues
    ``iters`` async dispatches and syncs ONCE (per-call timing at the
    100µs scale measures the host timer, not the schedule), and the
    best of ``repeats`` regions is kept — the fit's training target
    must be the code path, not CI scheduling noise.
    """
    from repro.kernels.ops import paged_attention

    rs = np.random.RandomState(hash((pages, page_size, batch)) % 2**31)
    q = jnp.asarray(rs.randn(batch, hq, 1, d), jnp.float32)
    kp = jnp.asarray(rs.randn(pages + 1, hkv, page_size, d), jnp.float32)
    vp = jnp.asarray(rs.randn(pages + 1, hkv, page_size, d), jnp.float32)
    bt = jnp.asarray(np.stack([rs.permutation(pages)
                               for _ in range(batch)]), jnp.int32)
    qpos = jnp.asarray(np.full(batch, pages * page_size - 1), jnp.int32)

    def step():
        return paged_attention(q, kp, vp, bt, qpos, backend="xla",
                               kv_split=kv_split,
                               pages_per_step=pages_per_step)

    step().block_until_ready()                  # compile (untimed)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = step()
        out.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best / iters * 1e6


def sweep(shapes=_SHAPES, iters=20):
    """Measure every admissible (tile, split) point of each shape."""
    from repro.launch.autotune import WorkloadShape, kv_candidates

    rows = []
    for pages, ps, hq, hkv, batch, d in shapes:
        shape = WorkloadShape(pages=pages, page_size=ps, hkv=hkv,
                              batch=batch)
        for t, split in kv_candidates(shape):
            us = _measure_point(pages, ps, hq, hkv, batch, d, split, t,
                                iters)
            rows.append({"bench": "calibrate",
                         "name": f"p{pages}ps{ps}b{batch}h{hkv}"
                                 f"_t{t}s{split}",
                         "pages": pages, "page_size": ps, "hkv": hkv,
                         "batch": batch, "kv_split": split,
                         "pages_per_step": t, "us_per_call": us})
    return rows


def run(shapes=_SHAPES, iters=20):
    """Sweep, fit, commit the artifact, gate on ranking quality."""
    from repro.launch.autotune import fit_rows, save_artifact

    rows = sweep(shapes=shapes, iters=iters)
    est = fit_rows(rows)
    path = save_artifact(est)
    c = est.cost_constants()
    # -- gates -------------------------------------------------------
    # the fit must explain the sweep: residual is 1 - R^2 over the
    # training rows ("round-trips its training rows within tolerance")
    assert est.residual < 0.5, \
        (f"calibration fit explains only {1 - est.residual:.0%} of the "
         f"sweep variance — feature basis no longer matches the "
         f"schedule (rows={est.n_rows})")
    assert c["tile_cost"] > 0 and c["combine_cost"] > 0
    # ranking gate per shape: the fitted-best point must measure close
    # to the measured-best point (2x is generous — CPU timer noise on
    # µs-scale arms — while still catching an inverted ranking)
    worst_ratio = 0.0
    for pages, ps, hq, hkv, batch, d in shapes:
        pts = [r for r in rows if (r["pages"], r["page_size"],
                                   r["batch"], r["hkv"])
               == (pages, ps, batch, hkv)]
        meas_best = min(p["us_per_call"] for p in pts)
        pred_best = min(pts, key=lambda p: est.predict(
            p["pages"], p["page_size"], p["hkv"], p["batch"],
            p["kv_split"], p["pages_per_step"]))
        worst_ratio = max(worst_ratio,
                          pred_best["us_per_call"] / meas_best)
    assert worst_ratio <= 2.0, \
        (f"fitted ranking picked a point {worst_ratio:.2f}x slower "
         f"than the measured best — refit or revisit the basis")
    rows.append({"bench": "calibrate", "name": "fit",
                 "n_rows": est.n_rows, "fit_residual": est.residual,
                 "tile_cost": c["tile_cost"],
                 "combine_cost": c["combine_cost"],
                 "ranking_ratio": worst_ratio,
                 "artifact": str(path)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
