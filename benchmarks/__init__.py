# Benchmark package: one module per paper claim (the paper has no numeric
# tables — it is explicit that results are forthcoming — so each benchmark
# operationalizes one of its §III/§IV claims; see DESIGN.md §5).
