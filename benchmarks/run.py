"""Benchmark harness: one module per paper claim (DESIGN.md §5).

Prints ``name,us_per_call,derived`` CSV: ``us_per_call`` where a walltime
exists (CPU-relative), and every other measured quantity folded into the
``derived`` column as ``key=value`` pairs.  Roofline benchmarks (per
paper-scale table) live in the dry-run artifacts; ``--with-roofline``
appends their summary lines if artifacts/dryrun exists.

JSON artifacts are written BY DEFAULT: one ``BENCH_<name>.json`` per
bench module (``BENCH_serving.json`` among them) plus a combined
``BENCH_all.json``, all at the repo root — so every bench run (local or
CI) lands in-repo and the perf trajectory accumulates in version
control instead of scrollback.  When a previous ``BENCH_<name>.json``
exists, per-row deltas against it are printed before it is overwritten
(``delta,<bench>/<name>,<key>,<old>-><new>,<pct>``).  ``--json PATH``
redirects the artifacts; ``--json none`` disables them.
"""

import argparse
import glob
import json
import os

#: default artifact directory: the repo root (parent of benchmarks/)
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: keys whose drift is worth a delta line (measured quantities, not
#: configuration echoes)
_DELTA_KEYS = ("us_per_call", "tok_per_s", "prompt_tok_per_s",
               "admitted_tok_per_s", "ms_total", "jit_calls_per_token",
               "speedup_vs_unsplit", "speedup_vs_fused_loop",
               "accepted_per_step", "capacity_vs_dense", "mean_row_fill",
               "greedy_agreement_vs_fp32", "fit_residual",
               "tile_cost", "combine_cost", "speedup_vs_pinned_worst",
               "speedup_vs_analytic", "time_to_promote_ms",
               "realtime_ttft_p99_ms", "batch_ttft_p50_ms",
               "batch_ttft_p99_ms")


def _fmt_derived(row):
    skip = {"bench", "name", "us_per_call"}
    parts = []
    for k, v in row.items():
        if k in skip:
            continue
        if isinstance(v, float):
            parts.append(f"{k}={v:.6g}")
        else:
            parts.append(f"{k}={v}")
    return ";".join(parts)


def _print_deltas(path, rows):
    """Compare fresh rows against the previous artifact at ``path``.

    One line per drifted measured key — the in-repo perf trajectory's
    diff view: a regression shows up in the bench output (and the git
    diff of the artifact) without opening either JSON.
    """
    try:
        with open(path) as f:
            prev = {(r.get("bench"), r.get("name")): r for r in json.load(f)}
    except (OSError, ValueError):
        return
    for row in rows:
        old = prev.get((row.get("bench"), row.get("name")))
        if not old:
            continue
        for k in _DELTA_KEYS:
            a, b = old.get(k), row.get(k)
            if not (isinstance(a, (int, float)) and isinstance(b, (int, float))):
                continue
            if b == a:
                continue
            pct = (b - a) / a * 100 if a else float("inf")
            print(f"delta,{row['bench']}/{row['name']},{k},"
                  f"{a:.6g}->{b:.6g},{pct:+.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--with-roofline", action="store_true")
    ap.add_argument("--json", default=_REPO_ROOT, metavar="PATH",
                    help="where BENCH_<name>.json per bench + combined "
                         "BENCH_all.json land (default: the repo root, so "
                         "runs accumulate in-repo); 'none' disables")
    args, _ = ap.parse_known_args()
    if args.json == "none":
        args.json = None

    from . import (bench_backends, bench_calibrate, bench_lut_tables,
                   bench_qmatmul, bench_quant_accuracy, bench_reuse_factor,
                   bench_serving)
    modules = {
        "lut_tables": bench_lut_tables,
        "quant_accuracy": bench_quant_accuracy,
        "qmatmul": bench_qmatmul,
        "reuse_factor": bench_reuse_factor,
        "backends": bench_backends,
        # calibrate runs BEFORE serving: it commits AUTOTUNE.json, so
        # the serving module's run_autotune compares against the fresh
        # fit instead of a stale artifact
        "calibrate": bench_calibrate,
        "serving": bench_serving,
    }
    wanted = set(args.only.split(",")) if args.only else set(modules)

    if args.json:
        os.makedirs(args.json, exist_ok=True)
    all_rows = {}
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if name not in wanted:
            continue
        rows = mod.run()
        all_rows[name] = rows
        for row in rows:
            us = row.get("us_per_call", "")
            us = f"{us:.3f}" if isinstance(us, float) else ""
            print(f"{row['bench']}/{row['name']},{us},{_fmt_derived(row)}")
        if args.json:
            path = os.path.join(args.json, f"BENCH_{name}.json")
            _print_deltas(path, rows)
            with open(path, "w") as f:
                json.dump(rows, f, indent=2, default=float)
    if args.json:
        # merge into the existing combined artifact: a --only run must
        # refresh its selected benches without dropping the committed
        # trajectory of the unselected ones
        all_path = os.path.join(args.json, "BENCH_all.json")
        try:
            with open(all_path) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
        merged.update(all_rows)
        with open(all_path, "w") as f:
            json.dump(merged, f, indent=2, default=float)

    if args.with_roofline and os.path.isdir("artifacts/dryrun"):
        for fn in sorted(glob.glob("artifacts/dryrun/*.json")):
            d = json.load(open(fn))
            if d.get("status") != "ok":
                continue
            derived = (f"bottleneck={d['bottleneck']};mfu={d['mfu']:.4f};"
                       f"compute_s={d['compute_s']:.4f};"
                       f"memory_s={d['memory_s']:.4f};"
                       f"collective_s={d['collective_s']:.4f}")
            print(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']},,"
                  f"{derived}")


if __name__ == "__main__":
    main()
