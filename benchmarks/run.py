"""Benchmark harness: one module per paper claim (DESIGN.md §5).

Prints ``name,us_per_call,derived`` CSV: ``us_per_call`` where a walltime
exists (CPU-relative), and every other measured quantity folded into the
``derived`` column as ``key=value`` pairs.  Roofline benchmarks (per
paper-scale table) live in the dry-run artifacts; ``--with-roofline``
appends their summary lines if artifacts/dryrun exists.

``--json PATH`` additionally writes the SAME rows machine-readably:
one ``BENCH_<name>.json`` per bench module (``BENCH_serving.json``
among them) plus a combined ``BENCH_all.json``, all under PATH.  CI's
full job runs this and uploads the directory, so the bench trajectory
is an artifact instead of scrollback.
"""

import argparse
import glob
import json
import os


def _fmt_derived(row):
    skip = {"bench", "name", "us_per_call"}
    parts = []
    for k, v in row.items():
        if k in skip:
            continue
        if isinstance(v, float):
            parts.append(f"{k}={v:.6g}")
        else:
            parts.append(f"{k}={v}")
    return ";".join(parts)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names")
    ap.add_argument("--with-roofline", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_<name>.json per bench plus a "
                         "combined BENCH_all.json under PATH (created if "
                         "missing) — the CSV rows, machine-readable")
    args, _ = ap.parse_known_args()

    from . import (bench_backends, bench_lut_tables, bench_qmatmul,
                   bench_quant_accuracy, bench_reuse_factor, bench_serving)
    modules = {
        "lut_tables": bench_lut_tables,
        "quant_accuracy": bench_quant_accuracy,
        "qmatmul": bench_qmatmul,
        "reuse_factor": bench_reuse_factor,
        "backends": bench_backends,
        "serving": bench_serving,
    }
    wanted = set(args.only.split(",")) if args.only else set(modules)

    if args.json:
        os.makedirs(args.json, exist_ok=True)
    all_rows = {}
    print("name,us_per_call,derived")
    for name, mod in modules.items():
        if name not in wanted:
            continue
        rows = mod.run()
        all_rows[name] = rows
        for row in rows:
            us = row.get("us_per_call", "")
            us = f"{us:.3f}" if isinstance(us, float) else ""
            print(f"{row['bench']}/{row['name']},{us},{_fmt_derived(row)}")
        if args.json:
            with open(os.path.join(args.json,
                                   f"BENCH_{name}.json"), "w") as f:
                json.dump(rows, f, indent=2, default=float)
    if args.json:
        with open(os.path.join(args.json, "BENCH_all.json"), "w") as f:
            json.dump(all_rows, f, indent=2, default=float)

    if args.with_roofline and os.path.isdir("artifacts/dryrun"):
        for fn in sorted(glob.glob("artifacts/dryrun/*.json")):
            d = json.load(open(fn))
            if d.get("status") != "ok":
                continue
            derived = (f"bottleneck={d['bottleneck']};mfu={d['mfu']:.4f};"
                       f"compute_s={d['compute_s']:.4f};"
                       f"memory_s={d['memory_s']:.4f};"
                       f"collective_s={d['collective_s']:.4f}")
            print(f"roofline/{d['arch']}/{d['shape']}/{d['mesh']},,"
                  f"{derived}")


if __name__ == "__main__":
    main()
