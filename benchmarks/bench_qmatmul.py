"""Paper claim (§IV-B): the fixed-point datapath is the efficient one —
DSP-slice MACs on FPGA, int8 MXU with int32 accumulation on TPU.

Compares int8 qmatmul vs bf16/f32 matmul on compiled-HLO flops/bytes (the
HBM-traffic halving is the structural win) and CPU wall time of the
interpret-mode kernel vs its oracle (numerical parity is in tests/).

Also measures the **fused epilogue** (hls4ml's dense→activation dataflow
fusion, ported): linear+bias+LUT as ONE ``pallas_call`` vs the three-launch
composition — kernel-launch counts straight from the jaxpr, intermediate
HBM traffic eliminated, and ref-backend wall time."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tables import TableSpec
from repro.kernels.ops import lut_activation, qmatmul
from repro.kernels.ref import qmatmul_ref
from repro.launch.hlo_analysis import analyze_hlo, count_jaxpr_primitive


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(c.as_text(), 1)


def run_fused_epilogue(m=512, k=512, n=512, iters=5):
    """Fused qmatmul+bias+LUT (1 launch) vs the unfused composition (3)."""
    rows = []
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    sa = jnp.asarray(rng.rand(m, 1) * 0.01 + 1e-3, jnp.float32)
    sb = jnp.asarray(rng.rand(1, n) * 0.01 + 1e-3, jnp.float32)
    bias = jnp.asarray(rng.randn(n), jnp.float32)
    spec = TableSpec("silu_gate", 1024, -10.0, 10.0, None, "interp")

    def fused():
        return qmatmul(a, b, sa, sb, bias=bias, act_spec=spec,
                       act_gated=True, backend="pallas")

    def unfused():
        y = qmatmul(a, b, sa, sb, backend="pallas") + bias.reshape(1, -1)
        return y * lut_activation(y, spec, backend="pallas")

    launches = {name: count_jaxpr_primitive(jax.make_jaxpr(f)().jaxpr,
                                            "pallas_call")
                for name, f in [("fused", fused), ("unfused", unfused)]}
    # intermediate (M, N) f32 HBM round trips the fusion removes: the
    # matmul result is written+read for the bias add and again for the LUT
    saved_bytes = 2 * 2 * m * n * 4

    # CPU walltime of the ref-backend composition (relative only; the
    # interpret-mode pallas kernel measures Python, not the TPU)
    def fused_ref():
        return qmatmul(a, b, sa, sb, bias=bias, act_spec=spec,
                       act_gated=True, backend="ref")

    def unfused_ref():
        y = qmatmul(a, b, sa, sb, backend="ref") + bias.reshape(1, -1)
        return y * lut_activation(y, spec, backend="ref")

    for name, f, nl in [("fused", fused_ref, launches["fused"]),
                        ("unfused", unfused_ref, launches["unfused"])]:
        jf = jax.jit(f)
        jf().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            jf().block_until_ready()
        rows.append({"bench": "qmatmul_epilogue", "name": name,
                     "pallas_calls": nl,
                     "us_per_call": (time.perf_counter() - t0) / iters * 1e6,
                     "intermediate_hbm_bytes": 0 if name == "fused"
                     else saved_bytes})
    assert launches["fused"] == 1 and launches["unfused"] >= 2, launches
    return rows


def run():
    rows = []
    m = k = n = 1024
    rng = np.random.RandomState(0)
    a8 = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    sa = jnp.ones((m, 1), jnp.float32)
    sb = jnp.ones((1, n), jnp.float32)
    af = jnp.asarray(rng.randn(m, k), jnp.float32)
    bf = jnp.asarray(rng.randn(k, n), jnp.float32)

    c_int8 = _cost(lambda a, b: qmatmul_ref(a, b, sa, sb), a8, b8)
    c_bf16 = _cost(lambda a, b: (a.astype(jnp.bfloat16)
                                 @ b.astype(jnp.bfloat16)), af, bf)
    c_f32 = _cost(lambda a, b: a @ b, af, bf)

    for name, c, in_bytes in [
            ("int8_mxu", c_int8, m * k + k * n),
            ("bf16", c_bf16, 2 * (m * k + k * n)),
            ("f32", c_f32, 4 * (m * k + k * n))]:
        rows.append({"bench": "qmatmul", "name": name,
                     "hlo_flops": c.flops, "hlo_bytes": c.bytes,
                     "operand_bytes": in_bytes,
                     "arith_intensity": c.flops / max(in_bytes, 1)})

    # wall time (CPU; relative only — absolute numbers are not TPU claims)
    for name, fn in [
            ("ref_int8", jax.jit(lambda: qmatmul_ref(a8, b8, sa, sb))),
            ("f32_matmul", jax.jit(lambda: af @ bf))]:
        fn().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            fn().block_until_ready()
        rows.append({"bench": "qmatmul", "name": f"walltime/{name}",
                     "us_per_call": (time.perf_counter() - t0) / 5 * 1e6})
    rows.extend(run_fused_epilogue())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
