"""Paper claim (§IV-B): the fixed-point datapath is the efficient one —
DSP-slice MACs on FPGA, int8 MXU with int32 accumulation on TPU.

Compares int8 qmatmul vs bf16/f32 matmul on compiled-HLO flops/bytes (the
HBM-traffic halving is the structural win) and CPU wall time of the
interpret-mode kernel vs its oracle (numerical parity is in tests/)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import qmatmul_ref
from repro.launch.hlo_analysis import analyze_hlo


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(c.as_text(), 1)


def run():
    rows = []
    m = k = n = 1024
    rng = np.random.RandomState(0)
    a8 = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    b8 = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    sa = jnp.ones((m, 1), jnp.float32)
    sb = jnp.ones((1, n), jnp.float32)
    af = jnp.asarray(rng.randn(m, k), jnp.float32)
    bf = jnp.asarray(rng.randn(k, n), jnp.float32)

    c_int8 = _cost(lambda a, b: qmatmul_ref(a, b, sa, sb), a8, b8)
    c_bf16 = _cost(lambda a, b: (a.astype(jnp.bfloat16)
                                 @ b.astype(jnp.bfloat16)), af, bf)
    c_f32 = _cost(lambda a, b: a @ b, af, bf)

    for name, c, in_bytes in [
            ("int8_mxu", c_int8, m * k + k * n),
            ("bf16", c_bf16, 2 * (m * k + k * n)),
            ("f32", c_f32, 4 * (m * k + k * n))]:
        rows.append({"bench": "qmatmul", "name": name,
                     "hlo_flops": c.flops, "hlo_bytes": c.bytes,
                     "operand_bytes": in_bytes,
                     "arith_intensity": c.flops / max(in_bytes, 1)})

    # wall time (CPU; relative only — absolute numbers are not TPU claims)
    for name, fn in [
            ("ref_int8", jax.jit(lambda: qmatmul_ref(a8, b8, sa, sb))),
            ("f32_matmul", jax.jit(lambda: af @ bf))]:
        fn().block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            fn().block_until_ready()
        rows.append({"bench": "qmatmul", "name": f"walltime/{name}",
                     "us_per_call": (time.perf_counter() - t0) / 5 * 1e6})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
