"""Paper claim (§III): the reuse factor trades parallelism against
resources; hls4ml's full unrolling "quickly depletes available resources".

TPU translation measured here:
  * scan unroll factor (reuse_factor → unroll) vs HLO size (the FPGA
    'area' analogue is compiled code size / instruction count),
  * qmatmul block-K (reuse of one MXU tile across K steps) vs VMEM
    working set and grid steps.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import get_family, loss_fn
from repro.nn.context import QuantContext


def _hlo_size(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    txt = c.as_text()
    return len(txt), txt.count("\n")


def run():
    rows = []
    cfg = get_config("yi-6b").smoke()
    fam = get_family(cfg)
    params = jax.eval_shape(lambda: fam.init(jax.random.PRNGKey(0), cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32)}

    for rf in (1, 2, 4, 8):
        ctx = QuantContext(compute_dtype=jnp.float32, reuse_factor=rf)
        size, lines = _hlo_size(
            lambda p, b: loss_fn(p, b, cfg, ctx)[0], params, batch)
        rows.append({"bench": "reuse_factor",
                     "name": f"scan_unroll/rf{rf}",
                     "unroll": ctx.scan_unroll,
                     "hlo_bytes": size, "hlo_lines": lines})

    # kernel-level: block-K reuse vs VMEM footprint (static analysis)
    for bk in (128, 256, 512, 1024):
        bm = bn = 256
        vmem = bm * bk + bk * bn + bm * bn * 4 + bm * bn * 4
        steps = 1024 // bk
        rows.append({"bench": "reuse_factor", "name": f"qmatmul_bk{bk}",
                     "vmem_bytes": vmem, "k_steps": steps,
                     "reuse": steps})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
