"""Paper claim (§IV-B): fixed-point PTQ costs little accuracy on the
hls4ml jet-tagging workload, and custom minifloats open a design space
between aggressive fixed point and fp32.

Trains the 16→64→32→32→5 MLP, then sweeps PTQ formats:
fixed-point widths {16,6} {12,4} {10,4} {8,3} {6,2} and minifloats
(e,m) ∈ {E5M2, E4M3, E3M4, E5M7(≈fp13)} — reporting accuracy deltas.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import FixedPointType, MiniFloatType
from repro.models import mlp
from repro.nn.context import QuantContext


def jet_data(n, seed=0):
    """Synthetic jet-tagging-like task: 16 features → 5 classes.  Class
    centers are FIXED (task identity); ``seed`` draws fresh noise/labels
    (train/test splits share the task)."""
    rng_task = np.random.RandomState(0)
    centers = rng_task.randn(5, 16) * 2.0
    rng = np.random.RandomState(seed + 1)
    y = rng.randint(0, 5, n)
    xx = centers[y] + rng.randn(n, 16) * 1.0
    return jnp.asarray(xx, jnp.float32), jnp.asarray(y, jnp.int32)


def train(steps=400, lr=0.05):
    x, y = jet_data(4096)
    params = mlp.init(jax.random.PRNGKey(0))
    ctx = QuantContext(compute_dtype=jnp.float32)

    @jax.jit
    def step(p):
        (_, m), g = jax.value_and_grad(mlp.loss, has_aux=True)(
            p, {"x": x, "y": y}, ctx)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), m

    for _ in range(steps):
        params, m = step(params)
    return params


def accuracy(params, qt=None, n=4096):
    x, y = jet_data(n, seed=9)
    if qt is None:
        ctx = QuantContext(compute_dtype=jnp.float32)
    else:
        ctx = QuantContext(mode="fake", policy=PrecisionPolicy.uniform(
            qt, activations=qt), compute_dtype=jnp.float32)
    p = mlp.forward(params, x, ctx)
    return float(jnp.mean((jnp.argmax(p, -1) == y)))


def run():
    params = train()
    acc_fp = accuracy(params)
    rows = [{"bench": "quant_accuracy", "name": "fp32", "accuracy": acc_fp,
             "delta": 0.0, "bits": 32}]
    for w, i in [(16, 6), (12, 4), (10, 4), (8, 3), (6, 2)]:
        acc = accuracy(params, FixedPointType(w, i))
        rows.append({"bench": "quant_accuracy",
                     "name": f"ac_fixed<{w},{i}>", "accuracy": acc,
                     "delta": acc - acc_fp, "bits": w})
    for e, m in [(5, 2), (4, 3), (3, 4), (5, 7)]:
        acc = accuracy(params, MiniFloatType(e, m, ieee_inf=(e, m) != (4, 3)))
        rows.append({"bench": "quant_accuracy", "name": f"e{e}m{m}",
                     "accuracy": acc, "delta": acc - acc_fp,
                     "bits": 1 + e + m})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
