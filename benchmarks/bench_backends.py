"""Paper claim (§IV-A): a de-specialized library runs identically across
backends.  Measures ref-vs-pallas(interpret) parity and dispatch overhead
for every registered op, plus the fallback path (unknown backend → ref)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import get_impl, list_ops, use_backend
from repro.core.tables import TableSpec
from repro.kernels import attention, lut_activation, qmatmul


def run():
    rows = []
    rng = np.random.RandomState(0)
    spec = TableSpec("gelu_gate", 1024, -8.0, 8.0, None, "interp")
    x = jnp.asarray(rng.randn(64, 128).astype(np.float32))
    a8 = jnp.asarray(rng.randint(-127, 128, (128, 256)), jnp.int8)
    b8 = jnp.asarray(rng.randint(-127, 128, (256, 64)), jnp.int8)
    q = jnp.asarray(rng.randn(1, 4, 64, 32).astype(np.float32))

    cases = [
        ("lut_activation", lambda be: lut_activation(x, spec, backend=be)),
        ("qmatmul", lambda be: qmatmul(a8, b8, 1.0, 1.0, backend=be)),
        ("attention", lambda be: attention(q, q, q, backend=be)),
    ]
    for name, fn in cases:
        ref = np.asarray(fn("ref"), np.float32)
        pal = np.asarray(fn("pallas"), np.float32)
        rows.append({"bench": "backends", "name": f"parity/{name}",
                     "max_abs_diff": float(np.abs(ref - pal).max()),
                     "backends": ",".join(list_ops()[name])})
        for be in ("ref", "pallas"):
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(fn(be))
            rows.append({"bench": "backends",
                         "name": f"walltime/{name}/{be}",
                         "us_per_call":
                             (time.perf_counter() - t0) / 3 * 1e6})

    # portability guarantee: an unknown backend degrades to ref, never fails
    f = get_impl("attention", "some_future_hls_tool", allow_fallback=True)
    rows.append({"bench": "backends", "name": "fallback_resolves",
                 "ok": f is not None})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
