"""The deployment scenario (§I/§V): quantized inference throughput.

Serves the smoke gemma model through the continuous-batching engine under
each numeric mode and reports tokens/s (CPU walltime — relative between
modes) plus greedy-token agreement vs the fp32 reference (accuracy
counterpart of the throughput numbers).

``run_prefill`` measures prompt ingestion: batched chunked prefill
(O(prompt_len / chunk) full-batch model calls for the whole group) vs the
legacy per-token decode loop (O(prompt_len) calls per slot).

``run_decode`` measures generation: the device-resident fused decode loop
(``step_many``: one jit dispatch and one host sync per block) vs the
per-token baseline (one of each per token), with byte-identical greedy
outputs asserted between the two.

``run_paged`` measures admission under mixed prompt lengths at EQUAL KV
HBM: the dense engine's capacity is ``batch`` slots of ``max_len`` rows
each, whether or not a request uses them; the paged engine spends the
same row budget as a shared page pool, so short requests admit the
moment their *used* tokens fit.  Reports admitted-tokens/s, peak
concurrent requests, and page utilization; asserts the paged engine
reaches ≥2x peak concurrency (or ≥1.5x admitted-tokens/s) at the same
row budget.

``run_long_context`` measures the split-KV latency knob at ≥64 pages
per slot: decode attention over a long page chain, unsplit (the serial
one-page-per-step schedule today's kernel executes — ``kv_split=1,
pages_per_step=1`` of the XLA schedule lowering, whose ``lax.scan``
carries the same dependence chain) vs the flash-decoding split chosen
by the cost model.  Asserts ≥1.5x decode tok/s; reports the resolved
``(kv_split, pages_per_step)`` pair so BENCH_serving.json records the
knob the model picked, not just the win.

``run_spec`` measures speculative decoding on a repetitive (code-like)
workload — the traffic shape where prompt-lookup drafting shines: the
greedy continuation keeps revisiting n-grams already in the history, so
most verify rounds commit several tokens for ONE target-model pass.
Compares the draft→verify engine against the PR 2 fused decode loop at
equal batch, asserts byte-identical greedy streams and ≥1.5x decode
tok/s, and reports tokens-accepted-per-verify-round.

Every serving comparison builds its engines through ``make_engine`` so
baselines and candidates share identical (seeded) params, mesh, and
defaults — the only differences are the kwargs under test."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models.api import get_family
from repro.nn.context import QuantContext
from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import AC_FIXED_16_6

_SETUP = None


def _serving_setup():
    """(cfg, ctx, fam, mesh, params) — built ONCE for every serving
    bench, so all engine comparisons share identical seeded weights."""
    global _SETUP
    if _SETUP is None:
        from repro.launch.mesh import make_local_mesh
        cfg = get_config("gemma-2b").smoke()
        ctx = QuantContext(compute_dtype=jnp.float32)
        fam = get_family(cfg)
        mesh = make_local_mesh()
        params = fam.init(jax.random.PRNGKey(0), cfg)
        _SETUP = (cfg, ctx, fam, mesh, params)
    return _SETUP


def make_engine(**kw):
    """One engine-construction path for every serving benchmark.

    ``run_decode``/``run_paged``/``run_spec`` baselines previously
    re-derived engine setup per run; routing them all through this
    helper guarantees compared engines differ ONLY in the kwargs under
    test (same params, same seed, same mesh, same defaults)."""
    from repro.launch.serve import Engine
    cfg, ctx, fam, mesh, params = _serving_setup()
    return Engine(cfg, ctx, params, mesh, **kw)


def _greedy(cfg, fam, params, ctx, prompts, gen=8):
    outs = []
    for p in prompts:
        cache = fam.init_cache(cfg, 1, p.shape[0] + gen + 1, jnp.float32)
        last, cache = fam.prefill(params, p[None], cache, cfg, ctx)
        toks = []
        pos = jnp.asarray([p.shape[0]], jnp.int32)
        tok = jnp.argmax(last[:, -1], -1)[:, None].astype(jnp.int32)
        for t in range(gen):
            toks.append(int(tok[0, 0]))
            lg, cache = fam.decode_step(params, tok, cache, pos + t, cfg,
                                        ctx)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(toks)
    return outs


def run_prefill(prompt_len=48, batch=4, chunk=8, iters=3):
    """Prompt-ingestion throughput: batched chunked prefill vs the
    per-token decode loop (model calls + prompt tokens/s)."""
    from repro.dist.constrain import use_mesh

    cfg, ctx, fam, mesh, params = _serving_setup()
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = {s: src.tokens(s, 1, prompt_len + 1)[0, :-1]
               for s in range(batch)}
    n_tok = batch * prompt_len
    rows = []
    with use_mesh(mesh):
        for name, chunked in [("chunked_prefill", True),
                              ("per_token_loop", False)]:
            # ONE engine per variant: iteration 0 pays the jit compiles
            # (warmup, untimed); later rounds re-admit the same prompts
            # into recycled slots, measuring steady-state ingestion.
            eng = make_engine(batch=batch, max_len=prompt_len + 8,
                              prefill_chunk=chunk)
            eng.chunked = eng.chunked and chunked
            calls = {"n": 0}

            def count(f):
                def g(*a, **k):
                    calls["n"] += 1
                    return f(*a, **k)
                return g

            eng.prefill = count(eng.prefill)
            eng.decode = count(eng.decode)
            times = []
            for it in range(iters + 1):
                for s in range(batch):
                    if eng.live[s]:
                        eng.finish(s)
                calls["n"] = 0
                t0 = time.perf_counter()
                eng.add_requests(prompts)
                jax.tree_util.tree_leaves(eng.cache)[0].block_until_ready()
                if it > 0:
                    times.append(time.perf_counter() - t0)
            rows.append({"bench": "serving_prefill", "name": name,
                         "model_calls": calls["n"],
                         "prompt_tok_per_s": n_tok / (sum(times)
                                                      / len(times)),
                         "ms_total": sum(times) / len(times) * 1e3})
    return rows


def run_decode(batch=4, prompt_len=16, gen_len=32, block=8, iters=3):
    """Decode throughput: fused multi-token loop vs per-token steps.

    Reports jit dispatches per generated token (the host↔device round
    trips the fused loop amortizes) and tok/s, and asserts the two
    engines emit byte-identical greedy token streams."""
    from repro.dist.constrain import use_mesh

    cfg, ctx, fam, mesh, params = _serving_setup()
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = {s: src.tokens(s, 1, prompt_len + 1)[0, :-1]
               for s in range(batch)}
    rows, outs = [], {}
    with use_mesh(mesh):
        for name, blk in [("decode_loop", block), ("per_token", 1)]:
            eng = make_engine(batch=batch,
                              max_len=prompt_len + gen_len + 1)
            dispatches = {"n": 0}
            real_step_many = eng.step_many

            def counting_step_many(n):
                dispatches["n"] += 1
                return real_step_many(n)

            eng.step_many = counting_step_many
            times = []
            for it in range(iters + 1):        # iteration 0 = jit warmup
                for s in range(batch):
                    if eng.outputs[s] is not None:
                        eng.finish(s)
                eng.add_requests(prompts, gen_len=gen_len)
                dispatches["n"] = 0
                t0 = time.perf_counter()
                while eng.live.any():
                    eng.step_many(blk)
                if it > 0:
                    times.append(time.perf_counter() - t0)
            n_tok = batch * gen_len
            outs[name] = [list(eng.outputs[s] or []) for s in range(batch)]
            rows.append({"bench": "serving_decode", "name": name,
                         "jit_calls_per_token": dispatches["n"] / n_tok,
                         "tok_per_s": n_tok / (sum(times) / len(times)),
                         "ms_total": sum(times) / len(times) * 1e3})
    # acceptance: byte-identical greedy outputs between the two engines
    assert outs["decode_loop"] == outs["per_token"], \
        "fused decode loop diverged from the per-token baseline"
    speedup = (rows[1]["jit_calls_per_token"]
               / rows[0]["jit_calls_per_token"])
    rows[0]["dispatch_reduction_vs_per_token"] = speedup
    return rows


def run_paged(gen_len=8, max_len=48, page_size=8, dense_batch=2,
              paged_batch=6, block=8, iters=2):
    """Mixed-length admission throughput at equal KV-row budget.

    Both engines get ``dense_batch * max_len`` KV rows.  The dense
    engine spends them as ``dense_batch`` fixed slots; the paged engine
    as a page pool shared by ``paged_batch`` lanes, so its concurrency
    is bounded by *used* tokens.  Requests mix short and long prompts —
    the traffic shape that leaves dense slots mostly empty."""
    from repro.dist.constrain import use_mesh

    cfg, ctx, fam, mesh, params = _serving_setup()
    src = SyntheticLM(cfg.vocab, seed=0)
    lens = [4, 20, 8, 24, 6, 16, 10, 12, 4, 18, 8, 14]
    prompts = [src.tokens(i, 1, n + 1)[0, :-1] for i, n in enumerate(lens)]
    n_admit_tok = sum(lens) + len(lens) * gen_len
    budget_rows = dense_batch * max_len

    rows, peaks = [], {}
    with use_mesh(mesh):
        for name, batch, kw in [
                ("dense_baseline", dense_batch, {}),
                ("paged", paged_batch,
                 dict(paged=True, page_size=page_size,
                      num_pages=budget_rows // page_size))]:
            eng = make_engine(batch=batch, max_len=max_len, **kw)
            times, fills, pools = [], [], []
            for it in range(iters + 1):        # iteration 0 = jit warmup
                t0 = time.perf_counter()
                for p in prompts:
                    eng.submit(p, gen_len=gen_len)
                eng.try_admit()
                while eng.live.any() or eng.waiting:
                    eng.step_many(block)
                    # page utilization: how full the *used* pages are
                    # (internal fragmentation) and how much of the pool
                    # is out (occupancy); dense fills are pos/max_len
                    held = sum(int(eng.pos[s]) for s in range(batch)
                               if eng.outputs[s] is not None)
                    if kw:
                        up = eng.allocator.used_pages
                        fills.append(held / max(up * page_size, 1))
                        pools.append(up / eng.allocator.num_pages)
                    else:
                        fills.append(held / budget_rows)
                eng.retire_finished()
                if it > 0:
                    times.append(time.perf_counter() - t0)
            dt = sum(times) / len(times)
            row = {"bench": "serving_paged", "name": name,
                   "kv_rows_budget": budget_rows,
                   "peak_concurrent": eng.counters["peak_live"],
                   "admitted_tok_per_s": n_admit_tok / dt,
                   "mean_row_fill": float(np.mean(fills)),
                   "ms_total": dt * 1e3}
            if kw:
                row["mean_pool_occupancy"] = float(np.mean(pools))
            peaks[name] = row
            rows.append(row)
    cap = peaks["paged"]["peak_concurrent"] \
        / peaks["dense_baseline"]["peak_concurrent"]
    tps = peaks["paged"]["admitted_tok_per_s"] \
        / peaks["dense_baseline"]["admitted_tok_per_s"]
    peaks["paged"]["capacity_vs_dense"] = cap
    peaks["paged"]["admitted_tok_speedup"] = tps
    # acceptance: the de-specialized layout must buy real capacity at
    # the same HBM — ≥2x concurrency, or failing that ≥1.5x admission
    # throughput (CPU walltime is the noisier of the two)
    assert cap >= 2.0 or tps >= 1.5, \
        f"paged engine shows no capacity win (cap {cap:.2f}, tps {tps:.2f})"
    return rows


def run_long_context(batch=4, hq=4, hkv=1, d=64, page_size=8, npages=64,
                     iters=100):
    """Long-context decode: split-KV flash decoding vs the serial chain.

    A decode-shaped attention step (S = 1, MQA like the gemma smoke
    model) against ``npages`` pages per slot — the regime the
    fused-loop engine hits at long context, where today's paged kernel
    walks its block table one page per grid step.  Both arms run the
    XLA lowering of the op (:mod:`repro.kernels` backend ``"xla"``), so
    the comparison isolates the *schedule*: the unsplit arm's scan IS
    the serial kernel's dependence chain (one page per step), the split
    arm runs the cost-model-chosen ``(kv_split, pages_per_step)`` point
    — partitions batched per step, merged by the shared log-sum-exp
    combine.  Interpret-mode Pallas walltime is deliberately NOT
    compared: on CPU it measures the interpreter's per-step array
    traffic, not the schedule (the kernel's conformance is covered in
    tests/test_split_kv.py instead).

    Asserts the knob's reason to exist: ≥1.5x decode tok/s at ≥64
    pages per slot.
    """
    from repro.kernels.flash_attention import (auto_pages_per_step,
                                               choose_kv_split)
    from repro.kernels.ops import paged_attention

    assert npages >= 64, "long-context bench contract: >=64 pages/slot"
    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.randn(batch, hq, 1, d), jnp.float32)
    kp = jnp.asarray(rs.randn(npages + 1, hkv, page_size, d), jnp.float32)
    vp = jnp.asarray(rs.randn(npages + 1, hkv, page_size, d), jnp.float32)
    # physically shuffled pages per slot (the table's whole point) and
    # near-full contexts: the last page partially filled per slot
    bt = jnp.asarray(np.stack([rs.permutation(npages)
                               for _ in range(batch)]), jnp.int32)
    qpos = jnp.asarray(npages * page_size - 1
                       - np.arange(batch) * (page_size // 2), jnp.int32)

    t_auto = auto_pages_per_step(page_size, npages)
    s_auto = choose_kv_split(npages * page_size, npages, hkv, batch=batch,
                             pages_per_step=t_auto)

    def time_arm(split, tile):
        def step():
            return paged_attention(q, kp, vp, bt, qpos, backend="xla",
                                   kv_split=split, pages_per_step=tile)
        step().block_until_ready()              # compile (untimed)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = step()
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    rows = []
    for name, split, tile in [("unsplit_serial_chain", 1, 1),
                              ("split_kv", s_auto, t_auto)]:
        dt = time_arm(split, tile)
        rows.append({"bench": "serving_long_context", "name": name,
                     "kv_split": split, "pages_per_step": tile,
                     "pages_per_slot": npages, "page_size": page_size,
                     "us_per_call": dt * 1e6,
                     "tok_per_s": batch / dt})
    speedup = rows[1]["tok_per_s"] / rows[0]["tok_per_s"]
    rows[1]["speedup_vs_unsplit"] = speedup
    # acceptance: the reuse-factor knob must buy real long-context
    # decode latency — >=1.5x tok/s over the serial page chain
    assert speedup >= 1.5, \
        (f"split-KV shows no long-context win (speedup {speedup:.2f} "
         f"at kv_split={s_auto}, pages_per_step={t_auto})")
    return rows


def run_autotune(batch=4, hq=4, hkv=1, d=64, page_size=8, npages=64,
                 iters=100, spec_gen_len=48, spec_cap=6):
    """The unified autotuner's two claims, measured.

    **Static resolution** (rule4ml move): the same long-context decode
    shape ``run_long_context`` uses, timed under three whole knob
    vectors — *pinned-worst* (``kv_split=1, pages_per_step=1``, the
    serial page chain a mis-pinned deployment would run), the
    *analytic* resolver (hand-set constants), and the *fitted* resolver
    (least-squares weights from the ``bench_calibrate`` sweep; the
    committed ``AUTOTUNE.json`` when present, else an inline refit).
    Asserts the fitted vector ≥1.2x the pinned-worst and no worse than
    the analytic default beyond timer noise.

    **Online adaptation**: a deliberately mismatched draft source (a
    drafter whose proposals never verify — the serving-time analogue of
    a draft model trained on the wrong distribution) served with
    acceptance-adaptive ``spec_k`` must re-rank k downward within a
    bounded number of loop re-traces AND commit byte-identical greedy
    streams to the fixed-k engine — the adapter may only change the
    draft-depth economics, never the tokens.
    """
    from repro.dist.constrain import use_mesh
    from repro.kernels.ops import paged_attention
    from repro.launch.autotune import (WorkloadShape, analytic_estimator,
                                       fit_rows, load_estimator, resolve)

    est_fit = load_estimator("fitted")
    if est_fit.source.startswith("analytic"):
        # no committed artifact/rows on this machine: refit inline from
        # a reduced sweep so the bench still compares a REAL fit
        from .bench_calibrate import sweep
        est_fit = fit_rows(sweep(iters=10))
    shape = WorkloadShape(pages=npages, page_size=page_size, hkv=hkv,
                          batch=batch)
    kv_analytic = resolve(shape, analytic_estimator())
    kv_fitted = resolve(shape, est_fit)

    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.randn(batch, hq, 1, d), jnp.float32)
    kp = jnp.asarray(rs.randn(npages + 1, hkv, page_size, d), jnp.float32)
    vp = jnp.asarray(rs.randn(npages + 1, hkv, page_size, d), jnp.float32)
    bt = jnp.asarray(np.stack([rs.permutation(npages)
                               for _ in range(batch)]), jnp.int32)
    qpos = jnp.asarray(np.full(batch, npages * page_size - 1), jnp.int32)

    arms = [("pinned_worst", (1, 1)),
            ("analytic", (kv_analytic.pages_per_step,
                          kv_analytic.kv_split)),
            ("fitted", (kv_fitted.pages_per_step, kv_fitted.kv_split))]

    def make_step(split, tile):
        def step():
            return paged_attention(q, kp, vp, bt, qpos, backend="xla",
                                   kv_split=split, pages_per_step=tile)
        return step

    # dedupe by knob vector: when two resolvers agree (the common case
    # for analytic vs fitted once the fit is sane) they name the SAME
    # compiled program — timing it twice measures host noise, not the
    # resolvers, and the noise floor here exceeds any real 0% delta
    steps = {knobs: make_step(knobs[1], knobs[0])
             for _, knobs in arms}
    for step in steps.values():
        step().block_until_ready()              # compile (untimed)
    # interleaved best-of: a machine-load burst long enough to span one
    # arm's back-to-back repeats would bias a sequential layout; round-
    # robin repeats make the arms a PAIRED comparison under shared noise
    best = {knobs: float("inf") for knobs in steps}
    for _ in range(5):
        for knobs, step in steps.items():
            t0 = time.perf_counter()
            for _ in range(iters):
                out = step()
            out.block_until_ready()
            best[knobs] = min(best[knobs], time.perf_counter() - t0)

    rows = []
    for name, (tile, split) in arms:
        dt = best[(tile, split)] / iters
        rows.append({"bench": "serving_autotune", "name": name,
                     "kv_split": split, "pages_per_step": tile,
                     "us_per_call": dt * 1e6, "tok_per_s": batch / dt})
    by = {r["name"]: r for r in rows}
    vs_worst = by["fitted"]["tok_per_s"] / by["pinned_worst"]["tok_per_s"]
    vs_analytic = by["fitted"]["tok_per_s"] / by["analytic"]["tok_per_s"]
    by["fitted"]["speedup_vs_pinned_worst"] = vs_worst
    by["fitted"]["speedup_vs_analytic"] = vs_analytic
    by["fitted"]["estimator_source"] = est_fit.source
    # acceptance: the fit must beat a mis-pinned vector decisively and
    # never lose to its own zero-data fallback (0.95 = timer noise on
    # arms that often resolve to the same point)
    assert vs_worst >= 1.2, \
        (f"fitted resolver shows no win over the pinned-worst vector "
         f"({vs_worst:.2f}x at {by['fitted']['kv_split']}/"
         f"{by['fitted']['pages_per_step']})")
    assert vs_analytic >= 0.95, \
        (f"fitted resolver lost to the analytic default "
         f"({vs_analytic:.2f}x) — the fit ranks worse than no data")

    # -- adaptive spec_k: byte-identity + bounded re-jit --------------
    from repro.train.step import LOOP_BUILDS

    cfg, ctx, fam, mesh, params = _serving_setup()
    prompts = {i: np.random.RandomState(100 + i).randint(
        0, cfg.vocab, (12,)).astype(np.int32) for i in range(batch)}

    def mismatched_drafter(eng):
        # proposals the greedy stream (almost) never continues with:
        # acceptance collapses to ~0, the regime where deep drafting is
        # pure waste and the adapter must walk k down.  Verification
        # commits the true greedy token either way, so the stream is
        # untouched by HOW wrong the drafts are.
        def f(hist, tok, pos):
            bad = (tok + 7) % eng.cfg.vocab
            return jnp.broadcast_to(bad, (tok.shape[0], eng.spec_k))
        return f

    outs, stats = {}, {}
    with use_mesh(mesh):
        for name, mode in [("spec_fixed_k", "off"),
                           ("spec_adaptive_k", "analytic")]:
            eng = make_engine(batch=batch,
                              max_len=12 + spec_gen_len + 1,
                              spec=True, spec_k=spec_cap, autotune=mode)
            eng.drafter_fn = mismatched_drafter(eng)
            builds0 = LOOP_BUILDS["spec"]
            eng.add_requests(prompts, gen_len=spec_gen_len)
            t0 = time.perf_counter()
            while eng.live.any():
                eng.step_many(4)
            dt = time.perf_counter() - t0
            outs[name] = [list(eng.outputs[s] or []) for s in range(batch)]
            st = eng.stats()
            stats[name] = st
            rows.append({"bench": "serving_autotune", "name": name,
                         "tok_per_s": batch * spec_gen_len / dt,
                         "spec_k_final": st["spec_k"],
                         "spec_k_rejits": st["spec_k_rejits"],
                         "accepted_per_step": st["accepted_per_step"],
                         "spec_loop_builds": LOOP_BUILDS["spec"] - builds0})
    assert outs["spec_adaptive_k"] == outs["spec_fixed_k"], \
        "adaptive spec_k changed committed tokens"
    ad = stats["spec_adaptive_k"]
    assert ad["spec_k"] < spec_cap and ad["spec_k_rejits"] >= 1, \
        (f"incompressible traffic did not adapt k down "
         f"(k={ad['spec_k']}, rejits={ad['spec_k_rejits']})")
    # bounded re-jit: one build per distinct k the adapter visited
    assert rows[-1]["spec_loop_builds"] <= ad["spec_k_rejits"] + 1, \
        "spec loop rebuilt more often than k changed"
    return rows


#: prompt seeds whose tiled patterns the smoke model continues with
#: strongly repetitive greedy streams — the workload class speculation
#: targets (code/template/extraction-style continuations, where most
#: tokens are predictable from history).  Incompressible streams sit at
#: the other end of the knob: acceptance drops toward 0 and speculation
#: degrades to ~the fused loop (never below one token per round).
_SPEC_SEEDS = (0, 9, 15, 21)


def run_spec(batch=4, pattern_len=6, tiles=3, gen_len=64, k=6,
             block=8, spec_block=4, iters=2):
    """Speculative decode throughput on the repetitive workload.

    Prompts are tiled token patterns (the synthetic stand-in for
    code/template continuations, seeded per ``_SPEC_SEEDS``) so the
    greedy stream keeps revisiting its own n-grams and prompt-lookup
    drafts mostly verify.  Both engines come from ``make_engine`` with
    identical params and differ only in speculation; outputs are
    asserted byte-identical and the speculative engine must reach ≥1.5x
    the fused loop's decode tok/s at equal batch (the PR 2 loop is the
    strong baseline — one jit dispatch per ``block`` tokens — so the
    gain is pure tokens-per-target-pass, not dispatch amortization)."""
    from repro.dist.constrain import use_mesh

    cfg, ctx, fam, mesh, params = _serving_setup()
    if batch > len(_SPEC_SEEDS):
        raise ValueError(
            f"run_spec has {len(_SPEC_SEEDS)} vetted repetitive-stream "
            f"seeds; batch={batch} would silently serve fewer slots "
            f"than reported (vet more seeds in _SPEC_SEEDS to scale)")
    prompts = {i: np.tile(np.random.RandomState(s).randint(
                   0, cfg.vocab, (pattern_len,)), tiles)
               for i, s in enumerate(_SPEC_SEEDS[:batch])}
    prompt_len = pattern_len * tiles
    n_tok = len(prompts) * gen_len
    rows, outs, accepted = [], {}, 0.0
    with use_mesh(mesh):
        for name, kw, blk in [
                ("fused_loop", {}, block),
                ("speculative", dict(spec=True, spec_k=k), spec_block)]:
            eng = make_engine(batch=batch,
                              max_len=prompt_len + gen_len + 1, **kw)
            times = []
            for it in range(iters + 1):        # iteration 0 = jit warmup
                for s in range(batch):
                    if eng.outputs[s] is not None:
                        eng.finish(s)
                eng.add_requests(prompts, gen_len=gen_len)
                t0 = time.perf_counter()
                while eng.live.any():
                    eng.step_many(blk)
                if it > 0:
                    times.append(time.perf_counter() - t0)
            outs[name] = [list(eng.outputs[s] or []) for s in range(batch)]
            # best-of-iters: both engines run the identical deterministic
            # token work per iteration, so min() measures the code path
            # and shrugs off CI scheduling noise that a mean absorbs
            row = {"bench": "serving_spec", "name": name,
                   "tok_per_s": n_tok / min(times),
                   "ms_total": min(times) * 1e3}
            if kw:
                st = eng.stats()
                accepted = st["accepted_per_step"]
                row["accepted_per_step"] = accepted
                row["committed_per_target_pass"] = accepted + 1
            rows.append(row)
    # acceptance: byte-identical greedy streams, ≥1.5x decode tok/s
    assert outs["speculative"] == outs["fused_loop"], \
        "speculative greedy stream diverged from the fused decode loop"
    speedup = rows[1]["tok_per_s"] / rows[0]["tok_per_s"]
    rows[1]["speedup_vs_fused_loop"] = speedup
    assert speedup >= 1.5, \
        (f"speculation shows no decode win on the repetitive workload "
         f"(speedup {speedup:.2f}, accepted/step {accepted:.2f})")
    return rows


def run_preemption(batch=3, page_size=4, num_pages=8, n_requests=6,
                   prompt_len=10, gen_len=6, block=2):
    """Graceful degradation on an over-committed pool (PR 6 smoke).

    A burst of requests whose combined page budget is ~3x the pool.
    The pre-robustness behaviour — direct admission past the free list
    — raises MemoryError; the preempting engine absorbs the same burst
    by time-slicing: victims spill their pages to host memory and
    resume later byte-identically, so every request completes and
    head-of-line wait stays bounded.  Reports the preemption/spill
    counters and the queue-wait (TTFT) tail; asserts no MemoryError,
    all requests served, preemptions actually fired, and p99 queue
    wait bounded by the drain walltime (no starved request)."""
    from repro.dist.constrain import use_mesh
    from repro.launch.lifecycle import RequestStatus

    cfg, ctx, fam, mesh, params = _serving_setup()
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = [src.tokens(i, 1, prompt_len)[0, :-1]
               for i in range(n_requests)]
    kw = dict(batch=batch, max_len=prompt_len + gen_len + 8,
              paged=True, page_size=page_size, num_pages=num_pages)
    with use_mesh(mesh):
        # the seed behaviour this bench exists to contrast: slot-addressed
        # admission onto an exhausted pool has nowhere to degrade to
        seed_eng = make_engine(**kw)
        seed_eng.add_requests({0: prompts[0], 1: prompts[1]},
                              gen_len=gen_len)
        try:
            seed_eng.add_requests({2: prompts[2]}, gen_len=gen_len)
            raise AssertionError(
                "over-committed admission no longer raises without "
                "preemption — the bench contrast is stale")
        except MemoryError:
            pass

        eng = make_engine(preempt=True, preempt_after=2, **kw)
        t0 = time.perf_counter()
        for p in prompts:                  # bursty arrival: all at once
            eng.submit(p, gen_len=gen_len)
        eng.try_admit()
        while eng.live.any() or eng.waiting:
            eng.step_many(block)
        eng.retire_finished()
        wall = time.perf_counter() - t0
    st = eng.stats()
    waits = sorted(r["ttft_s"] for r in eng.request_log)
    p99_wait = waits[min(len(waits) - 1, int(0.99 * len(waits)))]
    assert len(eng.done) == n_requests, "requests lost under preemption"
    assert all(r["status"] is RequestStatus.COMPLETED
               for r in eng.results.values())
    assert st["preemptions"] > 0, "pool pressure never triggered a spill"
    # liveness bound: the worst queue wait cannot exceed the drain —
    # nobody sat starved behind the burst
    assert p99_wait <= wall
    return [{"bench": "serving_preemption", "name": "preempt_and_spill",
             "requests": n_requests, "num_pages": num_pages,
             "preemptions": st["preemptions"],
             "spilled_pages": st["spilled_pages"],
             "p99_queue_wait_ms": p99_wait * 1e3,
             "ms_total": wall * 1e3}]


def run_priority(batch=3, page_size=4, num_pages=8, prompt_len=10,
                 gen_len=6, block=2, n_batch=3, n_standard=3,
                 n_realtime=2):
    """SLO classes on an over-committed mixed burst (PR 9 smoke).

    The ``run_preemption`` pool pressure, but the burst now carries all
    three priority classes — and the REALTIME requests arrive LAST, the
    worst case for a FIFO queue.  The class-ordered queue serves them
    first anyway, and the class floor on victim selection spills BATCH
    pages while every REALTIME request keeps its slots.  (REALTIME
    load alone fits the pool — two requests — so the only preemption
    pressure a REALTIME request could ever feel here comes from lower
    classes, which the victim floor forbids; within-class REALTIME
    spills, which the floor permits, need REALTIME itself to
    over-commit.)

    Asserts: every request completes; REALTIME preemptions stay at
    ZERO while BATCH preemptions fire (degradation lands on the class
    paid to absorb it); REALTIME p99 TTFT beats BATCH p50 despite the
    submission-order handicap, and stays bounded by the drain wall."""
    from repro.dist.constrain import use_mesh
    from repro.launch.lifecycle import PriorityClass, RequestStatus

    cfg, ctx, fam, mesh, params = _serving_setup()
    src = SyntheticLM(cfg.vocab, seed=0)
    n_requests = n_batch + n_standard + n_realtime
    prompts = [src.tokens(i, 1, prompt_len)[0, :-1]
               for i in range(n_requests)]
    # worst-case arrival order for the class that needs latency most
    order = (["batch"] * n_batch + ["standard"] * n_standard
             + ["realtime"] * n_realtime)
    with use_mesh(mesh):
        eng = make_engine(batch=batch, max_len=prompt_len + gen_len + 8,
                          paged=True, page_size=page_size,
                          num_pages=num_pages, preempt=True,
                          preempt_after=2,
                          slo_targets={"realtime": {"ttft_s": 30.0}})
        t0 = time.perf_counter()
        for p, cls in zip(prompts, order):
            eng.submit(p, gen_len=gen_len, priority=cls)
        eng.try_admit()
        while eng.live.any() or eng.waiting:
            eng.step_many(block)
        eng.retire_finished()
        wall = time.perf_counter() - t0
    st = eng.stats()
    cc = eng.class_counters
    assert len(eng.done) == n_requests, "requests lost under priority"
    assert all(r["status"] is RequestStatus.COMPLETED
               for r in eng.results.values())
    assert cc[PriorityClass.BATCH]["preemptions"] > 0, \
        "pool pressure never spilled a BATCH victim"
    # the headline invariant: a REALTIME request is never the victim
    # while a lower class holds pages (victim floor)
    assert cc[PriorityClass.REALTIME]["preemptions"] == 0, \
        "REALTIME was preempted while BATCH victims existed"
    rt = st["classes"]["realtime"]
    bt = st["classes"]["batch"]
    bt_waits = sorted(r["ttft_s"] for r in eng.request_log
                      if r["priority"] == "batch")
    bt_p50 = bt_waits[len(bt_waits) // 2]
    assert rt["ttft_p99_s"] <= wall, "REALTIME TTFT unbounded"
    assert rt["ttft_p99_s"] < bt_p50, \
        (f"REALTIME p99 TTFT {rt['ttft_p99_s']:.3f}s did not beat "
         f"BATCH p50 {bt_p50:.3f}s despite arriving last")
    return [{"bench": "serving_priority", "name": "mixed_class_burst",
             "requests": n_requests, "num_pages": num_pages,
             "realtime_ttft_p99_ms": rt["ttft_p99_s"] * 1e3,
             "batch_ttft_p50_ms": bt_p50 * 1e3,
             "realtime_preemptions": cc[PriorityClass.REALTIME][
                 "preemptions"],
             "batch_preemptions": cc[PriorityClass.BATCH]["preemptions"],
             "shed_rounds": sum(c["shed_rounds"] for c in cc.values()),
             "ms_total": wall * 1e3}]


def run_prefix_cache(n_requests=6, batch=2, pre_len=48, tail_len=4,
                     gen_len=4, page_size=8, chunk=8, block=4):
    """Prefix-cache admission on shared-preamble traffic, warm vs cold.

    Every request carries the same ``pre_len``-token preamble (the
    system-prompt / few-shot-header traffic shape) and a short private
    tail.  The cold engine recomputes the preamble's KV rows for every
    admission; the prefix-cached engine maps the committed pages and
    prefills only the suffix, so hit admissions cost O(new pages) model
    calls.  Both arms run the workload twice — iteration 0 pays jit
    compiles (and, warm, populates the index) untimed; the timed run
    reports per-run counter deltas, so the warm row shows the
    steady-state regime where every admission hits.

    Asserts byte-identical streams, a strict prefill model-call
    reduction, ``prefix_hits``/``prefix_tokens_saved`` covering every
    timed admission, and a mean-TTFT improvement."""
    from repro.dist.constrain import use_mesh

    cfg, ctx, fam, mesh, params = _serving_setup()
    rs = np.random.RandomState(0)
    pre = rs.randint(0, cfg.vocab, (pre_len,))
    prompts = [np.concatenate([pre, rs.randint(0, cfg.vocab, (tail_len,))])
               for _ in range(n_requests)]
    max_len = pre_len + tail_len + gen_len + 4
    rows, outs, calls, ttfts = [], {}, {}, {}
    with use_mesh(mesh):
        for name, kw in [("cold", {}),
                         ("prefix_cache", dict(prefix_cache=True))]:
            eng = make_engine(batch=batch, max_len=max_len, paged=True,
                              page_size=page_size, prefill_chunk=chunk,
                              **kw)
            n_calls = {"n": 0}
            real_prefill = eng.prefill

            def counting(*a, _f=real_prefill, _c=n_calls, **k):
                _c["n"] += 1
                return _f(*a, **k)

            eng.prefill = counting
            for it in range(2):            # iteration 0 = warmup, untimed
                before = dict(eng.counters)
                logged = len(eng.request_log)
                n_calls["n"] = 0
                t0 = time.perf_counter()
                for p in prompts:
                    eng.submit(p, gen_len=gen_len)
                eng.try_admit()
                while eng.live.any() or eng.waiting:
                    eng.step_many(block)
                eng.retire_finished()
                wall = time.perf_counter() - t0
            outs[name] = eng.done[-n_requests:]
            calls[name] = n_calls["n"]
            ttfts[name] = float(np.mean(
                [r["ttft_s"] for r in eng.request_log[logged:]]))
            row = {"bench": "serving_prefix_cache", "name": name,
                   "requests": n_requests, "preamble_tokens": pre_len,
                   "prefill_calls": n_calls["n"],
                   "ttft_mean_ms": ttfts[name] * 1e3,
                   "ms_total": wall * 1e3}
            if kw:
                # per-run deltas: the timed run's counter movement, not
                # the engine-lifetime totals (warmup populated the index)
                for key in ("prefix_hits", "prefix_hit_pages",
                            "prefix_tokens_saved", "cow_copies"):
                    row[key] = eng.counters[key] - before[key]
                row["prefix_index_pages"] = len(eng.prefix_index)
            rows.append(row)
    # acceptance: reuse must be invisible in the streams and visible in
    # the work — fewer prefill model calls, every timed admission a hit
    assert outs["prefix_cache"] == outs["cold"], \
        "prefix-cached streams diverged from the cold engine"
    warm = rows[1]
    assert warm["prefix_hits"] == n_requests, \
        f"expected every steady-state admission to hit ({warm})"
    assert warm["prefix_tokens_saved"] \
        >= n_requests * (pre_len // page_size) * page_size
    assert calls["prefix_cache"] < calls["cold"], \
        "prefix cache did not reduce prefill model calls"
    warm["prefill_calls_saved"] = calls["cold"] - calls["prefix_cache"]
    warm["ttft_speedup_vs_cold"] = ttfts["cold"] / ttfts["prefix_cache"]
    assert warm["ttft_speedup_vs_cold"] > 1.0, \
        (f"suffix-only prefill shows no TTFT win "
         f"(speedup {warm['ttft_speedup_vs_cold']:.2f})")
    return rows


def run_failover(batch=2, page_size=4, num_pages=16, prompt_len=10,
                 gen_len=6, block=2, kill_round=2):
    """Primary kill mid-burst: time-to-promote + per-class TTFT cost.

    A one-replica fleet with a hot standby serves the ``run_priority``
    mixed-class burst twice: once fault-free, once with the primary
    killed at fleet round ``kill_round`` (mid-burst — prefills landed,
    decodes in flight, admission queue non-empty).  The standby tails
    the journal, so promotion finishes the tail replay and resumes
    every stream; the burst drains to completion on the promoted
    engine.

    Asserts zero lost and zero duplicated streams (same request-id
    set, each completed exactly once, token streams byte-identical to
    the fault-free run), exactly one promotion with a measured
    time-to-promote, and REALTIME p99 TTFT inside its SLO target even
    across the failover — BATCH absorbs the degradation.  Reports
    time-to-promote and the per-class TTFT clean→failover movement so
    BENCH_serving.json records what a primary death costs each class."""
    import shutil
    import tempfile

    from repro.dist.constrain import use_mesh
    from repro.ft.serving import FleetFaultInjector
    from repro.launch.fleet import Fleet
    from repro.launch.lifecycle import RequestStatus

    cfg, ctx, fam, mesh, params = _serving_setup()
    src = SyntheticLM(cfg.vocab, seed=0)
    # worst-case arrival order again: the class that needs latency
    # most arrives last AND must survive the primary's death
    order = ["batch"] * 2 + ["standard"] * 2 + ["realtime"] * 2
    prompts = [src.tokens(i, 1, prompt_len)[0, :-1]
               for i in range(len(order))]
    slo_ttft_s = 30.0
    eng_kw = dict(batch=batch, max_len=prompt_len + gen_len + 8,
                  paged=True, page_size=page_size, num_pages=num_pages,
                  slo_targets={"realtime": {"ttft_s": slo_ttft_s}})

    def burst(inj, standby_dir):
        def factory(**over):
            return make_engine(**dict(eng_kw, **over))

        # wide failure-detection thresholds: this bench measures what a
        # promotion COSTS, not whether jitter trips the detector — on a
        # cold CPU, jit-compile spikes read exactly like a straggling
        # replica, and an organic death would poison the fault-free arm
        fl = Fleet(factory, 1, standby_dir=standby_dir,
                   fault_injector=inj, suspect_after=64, dead_after=128,
                   recover_after=1)
        t0 = time.perf_counter()
        for p, cls in zip(prompts, order):
            fl.submit(p, gen_len=gen_len, priority=cls)
        fl.try_admit()
        fl.drain(block=block)
        return fl, time.perf_counter() - t0

    rows = []
    runs = {}
    tmp = tempfile.mkdtemp(prefix="bench_failover_")
    try:
        with use_mesh(mesh):
            burst(None, tempfile.mkdtemp(dir=tmp))   # untimed: compiles
            for name, inj in [
                    ("fault_free", None),
                    ("kill_primary", FleetFaultInjector(
                        [(kill_round, 0, "kill")]))]:
                sdir = tempfile.mkdtemp(dir=tmp)
                fl, wall = burst(inj, sdir)
                runs[name] = fl
                st = fl.replicas[0].stats()   # promotion may have swapped
                row = {"bench": "serving_failover", "name": name,
                       "requests": len(order),
                       "promotions": fl.counters["promotions"],
                       "ms_total": wall * 1e3}
                for cls in ("realtime", "batch"):
                    c = st.get("classes", {}).get(cls, {})
                    if "ttft_p99_s" in c:
                        row[f"{cls}_ttft_p99_ms"] = c["ttft_p99_s"] * 1e3
                if inj is not None:
                    row["time_to_promote_ms"] = \
                        fl.counters["time_to_promote_s"] * 1e3
                rows.append(row)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    clean, faulty = runs["fault_free"], runs["kill_primary"]
    # zero lost, zero duplicated: same id set (dict keys are unique, so
    # presence == exactly once), every stream completed, byte-identical
    assert sorted(faulty.results) == sorted(clean.results), \
        "failover lost or invented streams"
    for fid, res in clean.results.items():
        assert faulty.results[fid]["status"] is RequestStatus.COMPLETED
        assert np.array_equal(faulty.results[fid]["tokens"],
                              res["tokens"]), \
            f"stream {fid} diverged across the failover"
    assert clean.counters["deaths"] == 0 \
        and clean.counters["promotions"] == 0, \
        "the fault-free arm was not fault-free"
    assert faulty.counters["deaths"] == 1
    assert faulty.counters["promotions"] == 1, \
        "the primary kill did not trigger exactly one promotion"
    assert faulty.counters["time_to_promote_s"] is not None
    rt_failover = rows[1].get("realtime_ttft_p99_ms")
    assert rt_failover is not None and rt_failover <= slo_ttft_s * 1e3, \
        (f"REALTIME p99 TTFT {rt_failover:.1f} ms blew its SLO "
         f"across the failover")
    return rows


def run():
    rows = []
    cfg = get_config("gemma-2b").smoke()
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = [jnp.asarray(src.tokens(i, 1, 8)[0, :-1], jnp.int32)
               for i in range(4)]

    ctxs = {
        "fp32": QuantContext(compute_dtype=jnp.float32),
        "fake_fx16_6": QuantContext(
            mode="fake", policy=PrecisionPolicy.uniform(AC_FIXED_16_6),
            compute_dtype=jnp.float32),
        "lut": QuantContext(use_lut=True, compute_dtype=jnp.float32),
    }
    ref = None
    for name, ctx in ctxs.items():
        t0 = time.perf_counter()
        outs = _greedy(cfg, fam, params, ctx, prompts)
        dt = time.perf_counter() - t0
        ntok = sum(len(o) for o in outs)
        row = {"bench": "serving", "name": name,
               "us_per_call": dt / ntok * 1e6,
               "tok_per_s": ntok / dt}
        if ref is None:
            ref = outs
        else:
            agree = np.mean([np.mean(np.asarray(a) == np.asarray(b))
                             for a, b in zip(ref, outs)])
            row["greedy_agreement_vs_fp32"] = float(agree)
        rows.append(row)
    rows.extend(run_prefill())
    rows.extend(run_decode())
    rows.extend(run_paged())
    rows.extend(run_long_context())
    rows.extend(run_autotune())
    rows.extend(run_spec())
    rows.extend(run_preemption())
    rows.extend(run_priority())
    rows.extend(run_prefix_cache())
    rows.extend(run_failover())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
