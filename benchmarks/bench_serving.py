"""The deployment scenario (§I/§V): quantized inference throughput.

Serves the smoke gemma model through the continuous-batching engine under
each numeric mode and reports tokens/s (CPU walltime — relative between
modes) plus greedy-token agreement vs the fp32 reference (accuracy
counterpart of the throughput numbers)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models.api import get_family
from repro.nn.context import QuantContext
from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import AC_FIXED_16_6


def _greedy(cfg, fam, params, ctx, prompts, gen=8):
    outs = []
    for p in prompts:
        cache = fam.init_cache(cfg, 1, p.shape[0] + gen + 1, jnp.float32)
        last, cache = fam.prefill(params, p[None], cache, cfg, ctx)
        toks = []
        pos = jnp.asarray([p.shape[0]], jnp.int32)
        tok = jnp.argmax(last[:, -1], -1)[:, None].astype(jnp.int32)
        for t in range(gen):
            toks.append(int(tok[0, 0]))
            lg, cache = fam.decode_step(params, tok, cache, pos + t, cfg,
                                        ctx)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(toks)
    return outs


def run():
    rows = []
    cfg = get_config("gemma-2b").smoke()
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = [jnp.asarray(src.tokens(i, 1, 8)[0, :-1], jnp.int32)
               for i in range(4)]

    ctxs = {
        "fp32": QuantContext(compute_dtype=jnp.float32),
        "fake_fx16_6": QuantContext(
            mode="fake", policy=PrecisionPolicy.uniform(AC_FIXED_16_6),
            compute_dtype=jnp.float32),
        "lut": QuantContext(use_lut=True, compute_dtype=jnp.float32),
    }
    ref = None
    for name, ctx in ctxs.items():
        t0 = time.perf_counter()
        outs = _greedy(cfg, fam, params, ctx, prompts)
        dt = time.perf_counter() - t0
        ntok = sum(len(o) for o in outs)
        row = {"bench": "serving", "name": name,
               "us_per_call": dt / ntok * 1e6,
               "tok_per_s": ntok / dt}
        if ref is None:
            ref = outs
        else:
            agree = np.mean([np.mean(np.asarray(a) == np.asarray(b))
                             for a, b in zip(ref, outs)])
            row["greedy_agreement_vs_fp32"] = float(agree)
        rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
