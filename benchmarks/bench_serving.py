"""The deployment scenario (§I/§V): quantized inference throughput.

Serves the smoke gemma model through the continuous-batching engine under
each numeric mode and reports tokens/s (CPU walltime — relative between
modes) plus greedy-token agreement vs the fp32 reference (accuracy
counterpart of the throughput numbers).

``run_prefill`` measures prompt ingestion: batched chunked prefill
(O(prompt_len / chunk) full-batch model calls for the whole group) vs the
legacy per-token decode loop (O(prompt_len) calls per slot).

``run_decode`` measures generation: the device-resident fused decode loop
(``step_many``: one jit dispatch and one host sync per block) vs the
per-token baseline (one of each per token), with byte-identical greedy
outputs asserted between the two."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models.api import get_family
from repro.nn.context import QuantContext
from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import AC_FIXED_16_6


def _greedy(cfg, fam, params, ctx, prompts, gen=8):
    outs = []
    for p in prompts:
        cache = fam.init_cache(cfg, 1, p.shape[0] + gen + 1, jnp.float32)
        last, cache = fam.prefill(params, p[None], cache, cfg, ctx)
        toks = []
        pos = jnp.asarray([p.shape[0]], jnp.int32)
        tok = jnp.argmax(last[:, -1], -1)[:, None].astype(jnp.int32)
        for t in range(gen):
            toks.append(int(tok[0, 0]))
            lg, cache = fam.decode_step(params, tok, cache, pos + t, cfg,
                                        ctx)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(toks)
    return outs


def run_prefill(prompt_len=48, batch=4, chunk=8, iters=3):
    """Prompt-ingestion throughput: batched chunked prefill vs the
    per-token decode loop (model calls + prompt tokens/s)."""
    from repro.dist.constrain import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Engine

    cfg = get_config("gemma-2b").smoke()
    ctx = QuantContext(compute_dtype=jnp.float32)
    fam = get_family(cfg)
    mesh = make_local_mesh()
    params = fam.init(jax.random.PRNGKey(0), cfg)
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = {s: src.tokens(s, 1, prompt_len + 1)[0, :-1]
               for s in range(batch)}
    n_tok = batch * prompt_len
    rows = []
    with use_mesh(mesh):
        for name, chunked in [("chunked_prefill", True),
                              ("per_token_loop", False)]:
            # ONE engine per variant: iteration 0 pays the jit compiles
            # (warmup, untimed); later rounds re-admit the same prompts
            # into recycled slots, measuring steady-state ingestion.
            eng = Engine(cfg, ctx, params, mesh, batch=batch,
                         max_len=prompt_len + 8, prefill_chunk=chunk)
            eng.chunked = eng.chunked and chunked
            calls = {"n": 0}

            def count(f):
                def g(*a, **k):
                    calls["n"] += 1
                    return f(*a, **k)
                return g

            eng.prefill = count(eng.prefill)
            eng.decode = count(eng.decode)
            times = []
            for it in range(iters + 1):
                for s in range(batch):
                    if eng.live[s]:
                        eng.finish(s)
                calls["n"] = 0
                t0 = time.perf_counter()
                eng.add_requests(prompts)
                jax.tree_util.tree_leaves(eng.cache)[0].block_until_ready()
                if it > 0:
                    times.append(time.perf_counter() - t0)
            rows.append({"bench": "serving_prefill", "name": name,
                         "model_calls": calls["n"],
                         "prompt_tok_per_s": n_tok / (sum(times)
                                                      / len(times)),
                         "ms_total": sum(times) / len(times) * 1e3})
    return rows


def run_decode(batch=4, prompt_len=16, gen_len=32, block=8, iters=3):
    """Decode throughput: fused multi-token loop vs per-token steps.

    Reports jit dispatches per generated token (the host↔device round
    trips the fused loop amortizes) and tok/s, and asserts the two
    engines emit byte-identical greedy token streams."""
    from repro.dist.constrain import use_mesh
    from repro.launch.mesh import make_local_mesh
    from repro.launch.serve import Engine

    cfg = get_config("gemma-2b").smoke()
    ctx = QuantContext(compute_dtype=jnp.float32)
    fam = get_family(cfg)
    mesh = make_local_mesh()
    params = fam.init(jax.random.PRNGKey(0), cfg)
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = {s: src.tokens(s, 1, prompt_len + 1)[0, :-1]
               for s in range(batch)}
    rows, outs = [], {}
    with use_mesh(mesh):
        for name, blk in [("decode_loop", block), ("per_token", 1)]:
            eng = Engine(cfg, ctx, params, mesh, batch=batch,
                         max_len=prompt_len + gen_len + 1)
            dispatches = {"n": 0}
            real_step_many = eng.step_many

            def counting_step_many(n):
                dispatches["n"] += 1
                return real_step_many(n)

            eng.step_many = counting_step_many
            times = []
            for it in range(iters + 1):        # iteration 0 = jit warmup
                for s in range(batch):
                    if eng.outputs[s] is not None:
                        eng.finish(s)
                eng.add_requests(prompts, gen_len=gen_len)
                dispatches["n"] = 0
                t0 = time.perf_counter()
                while eng.live.any():
                    eng.step_many(blk)
                if it > 0:
                    times.append(time.perf_counter() - t0)
            n_tok = batch * gen_len
            outs[name] = [list(eng.outputs[s] or []) for s in range(batch)]
            rows.append({"bench": "serving_decode", "name": name,
                         "jit_calls_per_token": dispatches["n"] / n_tok,
                         "tok_per_s": n_tok / (sum(times) / len(times)),
                         "ms_total": sum(times) / len(times) * 1e3})
    # acceptance: byte-identical greedy outputs between the two engines
    assert outs["decode_loop"] == outs["per_token"], \
        "fused decode loop diverged from the per-token baseline"
    speedup = (rows[1]["jit_calls_per_token"]
               / rows[0]["jit_calls_per_token"])
    rows[0]["dispatch_reduction_vs_per_token"] = speedup
    return rows


def run():
    rows = []
    cfg = get_config("gemma-2b").smoke()
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    src = SyntheticLM(cfg.vocab, seed=0)
    prompts = [jnp.asarray(src.tokens(i, 1, 8)[0, :-1], jnp.int32)
               for i in range(4)]

    ctxs = {
        "fp32": QuantContext(compute_dtype=jnp.float32),
        "fake_fx16_6": QuantContext(
            mode="fake", policy=PrecisionPolicy.uniform(AC_FIXED_16_6),
            compute_dtype=jnp.float32),
        "lut": QuantContext(use_lut=True, compute_dtype=jnp.float32),
    }
    ref = None
    for name, ctx in ctxs.items():
        t0 = time.perf_counter()
        outs = _greedy(cfg, fam, params, ctx, prompts)
        dt = time.perf_counter() - t0
        ntok = sum(len(o) for o in outs)
        row = {"bench": "serving", "name": name,
               "us_per_call": dt / ntok * 1e6,
               "tok_per_s": ntok / dt}
        if ref is None:
            ref = outs
        else:
            agree = np.mean([np.mean(np.asarray(a) == np.asarray(b))
                             for a, b in zip(ref, outs)])
            row["greedy_agreement_vs_fp32"] = float(agree)
        rows.append(row)
    rows.extend(run_prefill())
    rows.extend(run_decode())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
