"""Tests for the trace-time constant tables (the paper's constexpr claim)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.qtypes import AC_FIXED_18_8, FixedPointType
from repro.core.tables import (COMPUTE_FNS, SoftmaxTablePolicy, TableSpec,
                               get_table, lut_activation, register_compute,
                               softmax_table_policy, table_lookup,
                               table_softmax)


class TestConstexprTables:
    def test_exact_at_knots(self):
        """The constant table holds exactly f(lo + i·step) — the paper's
        equivalence between constexpr evaluation and runtime math."""
        spec = TableSpec("sigmoid", 256, -6.0, 6.0)
        t = get_table(spec)
        knots = spec.lo + spec.step * np.arange(spec.n)
        np.testing.assert_array_equal(
            t.np_values, COMPUTE_FNS["sigmoid"](knots).astype(np.float32))

    def test_values_are_trace_time_constants(self):
        """Building a table never traces jax — it is pure NumPy."""
        spec = TableSpec("exp", 64, -4.0, 0.0)
        t = get_table(spec)
        assert isinstance(t.np_values, np.ndarray)
        assert not t.np_values.flags.writeable  # immutable constant

    def test_cache_identity(self):
        a = get_table(TableSpec("tanh", 128, -4.0, 4.0))
        b = get_table(TableSpec("tanh", 128, -4.0, 4.0))
        assert a is b

    def test_quantized_table_values_representable(self):
        qt = FixedPointType(10, 2)
        t = get_table(TableSpec("sigmoid", 128, -8.0, 8.0, qt))
        lsb = qt.lsb
        assert np.allclose(np.round(t.np_values / lsb) * lsb, t.np_values)

    @settings(max_examples=25, deadline=None)
    @given(st.sampled_from(["sigmoid", "tanh", "silu_gate", "gelu_gate"]),
           st.integers(64, 2048))
    def test_interp_error_shrinks_with_n(self, fn, n):
        """Linear interpolation error is O(step²) for smooth activations."""
        spec = TableSpec(fn, n, -8.0, 8.0, indexing="interp")
        x = jnp.linspace(-7.9, 7.9, 511)
        y = table_lookup(x, jnp.asarray(get_table(spec).np_values),
                         spec.lo, spec.hi, "interp")
        ref = COMPUTE_FNS[fn](np.asarray(x, np.float64))
        err = np.max(np.abs(np.asarray(y) - ref))
        assert err <= 4.0 * (16.0 / n) ** 2  # |f''| ≤ ~1 for these gates

    def test_trunc_matches_hls4ml_indexing(self):
        spec = TableSpec("sigmoid", 16, 0.0, 16.0, indexing="trunc")
        t = get_table(spec)
        y = table_lookup(jnp.asarray([3.99]), jnp.asarray(t.np_values),
                         0.0, 16.0, "trunc")
        assert float(y[0]) == t.np_values[3]  # floor, not round

    def test_gated_form_asymptotics(self):
        """gated silu/gelu stay exact for |x| >> table domain — the
        de-specialized improvement over tabulating f directly."""
        x = jnp.asarray([50.0, 100.0, -100.0])
        y = lut_activation(x, "gelu", gated=True)
        np.testing.assert_allclose(np.asarray(y), [50.0, 100.0, 0.0],
                                   atol=1e-3)
        # faithful direct tabulation saturates (documented hls4ml behavior)
        y2 = lut_activation(x, "gelu", gated=False)
        assert float(y2[1]) < 9.0


class TestSoftmax:
    def test_softmax_table_override(self):
        """Paper §III: softmax silently overrides the user type with
        1024×18-bit tables; respect_user_type disables the override."""
        user = FixedPointType(8, 3)
        p = softmax_table_policy(user)
        assert p.qtype == AC_FIXED_18_8 and p.n == 1024
        p2 = softmax_table_policy(user, respect_user_type=True)
        assert p2.qtype == user

    def test_lut_softmax_close_to_exact(self):
        x = jnp.asarray(np.random.RandomState(0).randn(8, 64) * 3)
        # default policy: the paper's 1024-entry 18-bit fixed-point table
        y = table_softmax(x, policy=SoftmaxTablePolicy(indexing="interp"))
        ref = jax.nn.softmax(x, axis=-1)
        assert float(jnp.abs(y - ref).max()) < 5e-3
        # float-valued table + interpolation is comparable (the residual
        # error is the max-shifted exp-table discretization, not the
        # 18-bit value quantization — measured in bench_lut_tables)
        y2 = table_softmax(x, policy=SoftmaxTablePolicy(qtype=None,
                                                        indexing="interp"))
        assert float(jnp.abs(y2 - ref).max()) < 5e-3
        np.testing.assert_allclose(np.asarray(jnp.sum(y, -1)), 1.0,
                                   rtol=1e-5)

    def test_faithful_invert_table_softmax_degrades_on_long_rows(self):
        """The hls4ml invert table saturates at inv_hi — quantifying the
        drawback the paper's §III analysis identifies."""
        x = jnp.zeros((1, 512))  # row sum of exps = 512 > inv_hi (64)
        y_faithful = table_softmax(
            x, policy=SoftmaxTablePolicy(exact_divide=False))
        y_fixed = table_softmax(
            x, policy=SoftmaxTablePolicy(exact_divide=True))
        err_f = float(jnp.abs(jnp.sum(y_faithful, -1) - 1.0).max())
        err_x = float(jnp.abs(jnp.sum(y_fixed, -1) - 1.0).max())
        assert err_f > 0.5           # saturated invert table: badly off
        assert err_x < 1e-3          # exact divide: fine

    def test_custom_compute_registration(self):
        @register_compute("_test_square")
        def _sq(x):
            return x * x

        t = get_table(TableSpec("_test_square", 32, 0.0, 4.0))
        assert t.np_values[8] == pytest.approx(1.0)  # f(0 + 8*0.125) = 1
