"""Unified serving autotuner conformance.

The contract this suite pins, layer by layer:

* **Resolver** — deterministic whole-knob-vector resolution per
  workload shape, and the analytic estimator must reproduce the legacy
  hand-set path (``auto_pages_per_step`` + ``choose_kv_split``)
  *exactly*, candidate grid, occupancy boundary, tie-breaks and all:
  ``--autotune analytic`` is a refactor of the default, not a new
  policy.
* **Fit** — the least-squares estimator round-trips synthetic training
  rows generated from known weights, survives the JSON artifact cycle,
  and degrades to the analytic weights when there is no data.
* **Adapter** — acceptance-adaptive ``spec_k`` re-ranks from telemetry
  with hysteresis and cooldown; proposals stay inside
  ``[k_min, k_max]``.
* **Engine** — ``--autotune off`` streams are byte-identical to
  ``analytic``/``fitted`` streams (knobs may change latency, never
  tokens), adaptive ``spec_k`` never changes committed greedy tokens,
  and the fused spec loop re-traces at most once per distinct k
  (``train.step.LOOP_BUILDS`` counts actual traces).
"""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.constrain import use_mesh
from repro.kernels.flash_attention import (auto_pages_per_step,
                                           choose_kv_split,
                                           get_cost_constants,
                                           set_cost_constants)
from repro.launch import autotune
from repro.launch.autotune import (FEATURES, KnobVector, LatencyEstimator,
                                   SpecKAdapter, WorkloadShape,
                                   analytic_estimator, feature_vector,
                                   fit_rows, kv_candidates, load_artifact,
                                   load_estimator, rank_spec_k, resolve,
                                   save_artifact)
from repro.launch.serve import Engine

from test_paged_serving import _prompts, _setup


# ===========================================================================
class TestResolverConformance:
    # shape grid spanning the legacy selector's regimes: single-tile,
    # mid, long-chain; lanes from starved to past the occupancy target
    GRID = list(itertools.product((1, 3, 8, 16, 64, 200, 512),   # pages
                                  (4, 8, 16, 32),                # page_size
                                  (1, 2, 8),                     # hkv
                                  (1, 4, 64, 511, 512)))         # batch

    def test_analytic_resolver_equals_legacy_selector(self):
        """The tentpole invariant: resolving with the analytic
        estimator reproduces the hand-set default for EVERY shape —
        same tile, same split, ties and occupancy boundary included."""
        est = analytic_estimator()
        for pages, ps, hkv, batch in self.GRID:
            t_legacy = auto_pages_per_step(ps, pages)
            s_legacy = choose_kv_split(pages * ps, pages, hkv, batch=batch,
                                       pages_per_step=t_legacy)
            kv = resolve(WorkloadShape(pages=pages, page_size=ps, hkv=hkv,
                                       batch=batch), est)
            assert (kv.pages_per_step, kv.kv_split) == \
                (t_legacy, s_legacy), \
                (f"shape p{pages}/ps{ps}/h{hkv}/b{batch}: resolver "
                 f"({kv.pages_per_step},{kv.kv_split}) != legacy "
                 f"({t_legacy},{s_legacy})")

    def test_resolution_is_deterministic(self):
        shape = WorkloadShape(pages=64, page_size=8, hkv=1, batch=4)
        assert resolve(shape) == resolve(shape)

    def test_pinned_vectors(self):
        """Exact resolved vectors for canonical shapes — any drift in
        grids, constants, or tie-breaks shows up as a diff here."""
        long_ctx = resolve(WorkloadShape(pages=64, page_size=8, hkv=1,
                                         batch=4))
        assert long_ctx == KnobVector(kv_split=4, pages_per_step=16,
                                      decode_block=32, spec_k=4)
        dense = resolve(WorkloadShape(pages=0, page_size=8, hkv=1,
                                      batch=4))
        assert (dense.kv_split, dense.pages_per_step) == (1, 1)

    def test_decode_block_capped_by_gen_len(self):
        short = resolve(WorkloadShape(pages=0, page_size=8, hkv=1,
                                      batch=1, gen_len=1))
        assert short.decode_block == 1
        long = resolve(WorkloadShape(pages=0, page_size=8, hkv=1,
                                     batch=1, gen_len=64))
        assert long.decode_block in autotune.DECODE_BLOCKS

    def test_candidate_grid_includes_boundary_split(self):
        """lanes == target: the first saturated split must still be a
        candidate (the off-by-one the guard fix closed)."""
        cands = kv_candidates(WorkloadShape(pages=64, page_size=8,
                                            hkv=1, batch=512))
        assert (16, 2) in cands                  # boundary candidate
        assert (16, 4) not in cands              # deeper: pruned

    def test_default_spec_k_matches_historical_default(self):
        assert rank_spec_k(autotune._ACCEPT_PRIOR, 8) == 4

    def test_rank_spec_k_extremes(self):
        assert rank_spec_k(0.0, 8) == 1          # nothing verifies
        assert rank_spec_k(0.999, 8) == 8        # everything verifies


# ===========================================================================
class TestFittedEstimator:
    #: diverse synthetic corpus: every (shape, knob) point the resolver
    #: could visit on these shapes
    SHAPES = (WorkloadShape(pages=64, page_size=8, hkv=1, batch=4),
              WorkloadShape(pages=32, page_size=8, hkv=2, batch=2),
              WorkloadShape(pages=16, page_size=16, hkv=1, batch=8))

    def _rows(self, weights):
        rows = []
        for s in self.SHAPES:
            for t, split in kv_candidates(s):
                f = feature_vector(s.pages, s.page_size, s.hkv, s.batch,
                                   split, t)
                rows.append({"pages": s.pages, "page_size": s.page_size,
                             "hkv": s.hkv, "batch": s.batch,
                             "kv_split": split, "pages_per_step": t,
                             "us_per_call": float(f @ np.asarray(weights))})
        return rows

    def test_fit_round_trips_training_rows(self):
        """Rows generated from known nonnegative weights: the fit must
        reproduce every training latency (exact linear system)."""
        w_true = (4.0, 0.05, 1.5, 0.2, 10.0, 2.0)
        rows = self._rows(w_true)
        est = fit_rows(rows)
        assert est.source == "fit" and est.n_rows == len(rows)
        assert est.residual < 1e-9
        for r in rows:
            pred = est.predict(r["pages"], r["page_size"], r["hkv"],
                               r["batch"], r["kv_split"],
                               r["pages_per_step"])
            assert pred == pytest.approx(r["us_per_call"], rel=1e-6)
        c = est.cost_constants()
        assert c["tile_cost"] > 0 and c["combine_cost"] > 0

    def test_fit_weights_are_nonnegative(self):
        # corrupt one shape's rows so unconstrained lstsq would go
        # negative somewhere; the constrained fit must not
        rows = self._rows((4.0, 0.05, 1.5, 0.2, 10.0, 2.0))
        for r in rows[: len(rows) // 3]:
            r["us_per_call"] *= 5.0
        est = fit_rows(rows)
        assert all(w >= 0.0 for w in est.weights)

    def test_fit_requires_enough_rows(self):
        rows = self._rows((4.0, 0.05, 1.5, 0.2, 10.0, 2.0))
        with pytest.raises(ValueError):
            fit_rows(rows[: len(FEATURES) - 1])

    def test_artifact_round_trip(self, tmp_path):
        est = fit_rows(self._rows((4.0, 0.05, 1.5, 0.2, 10.0, 2.0)))
        p = save_artifact(est, path=tmp_path / "AUTOTUNE.json")
        back = load_artifact(path=p)
        assert back.source == "artifact"
        assert back.weights == pytest.approx(est.weights)
        # the artifact is the estimator fitted mode loads
        via_mode = load_estimator("fitted", path=p)
        assert via_mode.weights == pytest.approx(est.weights)

    def test_artifact_rejects_stale_feature_basis(self, tmp_path):
        est = analytic_estimator()
        p = save_artifact(est, path=tmp_path / "AUTOTUNE.json")
        import json
        d = json.loads(p.read_text())
        d["features"] = ["chain", "other"]
        p.write_text(json.dumps(d))
        with pytest.raises(ValueError):
            load_artifact(path=p)

    def test_fitted_mode_falls_back_to_analytic(self, tmp_path,
                                                monkeypatch):
        """No artifact, no calibration rows: fitted mode must still
        construct — analytic weights, provenance in ``source``."""
        monkeypatch.setattr(autotune, "_REPO_ROOT", tmp_path)
        est = load_estimator("fitted", path=tmp_path / "missing.json")
        assert est.source == "analytic-fallback"
        assert est.weights == analytic_estimator().weights

    def test_analytic_weights_project_back_to_constants(self):
        c = analytic_estimator().cost_constants()
        assert c["tile_cost"] == pytest.approx(
            get_cost_constants()["tile_cost"])
        assert c["combine_cost"] == pytest.approx(
            get_cost_constants()["combine_cost"])


# ===========================================================================
class TestCostConstants:
    def test_install_and_reset(self):
        """Fitted constants rewire the legacy selector; installing the
        analytic estimator restores the defaults byte-for-byte."""
        base = get_cost_constants()
        try:
            # a fit where combining is prohibitively expensive must pin
            # the legacy selector to split=1 on a long chain
            est = LatencyEstimator(weights=(1.0, 0.0, 1e9, 0.0, 0.0, 0.0),
                                   source="fit")
            autotune.install(est)
            assert choose_kv_split(512 * 8, 512, 1, batch=1,
                                   pages_per_step=8) == 1
        finally:
            autotune.install(analytic_estimator())
        assert get_cost_constants() == base
        assert choose_kv_split(512 * 8, 512, 1, batch=1,
                               pages_per_step=8) > 1

    def test_set_cost_constants_clears_decision_cache(self):
        base = get_cost_constants()
        try:
            before = choose_kv_split(512 * 8, 512, 1, batch=1,
                                     pages_per_step=8)
            set_cost_constants(combine_cost=1e9)
            after = choose_kv_split(512 * 8, 512, 1, batch=1,
                                    pages_per_step=8)
            assert before > 1 and after == 1
        finally:
            set_cost_constants()
        assert get_cost_constants() == base


# ===========================================================================
class TestSpecKAdapter:
    def test_no_data_keeps_current_k(self):
        ad = SpecKAdapter(k_init=4)
        assert ad.propose() == 4 and ad.switches == 0

    def test_low_acceptance_walks_k_down(self):
        ad = SpecKAdapter(k_init=4, min_rounds=4, cooldown=1)
        ad.observe(rounds=8, accepted=0)
        assert ad.propose() == 1
        assert ad.switches == 1

    def test_acceptance_inversion_round_trips(self):
        p = 0.5
        k = 4
        a_bar = sum(p ** i for i in range(1, k + 1))
        ad = SpecKAdapter(k_init=k, min_rounds=4, cooldown=1)
        ad.observe(rounds=100, accepted=int(round(a_bar * 100)))
        assert ad.acceptance() == pytest.approx(p, abs=0.02)

    def test_hysteresis_blocks_marginal_switch(self):
        """At the default prior the best k's score is within the
        hysteresis band of neighbouring ks — the adapter must hold."""
        ad = SpecKAdapter(k_init=4, min_rounds=4, cooldown=1)
        p = autotune._ACCEPT_PRIOR
        a_bar = sum(p ** i for i in range(1, 5))
        ad.observe(rounds=1000, accepted=int(round(a_bar * 1000)))
        assert ad.propose() == 4 and ad.switches == 0

    def test_cooldown_limits_switch_rate(self):
        ad = SpecKAdapter(k_init=4, min_rounds=1, cooldown=3)
        ad.observe(rounds=8, accepted=0)
        assert ad.propose() == 1                 # first switch is free
        # fresh telemetry immediately after the switch: held by cooldown
        ad.observe(rounds=8, accepted=8)
        assert ad.propose() == 1
        ad.observe(rounds=8, accepted=8)
        assert ad.propose() == 1
        ad.observe(rounds=8, accepted=8)
        assert ad.propose() > 1                  # cooldown elapsed

    def test_proposals_bounded_by_k_max(self):
        ad = SpecKAdapter(k_init=2, k_max=3, min_rounds=1, cooldown=1)
        ad.observe(rounds=50, accepted=100)      # sky-high acceptance
        assert ad.propose() <= 3

    def test_window_forgets_stale_telemetry(self):
        ad = SpecKAdapter(k_init=4, window=16, min_rounds=4, cooldown=1)
        ad.observe(rounds=16, accepted=0)        # cold epoch
        for _ in range(4):                       # hot epoch fills window
            ad.observe(rounds=8, accepted=30)
        assert ad.acceptance() > 0.5


# ===========================================================================
class TestEngineAutotune:
    def _streams(self, eng, prompts, gen_len=8, block=4):
        eng.add_requests(prompts, gen_len=gen_len)
        while eng.live.any():
            eng.step_many(block)
        return [list(eng.outputs[s] or []) for s in range(len(prompts))]

    def test_invalid_mode_rejected(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with pytest.raises(ValueError):
            Engine(cfg, ctx, params, mesh, batch=2, max_len=16,
                   autotune="learned")

    def test_off_streams_byte_identical_to_resolved(self):
        """The acceptance bar for every mode: knob resolution may move
        latency, never tokens."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = {i: p for i, p in enumerate(_prompts(cfg, (6, 5)))}
        outs = {}
        with use_mesh(mesh):
            for mode in ("off", "analytic", "fitted"):
                eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                             paged=True, page_size=4, autotune=mode)
                outs[mode] = self._streams(eng, prompts)
        assert outs["analytic"] == outs["off"]
        assert outs["fitted"] == outs["off"]

    def test_resolved_knobs_reported_in_stats(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                         paged=True, page_size=4, autotune="analytic")
            self._streams(eng, {0: _prompts(cfg, (6,))[0]})
            st = eng.stats()
        assert st["autotune"] == "analytic"
        assert st["autotune_source"] == "analytic"
        # grid value, capped by the engine's token budget (max_len)
        assert 1 <= st["decode_block"] <= 24
        assert st["kv_split"] >= 1 and st["pages_per_step"] >= 1

    def test_adaptive_spec_k_stream_invariant_and_bounded_rejit(self):
        """Mismatched drafts collapse acceptance to ~0: the adapter
        must walk k down, the greedy stream must not move by a byte,
        and the fused loop re-traces exactly once per distinct k."""
        from repro.train.step import LOOP_BUILDS

        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = {i: p for i, p in enumerate(_prompts(cfg, (6, 5)))}

        def mismatched(eng):
            def f(hist, tok, pos):
                bad = (tok + 7) % eng.cfg.vocab
                return jnp.broadcast_to(bad, (tok.shape[0], eng.spec_k))
            return f

        outs, stats = {}, {}
        with use_mesh(mesh):
            for mode in ("off", "analytic"):
                eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=32,
                             spec=True, spec_k=4, autotune=mode)
                eng.drafter_fn = mismatched(eng)
                if eng._spec_adapter is not None:
                    # fast-adapting variant: same policy, test-sized
                    # window so adaptation happens within a short run
                    eng._spec_adapter = SpecKAdapter(k_init=4, k_max=4,
                                                     min_rounds=4,
                                                     cooldown=1)
                builds0 = LOOP_BUILDS["spec"]
                outs[mode] = self._streams(eng, prompts, gen_len=16)
                stats[mode] = (eng.stats(), LOOP_BUILDS["spec"] - builds0)
        assert outs["analytic"] == outs["off"], \
            "adaptive spec_k changed committed greedy tokens"
        st, builds = stats["analytic"]
        assert st["spec_k"] < 4 and st["spec_k_rejits"] >= 1
        # one trace per distinct k (cap + each adapted k), none wasted
        assert builds <= st["spec_k_rejits"] + 1

    def test_adaptive_spec_k_holds_on_verifying_drafts(self):
        """High acceptance at the cap: nothing to gain below k_max, so
        the adapter must not thrash (no re-jits, k stays put)."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        # tiled pattern prompt: greedy continuation revisits its own
        # n-grams, prompt-lookup drafts verify at a high rate
        pat = np.tile(np.random.RandomState(3).randint(
            0, cfg.vocab, (5,)), 4).astype(np.int32)
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=1, max_len=40,
                         spec=True, spec_k=4, autotune="analytic")
            self._streams(eng, {0: pat}, gen_len=16)
            st = eng.stats()
        assert st["spec_k"] == 4
        assert st["spec_k_rejits"] == 0

    def test_dense_engine_resolves_decode_block_only(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                         autotune="analytic")
        assert 1 <= eng.decode_block <= 24
        # dense cache: the kv knobs stay unset — nothing to split
        assert eng.kv_split is None and eng.pages_per_step is None
