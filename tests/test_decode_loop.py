"""Device-resident decode loop: prefill/decode equivalence suite.

The fused multi-token loop (``Engine.step_many`` over
``build_decode_loop``'s single ``lax.scan``) must be *token-for-token*
equivalent to the per-token baseline (``Engine.step``) — same model step
order, same sampling stream, same stopping decisions — for every family
that serves (lm, ssm, hybrid), under f32 and pre-quantized int8 weights,
including slots that finish mid-block and slots recycled onto a new
request.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import FixedPointType
from repro.dist.constrain import use_mesh
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Engine, quantize_for_serving
from repro.models.api import get_family
from repro.nn.context import QuantContext

ARCHS = {"lm": "gemma-2b", "ssm": "mamba2-370m", "hybrid": "zamba2-1.2b"}
_CACHE = {}


def _setup(family: str, quant: str):
    """(cfg, ctx, params, mesh) per (family, quant) — built once."""
    key = (family, quant)
    if key not in _CACHE:
        cfg = get_config(ARCHS[family]).smoke()
        if quant == "int8":
            ctx = QuantContext(mode="int8",
                               policy=PrecisionPolicy.uniform(
                                   FixedPointType(8, 4)),
                               compute_dtype=jnp.float32)
        else:
            ctx = QuantContext(compute_dtype=jnp.float32)
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        if quant == "int8":
            params = quantize_for_serving(params, ctx)
        _CACHE[key] = (cfg, ctx, params, make_local_mesh())
    return _CACHE[key]


def _prompts(cfg, seed=0):
    rs = np.random.RandomState(seed)
    return {0: rs.randint(0, cfg.vocab, (9,)),
            1: rs.randint(0, cfg.vocab, (5,))}


def _engine(setup, **kw):
    cfg, ctx, params, mesh = setup
    kw.setdefault("batch", 2)
    kw.setdefault("max_len", 32)
    return Engine(cfg, ctx, params, mesh, **kw)


# ===========================================================================
class TestStepManyEquivalence:
    """step_many(n) == n * step(), token for token, state for state."""

    @pytest.mark.parametrize("family,quant", [
        ("lm", "f32"),
        pytest.param("lm", "int8", marks=pytest.mark.slow),
        pytest.param("ssm", "f32", marks=pytest.mark.slow),
        pytest.param("ssm", "int8", marks=pytest.mark.slow),
        pytest.param("hybrid", "f32", marks=pytest.mark.slow),
        pytest.param("hybrid", "int8", marks=pytest.mark.slow),
    ])
    def test_block_matches_per_token(self, family, quant):
        setup = _setup(family, quant)
        prompts = _prompts(setup[0])
        with use_mesh(setup[3]):
            per_tok = _engine(setup)
            per_tok.add_requests(prompts, gen_len=8)
            for _ in range(8):
                per_tok.step()

            # split into two blocks: also checks PRNG/stop bookkeeping
            # is invariant to how a generation is cut into blocks
            fused = _engine(setup)
            fused.add_requests(prompts, gen_len=8)
            fused.step_many(3)
            fused.step_many(5)

        assert fused.outputs == per_tok.outputs
        np.testing.assert_array_equal(fused.tokens, per_tok.tokens)
        np.testing.assert_array_equal(fused.pos, per_tok.pos)
        np.testing.assert_array_equal(fused.live, per_tok.live)

    def test_sampled_equivalence_across_blocks(self):
        """Temperature/top-k sampling consumes the same PRNG stream in
        one fused block as in n single steps (fold_in by global step)."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=1)
        with use_mesh(setup[3]):
            a = _engine(setup, seed=7)
            a.add_requests(prompts, gen_len=10,
                           temperature={0: 0.8, 1: 1.3}, top_k={0: 5, 1: 0})
            for _ in range(10):
                a.step()

            b = _engine(setup, seed=7)
            b.add_requests(prompts, gen_len=10,
                           temperature={0: 0.8, 1: 1.3}, top_k={0: 5, 1: 0})
            b.step_many(10)
        assert a.outputs == b.outputs
        assert a.outputs[0] != a.outputs[1]


# ===========================================================================
class TestStoppingAndRecycling:
    def test_slot_finishes_mid_block(self):
        """A slot whose budget ends inside a block stops emitting at
        exactly the same token as under per-token stepping."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=2)
        with use_mesh(setup[3]):
            fused = _engine(setup)
            fused.add_requests({0: prompts[0]}, gen_len=3)
            fused.add_requests({1: prompts[1]}, gen_len=10)
            fused.step_many(6)

            per_tok = _engine(setup)
            per_tok.add_requests({0: prompts[0]}, gen_len=3)
            per_tok.add_requests({1: prompts[1]}, gen_len=10)
            for _ in range(6):
                per_tok.step()

        assert len(fused.outputs[0]) == 3 and not fused.live[0]
        assert len(fused.outputs[1]) == 6 and fused.live[1]
        assert fused.outputs == per_tok.outputs
        np.testing.assert_array_equal(fused.pos, per_tok.pos)

    def test_eos_kills_slot_on_device(self):
        """Sampling the EOS id stops the slot inside the block; the EOS
        token itself is not emitted."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=3)
        with use_mesh(setup[3]):
            probe = _engine(setup)
            probe.add_requests({0: prompts[0]}, gen_len=8)
            probe.step_many(8)
            stream = probe.outputs[0]
            # eos must not occur before its first appearance: pick the
            # first token that is fresh in the greedy stream
            cut = next((i for i in range(1, len(stream))
                        if stream[i] not in stream[:i]), None)
            if cut is None:             # fully periodic stream: improbable
                pytest.skip("greedy stream has no fresh token to use as eos")
            eos = stream[cut]

            eng = _engine(setup, eos_id=eos)
            eng.add_requests({0: prompts[0]}, gen_len=8)
            eng.step_many(8)
        assert eng.outputs[0] == stream[:cut]
        assert not eng.live[0]

    @pytest.mark.parametrize("family", [
        "lm",
        pytest.param("ssm", marks=pytest.mark.slow),
        pytest.param("hybrid", marks=pytest.mark.slow),
    ])
    def test_recycled_slot_ignores_previous_occupant(self, family):
        """After finish(), a slot admitted to a new request generates
        exactly what a fresh engine would: its predecessor's KV rows /
        recurrent state are invalidated.  And the refill's prefill must
        not disturb the neighbouring live slot — on recurrent families
        the per-token prefill advances every lane, so slot isolation
        relies on the merge_slot restore."""
        setup = _setup(family, "f32")
        cfg = setup[0]
        rs = np.random.RandomState(4)
        p_old, p_live, p_new = (rs.randint(0, cfg.vocab, (n,))
                                for n in (7, 6, 8))
        with use_mesh(setup[3]):
            eng = _engine(setup)
            eng.add_requests({0: p_old, 1: p_live}, gen_len=12)
            eng.step_many(4)
            eng.finish(0)                       # retire mid-generation
            eng.add_requests({0: p_new}, gen_len=6)
            eng.step_many(6)

            solo = _engine(setup)
            solo.add_requests({0: p_new}, gen_len=6)
            solo.step_many(6)

            # reference for the LIVE neighbour: same admissions, same
            # steps, but no retire/refill in between
            undisturbed = _engine(setup)
            undisturbed.add_requests({0: p_old, 1: p_live}, gen_len=12)
            undisturbed.step_many(4)
            undisturbed.step_many(6)
        assert eng.outputs[0] == solo.outputs[0]
        assert eng.outputs[1] == undisturbed.outputs[1]

    @pytest.mark.parametrize("family", [
        "lm",
        pytest.param("ssm", marks=pytest.mark.slow),
    ])
    def test_deferred_refill_starts_clean(self, family):
        """A slot that idles for whole blocks between finish() and its
        refill must still prefill from clean state: decode advances
        dead lanes too (the held pad token drives recurrent state), so
        admission re-zeroes the lane."""
        setup = _setup(family, "f32")
        cfg = setup[0]
        rs = np.random.RandomState(9)
        p_old, p_live, p_new = (rs.randint(0, cfg.vocab, (n,))
                                for n in (6, 5, 7))
        with use_mesh(setup[3]):
            eng = _engine(setup)
            eng.add_requests({0: p_old, 1: p_live}, gen_len=14)
            eng.step_many(3)
            eng.finish(0)
            eng.step_many(5)            # slot 0 idles while 1 generates
            eng.add_requests({0: p_new}, gen_len=6)
            eng.step_many(6)

            solo = _engine(setup)
            solo.add_requests({0: p_new}, gen_len=6)
            solo.step_many(6)
        assert eng.outputs[0] == solo.outputs[0]

    def test_oversized_gen_len_clamps_to_cache_budget(self):
        """A gen budget beyond max_len must stop at the cache bound
        instead of keeping the slot live while writes clamp into the
        last KV row."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        prompt = np.random.RandomState(8).randint(0, cfg.vocab, (10,))
        with use_mesh(setup[3]):
            eng = _engine(setup, max_len=16)
            eng.add_requests({0: prompt}, gen_len=50)
            eng.step_many(12)
        assert not eng.live[0]
        assert eng.pos[0] == 16                 # stopped AT the bound
        assert len(eng.outputs[0]) == 6         # 16 - prompt_len

    def test_dead_slots_do_not_emit(self):
        """Slots never admitted stay silent through a block."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=5)
        with use_mesh(setup[3]):
            eng = _engine(setup)
            eng.add_requests({0: prompts[0]}, gen_len=5)
            block, block_live = eng.step_many(8)
        assert block.shape == (8, 2) and block_live.shape == (8, 2)
        assert not block_live[:, 1].any()
        assert eng.outputs[1] is None
        assert block_live[:, 0].sum() == 5      # budget, then silence


# ===========================================================================
class TestLoopStructure:
    def test_one_jit_dispatch_per_block(self):
        """The whole block is ONE compiled call: the loop function is
        entered once, and the per-step decode jit is never used."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=6)
        with use_mesh(setup[3]):
            eng = _engine(setup)
            eng.add_requests(prompts, gen_len=8)
            calls = {"decode": 0}
            real_decode = eng.decode

            def counting_decode(*a, **k):
                calls["decode"] += 1
                return real_decode(*a, **k)

            eng.decode = counting_decode
            eng.step_many(8)
        assert calls["decode"] == 0
        assert set(eng._loops) == {8}

    def test_block_tokens_match_outputs(self):
        """The (N, B) block returned by step_many is exactly what lands
        in the per-slot output streams."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=7)
        with use_mesh(setup[3]):
            eng = _engine(setup)
            eng.add_requests(prompts, gen_len=6)
            block, block_live = eng.step_many(6)
        for s in (0, 1):
            assert eng.outputs[s] == [int(t) for t in
                                      block[block_live[:, s], s]]
