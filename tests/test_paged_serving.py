"""Serving conformance suite: paged engine ≡ dense engine, byte for byte.

The paged KV cache (shared page pool + block tables + free-list
allocator + admission queue) must be *observationally invisible*: for
the same submitted requests, the paged engine emits exactly the token
streams the dense engine does — for every serving family (lm KV pages,
hybrid pages-KV-only, ssm no-KV) under f32 and pre-quantized int8
weights, including requests admitted mid-stream onto freshly recycled
pages and prompts whose pages are physically non-contiguous.

Plus the allocator's own invariants (hypothesis-stub sweeps) and the
``add_requests`` long-prompt rejection fix.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import FixedPointType
from repro.dist.constrain import use_mesh
from repro.launch.mesh import make_local_mesh
from repro.launch.paging import PageAllocator
from repro.launch.serve import Engine, quantize_for_serving
from repro.models.api import get_family
from repro.nn.context import QuantContext

ARCHS = {"lm": "gemma-2b", "ssm": "mamba2-370m", "hybrid": "zamba2-1.2b"}
_CACHE = {}


def _setup(family: str, quant: str):
    """(cfg, ctx, params, mesh) per (family, quant) — built once."""
    key = (family, quant)
    if key not in _CACHE:
        cfg = get_config(ARCHS[family]).smoke()
        if quant == "int8":
            ctx = QuantContext(mode="int8",
                               policy=PrecisionPolicy.uniform(
                                   FixedPointType(8, 4)),
                               compute_dtype=jnp.float32)
        else:
            ctx = QuantContext(compute_dtype=jnp.float32)
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        if quant == "int8":
            params = quantize_for_serving(params, ctx)
        _CACHE[key] = (cfg, ctx, params, make_local_mesh())
    return _CACHE[key]


def _serve(setup, prompts, *, gen_len=6, block=4, batch=2, max_len=32,
           **kw):
    """Submit everything, run blocks to drain, return the done streams.

    ``step_many`` performs the continuous-batching admission: finished
    slots retire and queued requests take their lanes/pages one block
    after they free up."""
    cfg, ctx, params, mesh = setup
    with use_mesh(mesh):
        eng = Engine(cfg, ctx, params, mesh, batch=batch, max_len=max_len,
                     **kw)
        for p in prompts:
            eng.submit(p, gen_len=gen_len)
        eng.try_admit()
        while eng.live.any() or eng.waiting:
            eng.step_many(block)
        eng.retire_finished()
    return eng


def _prompts(cfg, lens, seed=0):
    rs = np.random.RandomState(seed)
    return [rs.randint(0, cfg.vocab, (n,)) for n in lens]


# ===========================================================================
class TestPagedDenseConformance:
    """Byte-identical greedy streams, all families × weight precisions."""

    @pytest.mark.parametrize("family,quant", [
        ("lm", "f32"),
        ("ssm", "f32"),
        pytest.param("lm", "int8", marks=pytest.mark.slow),
        pytest.param("ssm", "int8", marks=pytest.mark.slow),
        pytest.param("hybrid", "f32", marks=pytest.mark.slow),
        pytest.param("hybrid", "int8", marks=pytest.mark.slow),
    ])
    def test_paged_matches_dense(self, family, quant):
        setup = _setup(family, quant)
        prompts = _prompts(setup[0], (9, 5, 12, 3))
        dense = _serve(setup, prompts)
        paged = _serve(setup, prompts, paged=True, page_size=8)
        assert paged.done == dense.done
        assert len(paged.done) == len(prompts)
        assert paged.allocator.used_pages == 0        # all pages returned

    @pytest.mark.slow
    def test_paged_matches_dense_int8_kv(self):
        """int8 KV *pages* (payload + per-token scale pages)."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12))
        dense = _serve(setup, prompts, kv_bits=8)
        paged = _serve(setup, prompts, kv_bits=8, paged=True, page_size=8)
        assert paged.done == dense.done

    def test_midblock_finish_admit_recycles_pages(self):
        """A tight pool: the queued request is admitted the moment a
        finishing request's pages return — onto *recycled* pages whose
        stale contents must never leak into its stream."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (10, 10, 10), seed=1)
        # 16-token budgets (10 + 6) = 4 pages each; 8 pages = exactly two
        # concurrent requests, so request 3 runs entirely on recycled pages
        paged = _serve(setup, prompts, gen_len=6, max_len=24,
                       paged=True, page_size=4, num_pages=8)
        dense = _serve(setup, prompts, gen_len=6, max_len=24)
        assert paged.done == dense.done
        assert paged.counters["peak_live"] == 2

    def test_prompt_spans_noncontiguous_pages(self):
        """A request admitted after an early finish inherits freed page
        ids out of order — its logical prompt spans physically
        non-contiguous pages and must still decode identically."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        prompts = _prompts(cfg, (4, 10, 14), seed=2)
        cfg_kw = dict(gen_len=6, max_len=24, block=2)
        paged = _serve(setup, prompts, paged=True, page_size=4,
                       num_pages=11, **cfg_kw)
        # request 0 (4+6=10 tokens, 3 pages) finishes first; request 2
        # (14+6=20 tokens, 5 pages) reuses its LIFO-freed pages plus
        # fresh ones — physically out of order
        pages3 = paged._slot_pages  # noqa: SLF001 — drained, must be empty
        assert pages3 == {}
        dense = _serve(setup, prompts, **cfg_kw)
        assert paged.done == dense.done

    def test_admission_waits_for_pages_not_lanes(self):
        """With a free lane but an empty pool, a request waits; it is
        admitted as soon as freed pages cover its budget — and still
        produces exactly a fresh engine's stream."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (10, 10, 10), seed=3)
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=3, max_len=24,
                         paged=True, page_size=4, num_pages=8)
            for p in prompts:
                eng.submit(p, gen_len=6)
            eng.try_admit()
            # three free lanes, but pages only cover two 4-page requests
            assert int(eng.live.sum()) == 2 and len(eng.waiting) == 1
            free_before = eng.allocator.free_pages
            assert free_before == 0
            while eng.live.any() or eng.waiting:
                eng.step_many(4)
            eng.retire_finished()

            solo = Engine(cfg, ctx, params, mesh, batch=3, max_len=24,
                          paged=True, page_size=4, num_pages=8)
            solo.submit(prompts[2], gen_len=6)
            solo.try_admit()
            while solo.live.any():
                solo.step_many(4)
            solo.retire_finished()
        assert eng.counters["admitted"] == 3
        assert eng.done[-1] == solo.done[0]


# ===========================================================================
class TestLongPromptRejection:
    """`add_requests` must reject prompts the cache cannot hold instead
    of silently clamp-writing their tail into the last rows."""

    @pytest.mark.parametrize("paged", [False, True])
    def test_add_requests_rejects_oversized_prompt(self, paged):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompt = _prompts(cfg, (33,))[0]         # max_len is 32
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=32,
                         paged=paged)
            with pytest.raises(ValueError, match="does not fit"):
                eng.add_requests({0: prompt}, gen_len=4)
            # nothing was admitted: the engine stays fully idle
            assert not eng.live.any() and eng.outputs == [None, None]
            if paged:
                assert eng.allocator.used_pages == 0
            # a fitting prompt still serves normally afterwards
            eng.add_requests({0: prompt[:8]}, gen_len=4)
            eng.step_many(4)
        assert len(eng.outputs[0]) == 4

    def test_submit_rejects_oversized_prompt(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=16)
            with pytest.raises(ValueError, match="does not fit"):
                eng.submit(_prompts(cfg, (17,))[0])
            assert not eng.waiting

    def test_submit_rejects_request_larger_than_pool(self):
        """A request whose budget exceeds the whole pool would block the
        FIFO head forever — rejected at submit time."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=32,
                         paged=True, page_size=4, num_pages=4)
            with pytest.raises(ValueError, match="pool only has"):
                eng.submit(_prompts(cfg, (8,))[0], gen_len=12)  # 5 pages
            eng.submit(_prompts(cfg, (8,))[0], gen_len=8)       # 4: fits
            assert len(eng.waiting) == 1

    def test_direct_admission_oom_is_atomic(self):
        """A slot-addressed add_requests that cannot get pages raises
        BEFORE touching allocator or engine state."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (8, 8))
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=32,
                         paged=True, page_size=4, num_pages=5)
            with pytest.raises(MemoryError, match="exhausted"):
                eng.add_requests({0: prompts[0], 1: prompts[1]}, gen_len=4)
            assert eng.allocator.used_pages == 0
            assert not eng.live.any()
            # the pool still serves a fitting admission afterwards
            eng.add_requests({0: prompts[0]}, gen_len=4)
            eng.step_many(4)
        assert len(eng.outputs[0]) == 4


# ===========================================================================
class TestPageAllocatorProperties:
    """Free-list invariants under hypothesis-stub interleaving sweeps."""

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 2 ** 16))
    def test_interleaved_alloc_free_never_double_assigns(
            self, num_pages, page_size, seed):
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, page_size)
        held = {}
        outstanding = set()
        for step in range(60):
            if held and (rs.rand() < 0.4 or alloc.free_pages == 0):
                owner = rs.choice(sorted(held))
                pages = held.pop(owner)
                outstanding.difference_update(pages)
                alloc.free(pages)
            else:
                n = int(rs.randint(0, alloc.free_pages + 1))
                pages = alloc.alloc(n, owner=step)
                # a page may never be assigned twice concurrently
                assert not (outstanding & set(pages))
                assert len(set(pages)) == len(pages)
                outstanding.update(pages)
                if pages:
                    held[step] = pages
            assert alloc.used_pages == len(outstanding)
            assert alloc.free_pages == num_pages - len(outstanding)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 64), st.integers(0, 2 ** 16))
    def test_freed_pages_immediately_reusable(self, num_pages, seed):
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, 4)
        a = alloc.alloc(num_pages)               # drain the pool
        assert not alloc.can_alloc(1)
        give_back = [p for p in a if rs.rand() < 0.5]
        alloc.free(give_back)
        # everything just freed is claimable again in one shot, now
        b = alloc.alloc(len(give_back))
        assert sorted(b) == sorted(give_back)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 64), st.integers(1, 40), st.integers(0, 2 ** 16))
    def test_no_spurious_oom_while_free_covers_need(self, num_pages, steps,
                                                    seed):
        """The dense layout's failure mode — enough total memory but no
        whole slot free — must not exist: any request with ``need <=
        free_pages`` succeeds, regardless of alloc/free history."""
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, 8)
        held = []
        for _ in range(steps):
            if held and rs.rand() < 0.5:
                alloc.free(held.pop(rs.randint(len(held))))
            need = int(rs.randint(0, num_pages + 1))
            if need <= alloc.free_pages:
                held.append(alloc.alloc(need))   # must never raise
            else:
                with pytest.raises(MemoryError):
                    alloc.alloc(need)

    def test_tokens_to_pages_rounding(self):
        alloc = PageAllocator(8, 16)
        assert [alloc.pages_for(t) for t in (0, 1, 16, 17, 32)] \
            == [0, 1, 1, 2, 2]

    def test_double_free_rejected(self):
        alloc = PageAllocator(4, 8)
        pages = alloc.alloc(2)
        alloc.free(pages)
        with pytest.raises(ValueError):
            alloc.free(pages)

    def test_failed_free_is_atomic(self):
        """A free() mixing valid and already-free ids must raise WITHOUT
        half-freeing the valid ones — the idempotent-double-free guard
        that keeps preempt/restore cycles from listing a page twice."""
        alloc = PageAllocator(8, 4)
        held = alloc.alloc(4, owner="a")
        freed = held[:2]
        alloc.free(freed)
        before = alloc.state()
        with pytest.raises(ValueError, match="not allocated"):
            alloc.free([held[2], freed[0]])          # valid + double-free
        with pytest.raises(ValueError, match="duplicate"):
            alloc.free([held[2], held[2]])           # in-call duplicate
        assert alloc.state() == before               # untouched either way

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 48), st.integers(0, 2 ** 16))
    def test_spill_adopt_interleavings_never_double_assign(
            self, num_pages, seed):
        """Preempt/resume as the allocator sees it: random alloc /
        free / spill(owner) / adopt(spilled ids) interleavings.  The
        invariants: a spill returns exactly the owner's pages, an adopt
        claims exactly the requested free ids, and no page is ever
        assigned to two owners at once across any cycle."""
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, 4)
        held: dict = {}                  # owner -> pages on the "device"
        spilled: dict = {}               # owner -> pages copied to host
        for step in range(80):
            ops = ["alloc"]
            if held:
                ops += ["free", "spill"]
            if spilled:
                ops += ["adopt"]
            op = ops[rs.randint(len(ops))]
            if op == "alloc":
                n = int(rs.randint(0, alloc.free_pages + 1))
                pages = alloc.alloc(n, owner=("r", step))
                if pages:
                    held[("r", step)] = pages
            elif op == "free":
                owner = sorted(held)[rs.randint(len(held))]
                alloc.free(held.pop(owner))
            elif op == "spill":
                owner = sorted(held)[rs.randint(len(held))]
                pages = alloc.spill(owner)
                assert sorted(pages) == sorted(held.pop(owner))
                spilled[owner] = pages
            else:                        # adopt: resume a spilled victim
                owner = sorted(spilled)[rs.randint(len(spilled))]
                pages = spilled.pop(owner)
                free_set = set(alloc.state()["free"])
                if set(pages) <= free_set:
                    alloc.adopt(pages, owner=owner)
                    held[owner] = pages
                else:                    # ids re-issued meanwhile: the
                    with pytest.raises(ValueError):  # claim must refuse
                        alloc.adopt(pages, owner=owner)
            # global invariant: held owners partition the used pages
            used = [p for pages in held.values() for p in pages]
            assert len(set(used)) == len(used)
            assert alloc.used_pages == len(used)
            for owner, pages in held.items():
                assert sorted(alloc.pages_of(owner)) == sorted(pages)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 32), st.integers(0, 2 ** 16))
    def test_state_round_trip_preserves_alloc_order(self, num_pages, seed):
        """load_state(state()) must reproduce the free-list ORDER: the
        next allocations after a restore hand out the same physical ids
        the original would — engine replay determinism rests on it."""
        rs = np.random.RandomState(seed)
        alloc = PageAllocator(num_pages, 4)
        for step in range(12):
            if rs.rand() < 0.5 and alloc.free_pages:
                alloc.alloc(int(rs.randint(1, alloc.free_pages + 1)),
                            owner=step)
            else:
                owners = {o for o in alloc.state()["owner"].values()}
                if owners:
                    alloc.spill(sorted(owners)[0])
        saved = alloc.state()
        twin = PageAllocator(num_pages, 4)
        twin.load_state(saved)
        n = min(3, alloc.free_pages)
        assert twin.alloc(n, owner="x") == alloc.alloc(n, owner="x")

    def test_load_state_rejects_non_partition(self):
        alloc = PageAllocator(4, 4)
        with pytest.raises(ValueError, match="partition"):
            alloc.load_state({"free": [0, 1], "owner": {1: "a", 3: "b"}})

    def test_adopt_rejects_assigned_or_unknown_ids(self):
        alloc = PageAllocator(4, 4)
        mine = alloc.alloc(2, owner="a")
        before = alloc.state()
        with pytest.raises(ValueError, match="already assigned"):
            alloc.adopt([mine[0]], owner="b")
        with pytest.raises(ValueError, match="not a valid free page"):
            alloc.adopt([99], owner="b")
        assert alloc.state() == before               # atomic: no change
