"""Fleet conformance: hot standby, SLO routing, class-partitioned pools.

The PR 10 tentpole.  A :class:`repro.launch.fleet.Fleet` fronts N
engine replicas behind one submit/step/results surface; pinned here:

* **Boundary validation** — replica counts, lag bounds, quota
  fractions, snapshot periods and heartbeat thresholds are rejected at
  construction with messages naming the constraint (the
  ``validate_request`` convention, per knob).
* **Class-partitioned page pools** — per-class floors and caps at the
  allocator, enforced at admission and by eviction priority in the
  prefix index: a BATCH flood can neither take REALTIME's reserved
  pages nor evict its prefix working set.
* **Heartbeat hysteresis** — alive → suspect → dead escalation over
  block-progress beats; dead is terminal, recovery needs consecutive
  healthy beats, and an alternating replica still converges to dead.
* **Promotion byte-identity** — the primary killed at EVERY fleet
  round; the journal-tailing standby finishes the replay and every
  completed stream equals the uninterrupted fleet's, exactly once.
* **Exactly-once re-dispatch** — a dead secondary's journaled-but-
  unfinished requests land on survivors once (REALTIME victims first),
  with the same total multiset of completed streams.
* **Bounded standby lag** — an injected lag spike defers at most one
  sync and never breaches ``max_standby_lag``.
"""

import numpy as np
import pytest

from repro.dist.constrain import use_mesh
from repro.ft import FleetFaultInjector, ReplicaHeartbeat
from repro.launch.fleet import Fleet
from repro.launch.lifecycle import (PriorityClass, RequestStatus,
                                    normalize_class_quotas)
from repro.launch.paging import PageAllocator
from repro.launch.serve import Engine, _parse_class_quotas

from test_paged_serving import _prompts, _setup

PAGED = dict(paged=True, page_size=4, num_pages=16)
RT, SD, BA = (PriorityClass.REALTIME, PriorityClass.STANDARD,
              PriorityClass.BATCH)


def _factory(setup, **base):
    cfg, ctx, params, mesh = setup
    base.setdefault("batch", 2)
    base.setdefault("max_len", 32)

    def make_engine(**over):
        return Engine(cfg, ctx, params, mesh, **dict(base, **over))

    return make_engine


def _run_fleet(setup, prompts, prios, *, n=1, standby_dir=None, inj=None,
               gen_len=6, block=4, fleet_kw=None, **eng_kw):
    with use_mesh(setup[3]):
        fl = Fleet(_factory(setup, **eng_kw), n,
                   standby_dir=None if standby_dir is None
                   else str(standby_dir),
                   fault_injector=inj, **(fleet_kw or {}))
        fids = [fl.submit(p, gen_len=gen_len, priority=prios[i])
                for i, p in enumerate(prompts)]
        fl.drain(block=block)
    return fl, fids


# ===========================================================================
class TestBoundaryValidation:
    """Every fleet-layer knob rejects nonsense at construction, with a
    message naming the constraint (the validate_request convention)."""

    def test_non_positive_replicas(self):
        for n in (0, -1):
            with pytest.raises(ValueError, match="n_replicas"):
                Fleet(lambda **kw: None, n)

    def test_negative_standby_lag(self):
        with pytest.raises(ValueError, match="max_standby_lag"):
            Fleet(lambda **kw: None, 1, max_standby_lag=-1)

    @pytest.mark.parametrize("frac", [0.0, -0.25, 1.5])
    def test_quota_fraction_outside_unit_interval(self, frac):
        with pytest.raises(ValueError, match=r"\(0, 1\]"):
            normalize_class_quotas({"realtime": {"floor": frac}})

    def test_quota_unknown_kind(self):
        with pytest.raises(ValueError, match="unknown class-quota keys"):
            normalize_class_quotas({"realtime": {"ceiling": 0.5}})

    def test_quota_floor_above_cap(self):
        with pytest.raises(ValueError, match="floor"):
            normalize_class_quotas({"batch": {"floor": 0.8, "cap": 0.5}})

    def test_quota_floors_oversubscribed(self):
        with pytest.raises(ValueError, match="floor"):
            normalize_class_quotas({"realtime": {"floor": 0.7},
                                    "batch": {"floor": 0.7}})

    def test_heartbeat_thresholds(self):
        with pytest.raises(ValueError, match="positive"):
            ReplicaHeartbeat(suspect_after=0)
        with pytest.raises(ValueError, match="dead_after"):
            ReplicaHeartbeat(suspect_after=3, dead_after=3)

    def test_negative_snapshot_every(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            with pytest.raises(ValueError, match="snapshot_every"):
                _factory(setup)(snapshot_every=-1)

    def test_class_quotas_need_paged(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            with pytest.raises(ValueError, match="paged"):
                _factory(setup)(class_quotas={"batch": {"cap": 0.5}})

    def test_request_over_class_cap_is_rejected(self):
        """A request that could NEVER fit its class cap would head-of-
        line block forever — refused at submit, like the pool bound."""
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _factory(setup)(
                **PAGED, class_quotas={"batch": {"cap": 0.25}})
            with pytest.raises(ValueError, match="capped"):
                eng.submit(_prompts(setup[0], (9,), seed=1)[0],
                           gen_len=20, priority="batch")

    def test_cli_quota_spec_parsing(self):
        assert _parse_class_quotas(None) is None
        q = _parse_class_quotas(["realtime:floor=0.25", "batch:cap=0.5"])
        assert q[RT]["floor"] == 0.25 and q[BA]["cap"] == 0.5
        for bad in ["realtime=0.25", "realtime:floor", "rt:floor=x"]:
            with pytest.raises(SystemExit):
                _parse_class_quotas([bad])


# ===========================================================================
class TestAllocatorQuotas:
    def test_floor_rounds_up_cap_rounds_down_but_never_zero(self):
        a = PageAllocator(10, 4, class_quotas={
            "realtime": {"floor": 0.25}, "batch": {"cap": 0.05}})
        assert a.floor_pages(RT) == 3          # ceil(2.5)
        assert a.cap_pages(BA) == 1            # max(1, floor(0.5))
        assert a.cap_pages(RT) is None
        assert a.floor_pages(BA) == 0

    def test_unpartitioned_pool_tracks_nothing(self):
        a = PageAllocator(8, 4)
        pages = a.alloc(3, owner=0, cls="batch")
        assert a.class_used(BA) == 0           # legacy: no charges
        assert a.can_alloc(5, cls="realtime")
        a.free(pages)

    def test_charge_follows_page_lifetime_not_ownership(self):
        a = PageAllocator(8, 4, class_quotas={"realtime": {"floor": 0.5}})
        pages = a.alloc(2, owner=0, cls="realtime")
        assert a.class_used(RT) == 2
        a.share(pages)
        a.transfer(pages, "__prefix__")        # publication keeps charge
        assert a.class_used(RT) == 2
        a.free(pages)                          # drops to refcount 1
        assert a.class_used(RT) == 2
        a.free(pages)                          # back to the pool
        assert a.class_used(RT) == 0

    def test_floor_reservation_blocks_other_classes(self):
        a = PageAllocator(8, 4, class_quotas={"realtime": {"floor": 0.5}})
        assert not a.can_alloc(5, cls="batch")  # would leave 3 < 4 floor
        assert a.can_alloc(4, cls="batch")
        assert a.can_alloc(8, cls="realtime")   # the floor's own class may
        with pytest.raises(MemoryError, match="reserved"):
            a.alloc(5, owner=0, cls="batch")

    def test_cap_violation_raises_with_class_name(self):
        a = PageAllocator(8, 4, class_quotas={"batch": {"cap": 0.5}})
        a.alloc(4, owner=0, cls="batch")
        with pytest.raises(MemoryError, match="batch over its page cap"):
            a.alloc(1, owner=1, cls="batch")
        assert a.can_alloc(4, cls="standard")  # other classes unaffected

    def test_quota_evict_want_sizes_the_sweep(self):
        a = PageAllocator(8, 4, class_quotas={"batch": {"cap": 0.5}})
        assert a.quota_evict_want("batch", 2) == 0
        a.alloc(3, owner=0, cls="batch")
        assert a.quota_evict_want("batch", 3) == 2   # 6 > cap 4 by 2
        assert a.quota_evict_want("standard", 3) == 0
        assert PageAllocator(8, 4).quota_evict_want("batch", 99) == 0

    def test_state_round_trip_preserves_charges(self):
        a = PageAllocator(8, 4, class_quotas={"realtime": {"floor": 0.5}})
        a.alloc(2, owner=0, cls="realtime")
        a.alloc(1, owner=1, cls="batch")
        b = PageAllocator(8, 4, class_quotas={"realtime": {"floor": 0.5}})
        b.load_state(a.state())
        assert b.class_used(RT) == 2 and b.class_used(BA) == 1

    def test_legacy_state_loads_uncharged(self):
        a = PageAllocator(8, 4)
        a.alloc(2, owner=0)
        st = a.state()
        st.pop("cls", None)                    # pre-quota snapshot shape
        b = PageAllocator(8, 4, class_quotas={"realtime": {"floor": 0.25}})
        b.load_state(st)
        assert b.class_used(SD) == 0           # unknown history: uncharged


# ===========================================================================
class TestHeartbeatHysteresis:
    def test_escalation_and_terminal_death(self):
        hb = ReplicaHeartbeat(suspect_after=2, dead_after=4)
        assert hb.beat(False) == "alive"
        assert hb.beat(False) == "suspect"
        assert hb.beat(False) == "suspect"
        assert hb.beat(False) == "dead"
        assert hb.beat(True) == "dead"         # terminal

    def test_recovery_needs_consecutive_healthy_beats(self):
        hb = ReplicaHeartbeat(suspect_after=2, dead_after=4,
                              recover_after=2)
        hb.beat(False), hb.beat(False)
        assert hb.state == "suspect"
        assert hb.beat(True) == "suspect"      # one lucky block: not yet
        assert hb.beat(True) == "alive"

    def test_alternating_blocks_still_converge_to_dead(self):
        """The unhealthy streak is only forgiven by a full recovery, so
        good/bad alternation cannot hover at the threshold forever."""
        hb = ReplicaHeartbeat(suspect_after=2, dead_after=4,
                              recover_after=2)
        states = [hb.beat(h) for h in
                  (False, True, False, True, False, True, False)]
        assert states[-1] == "dead"


# ===========================================================================
class TestRouting:
    def test_least_pressure_spreads_load(self):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=41)
        with use_mesh(setup[3]):
            fl = Fleet(_factory(setup), 2)
            fids = [fl.submit(p, gen_len=4) for p in prompts]
            homes = [fl._ledger[f]["replica"] for f in fids]
            assert sorted(homes) == [0, 0, 1, 1]  # alternating, not piled
            fl.drain(block=4)
        assert all(fl.results[f]["status"] is RequestStatus.COMPLETED
                   for f in fids)

    def test_suspects_avoided_until_nothing_else_lives(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            fl = Fleet(_factory(setup), 2)
            fl.state[1] = "suspect"
            fids = [fl.submit(p, gen_len=4)
                    for p in _prompts(setup[0], (9, 5), seed=42)]
            assert all(fl._ledger[f]["replica"] == 0 for f in fids)
            fl.state[0] = "dead"               # now only the suspect lives
            f = fl.submit(_prompts(setup[0], (7,), seed=43)[0], gen_len=4)
            assert fl._ledger[f]["replica"] == 1

    def test_whole_fleet_dead_raises(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            fl = Fleet(_factory(setup), 2)
            fl.state = ["dead", "dead"]
            with pytest.raises(RuntimeError, match="no live replicas"):
                fl.submit(_prompts(setup[0], (7,), seed=44)[0], gen_len=4)


# ===========================================================================
class TestPromotionByteIdentity:
    """Primary killed at EVERY fleet round; the promoted standby's
    completed streams must equal the uninterrupted fleet's — content,
    status, and exactly-once completion."""

    CELLS = [
        ("lm", {}, False),
        ("lm", dict(PAGED), False),
        pytest.param("lm", dict(PAGED), True, marks=pytest.mark.slow),
        pytest.param("ssm", {}, False, marks=pytest.mark.slow),
        pytest.param("hybrid", dict(PAGED), False,
                     marks=pytest.mark.slow),
    ]

    @pytest.mark.parametrize("family,kw,spec", CELLS)
    def test_kill_primary_at_every_round(self, tmp_path, family, kw, spec):
        setup = _setup(family, "f32")
        drive = dict(gen_len=12, block=2) if spec else {}
        if spec:
            kw = dict(kw, spec=True)
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=31)
        prios = ("batch", "realtime", None, "standard")
        clean, fids = _run_fleet(setup, prompts, prios,
                                 standby_dir=tmp_path / "clean",
                                 **drive, **kw)
        rounds = clean._round
        assert rounds >= 3, "workload too short to exercise promotion"
        for rnd in range(1, rounds + 1):
            inj = FleetFaultInjector([(rnd, 0, "kill")])
            fl, _ = _run_fleet(setup, prompts, prios,
                               standby_dir=tmp_path / str(rnd), inj=inj,
                               **drive, **kw)
            assert fl.counters["promotions"] == 1
            assert fl.counters["time_to_promote_s"] is not None
            assert set(fl.results) == set(fids), f"lost stream @ {rnd}"
            for f in fids:
                assert fl.results[f]["tokens"] == \
                    clean.results[f]["tokens"], f"diverged @ round {rnd}"
                assert fl.results[f]["status"] == clean.results[f]["status"]

    def test_promote_without_standby_is_refused(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            fl = Fleet(_factory(setup), 2)
            with pytest.raises(RuntimeError, match="standby"):
                fl.promote()


# ===========================================================================
class TestRedispatch:
    def test_secondary_death_same_multiset_exactly_once(self, tmp_path):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3, 7, 8), seed=31)
        prios = ("batch", "realtime", None, "standard", "realtime",
                 "batch")
        clean, fids = _run_fleet(setup, prompts, prios, n=2)
        inj = FleetFaultInjector([(1, 1, "kill")])
        fl, _ = _run_fleet(setup, prompts, prios, n=2, inj=inj)
        assert fl.counters["deaths"] == 1
        assert fl.counters["redispatched"] >= 1
        assert set(fl.results) == set(fids)
        for f in fids:
            assert fl.results[f]["tokens"] == clean.results[f]["tokens"]
        # exactly once: every re-dispatched ledger entry moved exactly
        # one time, and no fleet id produced two results
        assert all(not e["redispatched"] or e["replica"] == 0
                   for e in fl._ledger.values())

    def test_realtime_victims_redispatch_first(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            fl = Fleet(_factory(setup), 2)
            # pin three requests to replica 1 by marking 0 suspect
            fl.state[0] = "suspect"
            prompts = _prompts(setup[0], (9, 5, 7), seed=45)
            for p, prio in zip(prompts, ("batch", "realtime", "standard")):
                fl.submit(p, gen_len=4, priority=prio)
            fl.state[0] = "alive"
            order = []
            orig = fl.replicas[0].submit

            def spy(prompt, **kw):
                order.append(kw.get("priority"))
                return orig(prompt, **kw)

            fl.replicas[0].submit = spy
            fl._on_death(1)
            assert order == ["realtime", "standard", "batch"]
            fl.drain(block=4)
        assert len(fl.results) == 3

    def test_stalled_replica_escalates_to_dead_and_work_moves(self):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=31)
        prios = ("batch", "realtime", None, "standard")
        clean, fids = _run_fleet(setup, prompts, prios, n=2)
        inj = FleetFaultInjector([(r, 1, "stall") for r in range(1, 30)])
        fl, _ = _run_fleet(setup, prompts, prios, n=2, inj=inj)
        assert fl.state[1] == "dead"
        assert fl.counters["suspects"] == 1    # went through suspect first
        for f in fids:
            assert fl.results[f]["tokens"] == clean.results[f]["tokens"]


# ===========================================================================
class TestStandbyLag:
    def test_lag_spike_defers_one_sync_within_bound(self, tmp_path):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=31)
        prios = ("batch", "realtime", None, "standard")
        clean, fids = _run_fleet(setup, prompts, prios,
                                 standby_dir=tmp_path / "clean")
        inj = FleetFaultInjector([(2, None, "lag"), (3, 0, "kill")])
        fl, _ = _run_fleet(setup, prompts, prios,
                           standby_dir=tmp_path / "lag", inj=inj)
        assert ("lag" in {k for (_, _, k) in inj.events})
        for f in fids:
            assert fl.results[f]["tokens"] == clean.results[f]["tokens"]

    def test_fault_free_standby_fleet_drains_caught_up(self, tmp_path):
        """Drain liveness: the admission sweep journals on the primary
        even when idle, so a drive loop that admits after stepping must
        sync the standby too — or the follower sits one record behind
        forever and ``busy()`` never clears.  Wide heartbeat thresholds
        keep scheduler jitter from promoting organically, which would
        mask the hang (the follower detaches on promotion)."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5), seed=52)
        fl, fids = _run_fleet(setup, prompts, (None, "realtime"),
                              standby_dir=tmp_path,
                              fleet_kw=dict(suspect_after=64,
                                            dead_after=128))
        assert fl.counters["deaths"] == 0
        assert fl.counters["promotions"] == 0
        assert fl.counters["journal_lag_records"] == 0
        assert not fl.busy()
        assert all(fl.results[f]["status"] is RequestStatus.COMPLETED
                   for f in fids)

    def test_zero_lag_bound_forces_every_sync(self, tmp_path):
        """max_standby_lag=0: even an injected spike may not defer —
        the bound wins and the standby stays fully caught up."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5), seed=46)
        inj = FleetFaultInjector([(r, None, "lag") for r in range(1, 20)])
        fl, fids = _run_fleet(setup, prompts, (None, None),
                              standby_dir=tmp_path, inj=inj,
                              fleet_kw=dict(max_standby_lag=0))
        assert fl.counters["journal_lag_records"] == 0
        assert all(fl.results[f]["status"] is RequestStatus.COMPLETED
                   for f in fids)


# ===========================================================================
class TestQuotaIsolation:
    def test_batch_flood_cannot_take_realtime_floor_or_prefix(self):
        setup = _setup("lm", "f32")
        cfg = setup[0]
        kw = dict(batch=2, max_len=32, paged=True, page_size=4,
                  num_pages=16, prefix_cache=True,
                  class_quotas={"realtime": {"floor": 0.25},
                                "batch": {"cap": 0.5}})
        with use_mesh(setup[3]):
            eng = _factory(setup)(**kw)
            rs = np.random.RandomState(7)
            pre = rs.randint(0, cfg.vocab, (8,))

            def drive(prompt, prio):
                eng.submit(prompt, gen_len=4, priority=prio)
                eng.try_admit()
                while eng.live.any() or eng.waiting:
                    eng.step_many(4)
                eng.retire_finished()

            drive(np.concatenate([pre, rs.randint(0, cfg.vocab, (3,))]),
                  "realtime")
            rt_pages = set(eng.prefix_index.pages())
            assert rt_pages, "realtime run published nothing"
            # BATCH flood: distinct prompts, enough to churn the pool
            for i in range(8):
                eng.submit(_prompts(cfg, (9,), seed=100 + i)[0],
                           gen_len=6, priority="batch")
            eng.try_admit()
            while eng.live.any() or eng.waiting:
                eng.step_many(4)
            eng.retire_finished()
            # the floor held: realtime's published working set survived
            # the flood page-for-page, and batch stayed under its cap
            assert rt_pages <= set(eng.prefix_index.pages())
            assert (eng.allocator.class_used(BA)
                    <= eng.allocator.cap_pages(BA))
            # and the survivor is WARM: the next realtime admission hits
            hits = eng.counters["prefix_hits"]
            drive(np.concatenate([pre, rs.randint(0, cfg.vocab, (3,))]),
                  "realtime")
            assert eng.counters["prefix_hits"] > hits

    def test_flood_evicts_its_own_published_pages_to_stay_live(self):
        """A capped class whose published prefixes hold its whole
        budget must evict ITSELF forward — cap pressure never deadlocks
        admission."""
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _factory(setup)(
                batch=2, max_len=32, paged=True, page_size=4,
                num_pages=16, prefix_cache=True,
                class_quotas={"batch": {"cap": 0.5}})
            for i in range(6):
                eng.submit(_prompts(setup[0], (9,), seed=200 + i)[0],
                           gen_len=6, priority="batch")
            eng.try_admit()
            rounds = 0
            while eng.live.any() or eng.waiting:
                eng.step_many(4)
                rounds += 1
                assert rounds < 200, "admission deadlocked under cap"
            eng.retire_finished()
        assert len(eng.done) == 6


# ===========================================================================
class TestFleetStats:
    def test_engine_health_fields(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _factory(setup)()
            st = eng.stats()
        assert st["uptime_s"] >= 0.0
        assert st["recoveries"] == 0
        assert st["journal_lag_records"] is None   # no fleet feeds it

    def test_fleet_stats_shape_and_dead_replicas(self, tmp_path):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5), seed=47)
        inj = FleetFaultInjector([(1, 1, "kill")])
        fl, _ = _run_fleet(setup, prompts, (None, None), n=2, inj=inj)
        st = fl.stats()
        assert st["replicas"] == 2
        assert st["states"][1] == "dead"
        assert st["per_replica"][1] is None
        assert st["per_replica"][0]["requests"] >= 1
        assert st["results"] == 2 and st["routed_open"] == 0

    def test_standby_lag_feeds_primary_stats(self, tmp_path):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5), seed=48)
        fl, _ = _run_fleet(setup, prompts, (None, None),
                           standby_dir=tmp_path)
        st = fl.replicas[0].stats()
        assert st["journal_lag_records"] == 0      # fully caught up
        assert fl.counters["journal_lag_records"] == 0

    def test_promoted_standby_counts_a_recovery(self, tmp_path):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5), seed=49)
        inj = FleetFaultInjector([(1, 0, "kill")])
        fl, _ = _run_fleet(setup, prompts, (None, None),
                           standby_dir=tmp_path, inj=inj)
        assert fl.replicas[0].stats()["recoveries"] == 1
