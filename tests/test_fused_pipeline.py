"""Fused quantized dense pipeline tests.

Covers the three legs end to end:

* pre-quantized (QTensor) weights through ``linear()`` — bit-exact vs
  the dynamic-quant path, and ZERO weight-quantization ops per forward
  (counted in the jaxpr);
* the fused qmatmul epilogue (bias + LUT activation) vs the explicit
  three-op ``ref`` composition, and the one-``pallas_call`` claim;
* batched chunked prefill vs the per-token decode loop (same first
  generated token), plus engine hygiene (empty prompts, slot
  invalidation, live slots undisturbed by refills).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import FixedPointType, QTensor
from repro.core.quantize import dequantize_params, ptq_params
from repro.core.tables import TableSpec
from repro.kernels.ops import lut_activation, qmatmul
from repro.kernels.ref import lut_activation_ref, qmatmul_ref
from repro.launch.hlo_analysis import count_jaxpr_primitive as \
    _count_primitive
from repro.nn.context import QuantContext
from repro.nn.linear import linear, linear_init

RNG = np.random.RandomState(0)
QT8 = FixedPointType(8, 4)


def _int8_ctx(**kw):
    return QuantContext(mode="int8", policy=PrecisionPolicy.uniform(QT8),
                        compute_dtype=jnp.float32, **kw)


# ===========================================================================
class TestPrequantLinear:
    def test_qtensor_weights_bitexact_vs_dynamic(self):
        ctx = _int8_ctx()
        p = linear_init(jax.random.PRNGKey(0), 64, 48, bias=True)
        p["b"] = jnp.asarray(RNG.randn(48), jnp.float32)
        x = jnp.asarray(RNG.randn(3, 5, 64), jnp.float32)
        y_dyn = linear(p, x, ctx, path="mlp/up")
        qp = ptq_params(p, QT8)
        assert isinstance(qp["w"], QTensor)
        assert not isinstance(qp["b"], QTensor)  # bias stays float
        y_pre = linear(qp, x, ctx, path="mlp/up")
        np.testing.assert_array_equal(np.asarray(y_dyn), np.asarray(y_pre))

    def test_zero_weight_quant_ops_per_forward(self):
        """Acceptance: with QTensor weights the forward jaxpr contains NO
        weight calibrate/round — only the single activation round."""
        ctx = _int8_ctx()
        p = linear_init(jax.random.PRNGKey(0), 64, 48)
        qp = ptq_params(p, QT8)
        x = jnp.asarray(RNG.randn(4, 64), jnp.float32)

        dyn = jax.make_jaxpr(lambda xx: linear(p, xx, ctx))(x)
        pre = jax.make_jaxpr(lambda xx: linear(qp, xx, ctx))(x)
        n_dyn = _count_primitive(dyn.jaxpr, "round")
        n_pre = _count_primitive(pre.jaxpr, "round")
        # dynamic path rounds activations AND weights; prequant only acts
        assert n_dyn == 2, n_dyn
        assert n_pre == 1, n_pre
        # the weight max-abs calibration also disappears
        assert _count_primitive(pre.jaxpr, "reduce_max") \
            < _count_primitive(dyn.jaxpr, "reduce_max")

    def test_stacked_weights_scan_sliceable(self):
        """ptq scales keep the leading stack axis so lax.scan can slice
        QTensor params layer by layer."""
        w = jnp.asarray(RNG.randn(4, 16, 32), jnp.float32)   # (L, in, out)
        q = ptq_params({"w": w}, QT8)["w"]
        assert q.data.shape == (4, 16, 32)
        assert q.scale.shape == (4, 1, 32)

        def body(carry, p_l):
            y = linear(p_l, carry, _int8_ctx())
            return jnp.tanh(y[..., :16]), None

        out, _ = jax.lax.scan(body, jnp.ones((2, 16)), {"w": q})
        assert out.shape == (2, 16)

    def test_embed_router_and_conv_stay_dense(self):
        params = {"embed": {"table": jnp.ones((32, 8))},
                  "moe": {"router": jnp.ones((8, 4)),
                          "w_gate": jnp.ones((4, 8, 16))},
                  "ssm": {"conv_w": jnp.ones((4, 8)),
                          "in_proj": {"w": jnp.ones((8, 16))}}}
        q = ptq_params(params, QT8)
        assert not isinstance(q["embed"]["table"], QTensor)
        assert not isinstance(q["moe"]["router"], QTensor)
        assert not isinstance(q["ssm"]["conv_w"], QTensor)
        assert isinstance(q["moe"]["w_gate"], QTensor)
        assert isinstance(q["ssm"]["in_proj"]["w"], QTensor)

    def test_mla_family_serves_with_ptq_params(self):
        """wkv_b is consumed raw (reshaped, not via linear) — the PTQ
        QTensor must dequantize instead of crashing (deepseek/MLA)."""
        from repro.configs import get_config
        from repro.models.api import get_family
        cfg = get_config("deepseek-v2-236b").smoke()
        ctx = _int8_ctx()
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        qparams = ptq_params(params, ctx.policy)
        cache = fam.init_cache(cfg, 1, 12, jnp.float32)
        toks = jnp.asarray(RNG.randint(0, cfg.vocab, (1, 4)), jnp.int32)
        last, cache = fam.prefill(qparams, toks, cache, cfg, ctx)
        lg, _ = fam.decode_step(qparams, toks[:, :1], cache,
                                jnp.asarray([4], jnp.int32), cfg, ctx)
        assert np.isfinite(np.asarray(last)).all()
        assert np.isfinite(np.asarray(lg)).all()

    def test_qtensor_specs_keep_payload_sharding(self):
        """param_specs must not let the scale's size-1 axes strip the
        payload's FSDP axis — payload and scale get separate specs."""
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import named, param_specs
        mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
        qp = ptq_params({"blk": {"w": jnp.ones((128, 256))}}, QT8)
        specs = param_specs(qp, mesh)
        assert isinstance(specs["blk"]["w"], QTensor)
        assert len(specs["blk"]["w"].data) == 2      # payload rule intact
        # the scale's own spec is guarded against the SCALE's shape: any
        # mesh axis assigned to its size-1 dim must divide 1
        s_spec = specs["blk"]["w"].scale
        scale_shape = qp["blk"]["w"].scale.shape
        for axis, dim in zip(tuple(s_spec), scale_shape):
            if axis is not None:
                assert dim % mesh.shape[axis] == 0
        put = jax.device_put(qp, named(specs, mesh))  # trees must line up
        assert isinstance(put["blk"]["w"], QTensor)

    def test_qtensor_under_float_modes_dequantizes(self):
        """QTensor weights still work when the context is not int8."""
        p = linear_init(jax.random.PRNGKey(1), 32, 16)
        qp = ptq_params(p, QT8)
        x = jnp.asarray(RNG.randn(4, 32), jnp.float32)
        ctx = QuantContext(compute_dtype=jnp.float32)
        y_q = linear(qp, x, ctx)
        y_ref = x @ dequantize_params(qp)["w"]
        np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)


# ===========================================================================
class TestFusedEpilogue:
    def _operands(self, m=32, k=128, n=64):
        a = RNG.randint(-127, 128, (m, k)).astype(np.int8)
        b = RNG.randint(-127, 128, (k, n)).astype(np.int8)
        sa = (RNG.rand(m, 1).astype(np.float32) + 0.1) * 0.005
        sb = (RNG.rand(1, n).astype(np.float32) + 0.1) * 0.005
        bias = RNG.randn(n).astype(np.float32)
        return a, b, sa, sb, bias

    @pytest.mark.parametrize("indexing", ["interp", "nearest", "trunc"])
    @pytest.mark.parametrize("gated", [False, True])
    def test_fused_matches_ref_composition(self, indexing, gated):
        a, b, sa, sb, bias = self._operands()
        fn = "silu_gate" if gated else "sigmoid"
        spec = TableSpec(fn, 512, -10.0, 10.0, None, indexing)
        # explicit composition: qmatmul -> +bias -> LUT
        y = qmatmul_ref(a, b, sa, sb)
        y = y + bias.reshape(1, -1)
        z = lut_activation_ref(y, spec)
        want = y * z if gated else z
        got = qmatmul(a, b, sa, sb, bias=bias, act_spec=spec,
                      act_gated=gated, backend="pallas")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        got_ref = qmatmul(a, b, sa, sb, bias=bias, act_spec=spec,
                          act_gated=gated, backend="ref")
        np.testing.assert_array_equal(np.asarray(got_ref), np.asarray(want))

    def test_bias_only_epilogue(self):
        a, b, sa, sb, bias = self._operands()
        want = np.asarray(qmatmul_ref(a, b, sa, sb)) + bias.reshape(1, -1)
        got = qmatmul(a, b, sa, sb, bias=bias, backend="pallas")
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                   atol=1e-4)

    def test_fused_is_one_pallas_call(self):
        """Acceptance: one kernel launch where the unfused path used
        three (matmul, bias add, activation)."""
        a, b, sa, sb, bias = self._operands()
        spec = TableSpec("sigmoid", 256, -8.0, 8.0, None, "interp")

        fused = jax.make_jaxpr(lambda: qmatmul(
            a, b, sa, sb, bias=bias, act_spec=spec,
            backend="pallas"))()
        unfused = jax.make_jaxpr(lambda: lut_activation(
            qmatmul(a, b, sa, sb, backend="pallas") + bias.reshape(1, -1),
            spec, backend="pallas"))()
        assert _count_primitive(fused.jaxpr, "pallas_call") == 1
        assert _count_primitive(unfused.jaxpr, "pallas_call") == 2

    def test_linear_fuses_under_int8_lut(self):
        """linear(act=...) under int8+LUT emits ONE pallas_call and
        matches the unfused act_fn composition."""
        from repro.nn.activations import act_fn
        ctx = _int8_ctx(use_lut=True, table_indexing="interp",
                        backend="pallas")
        p = linear_init(jax.random.PRNGKey(2), 64, 32, bias=True)
        p["b"] = jnp.asarray(RNG.randn(32), jnp.float32)
        qp = ptq_params(p, QT8)
        x = jnp.asarray(RNG.randn(4, 64), jnp.float32)

        fused = jax.make_jaxpr(
            lambda xx: linear(qp, xx, ctx, path="mlp/up", act="silu"))(x)
        assert _count_primitive(fused.jaxpr, "pallas_call") == 1

        y_fused = linear(qp, x, ctx, path="mlp/up", act="silu")
        y_unfused = act_fn("silu", linear(qp, x, ctx, path="mlp/up"), ctx,
                           path="mlp/up/act")
        np.testing.assert_allclose(np.asarray(y_fused),
                                   np.asarray(y_unfused), rtol=1e-4,
                                   atol=1e-4)


# ===========================================================================
class TestBatchedPrefill:
    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_config
        from repro.models.api import get_family
        cfg = get_config("gemma-2b").smoke()
        ctx = QuantContext(compute_dtype=jnp.float32)
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        from repro.launch.mesh import make_local_mesh
        return cfg, ctx, params, make_local_mesh()

    def _engine(self, setup, **kw):
        from repro.launch.serve import Engine
        cfg, ctx, params, mesh = setup
        return Engine(cfg, ctx, params, mesh, batch=2, max_len=40, **kw)

    def test_first_token_matches_per_token_loop(self, setup):
        """Acceptance: batched chunked prefill produces the same first
        generated token (and subsequent decode) as the old per-token
        decode loop."""
        from repro.dist.constrain import use_mesh
        rs = np.random.RandomState(0)
        prompts = {0: rs.randint(0, setup[0].vocab, (13,)),
                   1: rs.randint(0, setup[0].vocab, (7,))}
        with use_mesh(setup[3]):
            chunked = self._engine(setup, prefill_chunk=4)
            chunked.add_requests(prompts)
            looped = self._engine(setup)
            looped.chunked = False          # force the legacy loop
            looped.add_requests(prompts)
            np.testing.assert_array_equal(chunked.tokens, looped.tokens)
            for _ in range(4):
                chunked.step()
                looped.step()
            assert chunked.outputs == looped.outputs

    def test_chunked_prefill_call_count(self, setup):
        """Prompt ingestion is O(ceil(max_len / chunk)) full-batch steps,
        not O(prompt_len) per slot."""
        from repro.dist.constrain import use_mesh
        rs = np.random.RandomState(1)
        with use_mesh(setup[3]):
            eng = self._engine(setup, prefill_chunk=4)
            calls = {"n": 0}
            inner = eng.prefill

            def counting_prefill(*a, **k):
                calls["n"] += 1
                return inner(*a, **k)

            eng.prefill = counting_prefill
            eng.add_requests({0: rs.randint(0, setup[0].vocab, (13,)),
                              1: rs.randint(0, setup[0].vocab, (7,))})
            assert calls["n"] == 4          # ceil(13 / 4) for BOTH slots

    def test_empty_prompt_is_defined(self, setup):
        from repro.dist.constrain import use_mesh
        with use_mesh(setup[3]):
            eng = self._engine(setup)
            eng.add_requests({0: np.zeros((0,), np.int32)})
            assert eng.live[0]
            assert eng.pos[0] == 1          # the implicit BOS pad token
            assert 0 <= eng.tokens[0, 0] < setup[0].vocab

    def test_finish_invalidates_slot_cache(self, setup):
        from repro.dist.constrain import use_mesh
        rs = np.random.RandomState(2)
        with use_mesh(setup[3]):
            eng = self._engine(setup)
            eng.add_requests({0: rs.randint(0, setup[0].vocab, (6,)),
                              1: rs.randint(0, setup[0].vocab, (6,))})
            eng.step()
            eng.finish(0)
            assert not eng.live[0] and eng.pos[0] == 0
            for leaf in jax.tree_util.tree_leaves(eng.cache):
                assert not np.asarray(leaf[:, 0]).any()   # slot 0 zeroed
                assert np.asarray(leaf[:, 1]).any()       # slot 1 intact

    def test_refill_does_not_disturb_live_slot(self, setup):
        """A mid-flight batched refill must leave a generating slot's
        token stream identical to an undisturbed run."""
        from repro.dist.constrain import use_mesh
        rs = np.random.RandomState(3)
        p0 = rs.randint(0, setup[0].vocab, (9,))
        p1 = rs.randint(0, setup[0].vocab, (11,))
        with use_mesh(setup[3]):
            solo = self._engine(setup, prefill_chunk=4)
            solo.add_requests({0: p0})
            for _ in range(6):
                solo.step()

            eng = self._engine(setup, prefill_chunk=4)
            eng.add_requests({0: p0})
            for _ in range(3):
                eng.step()
            eng.add_requests({1: p1})       # refill while slot 0 is live
            for _ in range(3):
                eng.step()
        assert eng.outputs[0] == solo.outputs[0]
