"""End-to-end behaviour tests: the paper's full pipeline on its canonical
workload (train fp32 → PTQ → accuracy claim → LUT deployment), plus the
integrated train/serve drivers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import AC_FIXED_16_6, E4M3, FixedPointType
from repro.models import mlp
from repro.nn.context import QuantContext


def jet_data(n, seed=0):
    """Synthetic jet-tagging-like task: 16 features → 5 classes.  Class
    centers are FIXED (task identity); ``seed`` draws fresh noise/labels
    (train/test splits share the task)."""
    rng_task = np.random.RandomState(0)
    centers = rng_task.randn(5, 16) * 2.0
    rng = np.random.RandomState(seed + 1)
    y = rng.randint(0, 5, n)
    x = centers[y] + rng.randn(n, 16) * 1.0
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


@pytest.fixture(scope="module")
def trained_mlp():
    x, y = jet_data(2048)
    params = mlp.init(jax.random.PRNGKey(0))
    ctx = QuantContext(compute_dtype=jnp.float32)

    @jax.jit
    def step(params, lr):
        (l, m), g = jax.value_and_grad(mlp.loss, has_aux=True)(
            params, {"x": x, "y": y}, ctx)
        return jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params,
                                      g), m

    for i in range(300):
        params, m = step(params, 0.05)
    assert float(m["accuracy"]) > 0.85, float(m["accuracy"])
    return params, float(m["accuracy"])


class TestPaperPipeline:
    def test_fp32_baseline_trains(self, trained_mlp):
        _, acc = trained_mlp
        assert acc > 0.85

    def test_ptq_ac_fixed_16_6_small_accuracy_loss(self, trained_mlp):
        """The paper's core claim (inherited from hls4ml): ac_fixed<16,6>
        post-training quantization costs ~no accuracy."""
        params, acc_fp = trained_mlp
        x, y = jet_data(2048, seed=1)
        ctx_q = QuantContext(mode="fake",
                             policy=PrecisionPolicy.uniform(AC_FIXED_16_6),
                             compute_dtype=jnp.float32)
        pred = mlp.forward(params, x, ctx_q)
        acc_q = float(jnp.mean((jnp.argmax(pred, -1) == y)))
        assert acc_q > acc_fp - 0.02, (acc_q, acc_fp)

    def test_minifloat_between_fixed8_and_fp32(self, trained_mlp):
        """Paper §IV-B: custom floats open a design space — E4M3 should
        not be materially worse than fp32 here."""
        params, acc_fp = trained_mlp
        x, y = jet_data(2048, seed=2)

        def acc_with(qt):
            ctx = QuantContext(mode="fake",
                               policy=PrecisionPolicy.uniform(qt),
                               compute_dtype=jnp.float32)
            p = mlp.forward(params, x, ctx)
            return float(jnp.mean((jnp.argmax(p, -1) == y)))

        acc_e4m3 = acc_with(E4M3)
        assert acc_e4m3 > acc_fp - 0.05

    def test_lut_softmax_deployment(self, trained_mlp):
        """Deployed predict() with the 1024×18-bit table softmax matches
        exact probabilities to table precision."""
        params, _ = trained_mlp
        x, _ = jet_data(256, seed=3)
        ctx_lut = QuantContext(use_lut=True, compute_dtype=jnp.float32)
        ctx_fp = QuantContext(compute_dtype=jnp.float32)
        p_lut = mlp.predict(params, x, ctx_lut)
        p_fp = mlp.predict(params, x, ctx_fp)
        assert float(jnp.abs(p_lut - p_fp).max()) < 2e-2
        agree = jnp.mean((jnp.argmax(p_lut, -1) == jnp.argmax(p_fp, -1)))
        assert float(agree) > 0.99


class TestDrivers:
    def test_train_driver_smoke(self, tmp_path):
        from repro.launch.train import main
        out = main(["--arch", "olmoe-1b-7b", "--smoke", "--steps", "6",
                    "--batch", "4", "--seq", "32", "--microbatches", "2",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
                    "--log-every", "0"])
        assert int(out["step"]) == 6

    def test_train_driver_fault_injection(self, tmp_path):
        from repro.launch.train import main
        out = main(["--arch", "yi-6b", "--smoke", "--steps", "8",
                    "--batch", "2", "--seq", "16",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
                    "--fail-at", "5", "--log-every", "0"])
        assert out["restores"] == 1
        assert int(out["step"]) == 8

    def test_serve_driver_quantized(self):
        from repro.launch.serve import main
        done = main(["--arch", "gemma-2b", "--smoke", "--requests", "3",
                     "--batch", "2", "--prompt-len", "4", "--gen-len", "4",
                     "--quant", "fake", "--lut"])
        assert len(done) == 3
        assert all(len(seq) >= 4 for seq in done)
