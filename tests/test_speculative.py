"""Speculative decoding conformance suite.

Two layers of guarantees:

1. The ``verify_tokens`` op: fused lowering == ref oracle bit-for-bit
   (shared-noise exact match, see the oracle docstring for what that
   does and does not verify), plus the semantic properties asserted
   independently — the greedy chain IS the argmax chain, ``n_advance``
   bounds, next-token consistency.

2. The engine: greedy speculative streams are byte-identical to the
   non-speculative engine for lm/ssm/hybrid × f32/int8 × dense/paged —
   for the default prompt-lookup drafter, for a second-model drafter,
   and for a deliberately-adversarial drafter (which must degrade to
   ≥ 1 committed token per round and never corrupt KV/recurrent state:
   byte-identity with full rejection is precisely the proof that the
   family-aware rollback restored every consumed-but-rejected token).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.precision import PrecisionPolicy
from repro.core.qtypes import FixedPointType
from repro.dist.constrain import use_mesh
from repro.kernels.ops import verify_tokens
from repro.kernels.ref import verify_tokens_ref
from repro.kernels.speculative import draft_ngram, verify_tokens_fused
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import Engine, quantize_for_serving
from repro.models.api import get_family
from repro.nn.context import QuantContext

ARCHS = {"lm": "gemma-2b", "ssm": "mamba2-370m", "hybrid": "zamba2-1.2b"}
_CACHE = {}


def _setup(family: str, quant: str = "f32"):
    key = (family, quant)
    if key not in _CACHE:
        cfg = get_config(ARCHS[family]).smoke()
        if quant == "int8":
            ctx = QuantContext(mode="int8",
                               policy=PrecisionPolicy.uniform(
                                   FixedPointType(8, 4)),
                               compute_dtype=jnp.float32)
        else:
            ctx = QuantContext(compute_dtype=jnp.float32)
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        if quant == "int8":
            params = quantize_for_serving(params, ctx)
        _CACHE[key] = (cfg, ctx, params, make_local_mesh())
    return _CACHE[key]


def _prompts(cfg, seed=0, repetitive=False):
    rs = np.random.RandomState(seed)
    if repetitive:
        # the workload where prompt-lookup shines: tiled patterns give
        # the n-gram drafter matches from the first generated token
        pat = rs.randint(0, cfg.vocab, (4,))
        return {0: np.tile(pat, 3), 1: np.tile(pat[::-1], 2)}
    return {0: rs.randint(0, cfg.vocab, (9,)),
            1: rs.randint(0, cfg.vocab, (5,))}


def _engine(setup, **kw):
    cfg, ctx, params, mesh = setup
    kw.setdefault("batch", 2)
    kw.setdefault("max_len", 32)
    return Engine(cfg, ctx, params, mesh, **kw)


def _drain(eng, block=3):
    while eng.live.any() or eng.waiting:
        eng.step_many(block)
    return [list(o) if o is not None else None for o in eng.outputs]


# ===========================================================================
class TestVerifyTokensOp:
    """Fused == ref, plus the acceptance-rule semantics."""

    def _case(self, seed, b, k, v, greedy_frac=0.5):
        rs = np.random.RandomState(seed)
        logits = jnp.asarray(rs.randn(b, k + 1, v), jnp.float32)
        draft = jnp.asarray(rs.randint(0, v, (b, k)), jnp.int32)
        temp = jnp.asarray(np.where(rs.rand(b) < greedy_frac, 0.0,
                                    rs.rand(b) * 1.5 + 0.1), jnp.float32)
        top_k = jnp.asarray(rs.randint(0, v + 1, (b,)), jnp.int32)
        key = jax.random.PRNGKey(seed)
        return logits, draft, temp, top_k, key

    @given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 5),
           k=st.integers(1, 6), v=st.integers(4, 40))
    @settings(max_examples=20, deadline=None)
    def test_fused_matches_ref(self, seed, b, k, v):
        logits, draft, temp, top_k, key = self._case(seed, b, k, v)
        for kk in (key, None):
            nf, af = verify_tokens_fused(logits, draft, temp, top_k, kk)
            nr, ar = verify_tokens_ref(logits, draft, temp, top_k, kk)
            np.testing.assert_array_equal(np.asarray(nf), np.asarray(nr))
            np.testing.assert_array_equal(np.asarray(af), np.asarray(ar))

    @given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 4),
           k=st.integers(1, 5), v=st.integers(4, 30))
    @settings(max_examples=20, deadline=None)
    def test_greedy_chain_property(self, seed, b, k, v):
        """Greedy verification commits exactly the leading argmax-chain
        matches and holds the first uncommitted chain token."""
        logits, draft, _, _, _ = self._case(seed, b, k, v)
        nt, na = verify_tokens_fused(logits, draft,
                                     jnp.zeros((b,)), jnp.zeros((b,),
                                                                jnp.int32),
                                     None)
        gl, dr = np.asarray(logits), np.asarray(draft)
        for i in range(b):
            chain = np.argmax(gl[i], axis=-1)           # (k+1,)
            a = 0
            while a < k and dr[i, a] == chain[a]:
                a += 1
            assert int(na[i]) == a + 1
            assert int(nt[i]) == chain[a]

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_n_advance_bounds_and_validity(self, seed):
        b, k, v = 4, 5, 16
        logits, draft, temp, top_k, key = self._case(seed, b, k, v,
                                                     greedy_frac=0.3)
        nt, na = verify_tokens_fused(logits, draft, temp, top_k, key)
        assert ((np.asarray(na) >= 1) & (np.asarray(na) <= k + 1)).all()
        assert ((np.asarray(nt) >= 0) & (np.asarray(nt) < v)).all()

    def test_registry_dispatch(self):
        logits, draft, temp, top_k, key = self._case(3, 2, 3, 8)
        a = verify_tokens(logits, draft, temp, top_k, key, backend="ref")
        bq = verify_tokens(logits, draft, temp, top_k, key,
                           backend="pallas")
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(bq[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(bq[1]))

    def test_deterministic_under_jit_and_scan(self):
        logits, draft, temp, top_k, key = self._case(9, 3, 4, 12,
                                                     greedy_frac=0.0)
        eager = verify_tokens_fused(logits, draft, temp, top_k, key)
        jitted = jax.jit(verify_tokens_fused)(logits, draft, temp, top_k,
                                              key)

        def body(c, _):
            return c, verify_tokens_fused(logits, draft, temp, top_k, key)

        _, scanned = jax.lax.scan(body, 0, jnp.arange(2))
        for got in (jitted, (scanned[0][0], scanned[1][0])):
            np.testing.assert_array_equal(np.asarray(eager[0]),
                                          np.asarray(got[0]))
            np.testing.assert_array_equal(np.asarray(eager[1]),
                                          np.asarray(got[1]))


# ===========================================================================
class TestDraftNgram:
    def test_copies_continuation_of_latest_match(self):
        hist = jnp.asarray([[5, 6, 7, 8, 5, 6, 0, 0, 0, 0]], jnp.int32)
        # committed: 5 6 7 8 5; cur token 6 at pos 5 → trailing bigram
        # (5, 6) matched at t=1 → draft the continuation 7 8 5
        drafts, h2 = draft_ngram(hist, jnp.asarray([[6]], jnp.int32),
                                 jnp.asarray([5], jnp.int32), 3, 2)
        np.testing.assert_array_equal(np.asarray(drafts), [[7, 8, 5]])
        assert int(h2[0, 5]) == 6          # cur committed into hist

    def test_no_match_falls_back_to_cur(self):
        hist = jnp.asarray([[1, 2, 3, 4, 0, 0, 0, 0]], jnp.int32)
        drafts, _ = draft_ngram(hist, jnp.asarray([[9]], jnp.int32),
                                jnp.asarray([4], jnp.int32), 3, 2)
        np.testing.assert_array_equal(np.asarray(drafts), [[9, 9, 9]])

    def test_short_history_falls_back(self):
        hist = jnp.zeros((1, 8), jnp.int32)
        drafts, _ = draft_ngram(hist, jnp.asarray([[3]], jnp.int32),
                                jnp.asarray([0], jnp.int32), 2, 2)
        np.testing.assert_array_equal(np.asarray(drafts), [[3, 3]])


# ===========================================================================
class TestGreedyEquivalence:
    """Speculative greedy output == the target's argmax stream, for
    every family × quant × cache layout the engine serves."""

    @pytest.mark.parametrize("family,quant,paged", [
        ("lm", "f32", False),
        ("lm", "f32", True),
        pytest.param("lm", "int8", False, marks=pytest.mark.slow),
        pytest.param("lm", "int8", True, marks=pytest.mark.slow),
        pytest.param("ssm", "f32", False, marks=pytest.mark.slow),
        pytest.param("ssm", "f32", True, marks=pytest.mark.slow),
        pytest.param("ssm", "int8", False, marks=pytest.mark.slow),
        pytest.param("ssm", "int8", True, marks=pytest.mark.slow),
        pytest.param("hybrid", "f32", False, marks=pytest.mark.slow),
        pytest.param("hybrid", "f32", True, marks=pytest.mark.slow),
        pytest.param("hybrid", "int8", False, marks=pytest.mark.slow),
        pytest.param("hybrid", "int8", True, marks=pytest.mark.slow),
    ])
    def test_spec_stream_matches_plain_engine(self, family, quant, paged):
        setup = _setup(family, quant)
        kw = dict(paged=True, page_size=8) if paged else {}
        for rep in (False, True):
            prompts = _prompts(setup[0], seed=2, repetitive=rep)
            with use_mesh(setup[3]):
                base = _engine(setup, **kw)
                base.add_requests(prompts, gen_len=10)
                base.step_many(10)

                spec = _engine(setup, spec=True, spec_k=3, **kw)
                spec.add_requests(prompts, gen_len=10)
                while spec.live.any():
                    spec.step_many(2)
            assert spec.outputs == base.outputs, \
                f"greedy divergence (repetitive={rep})"
            np.testing.assert_array_equal(spec.pos, base.pos)
            np.testing.assert_array_equal(spec.live, base.live)

    def test_repetitive_stream_accepts_drafts(self):
        """On the repetitive workload the prompt-lookup drafter must
        actually land accepted drafts (otherwise the equivalence tests
        only ever exercise the full-rejection path)."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=2, repetitive=True)
        with use_mesh(setup[3]):
            spec = _engine(setup, spec=True, spec_k=3)
            spec.add_requests(prompts, gen_len=12)
            while spec.live.any():
                spec.step_many(2)
        assert spec.stats()["accepted_per_step"] > 0.5

    def test_block_split_invariance_greedy(self):
        """Cutting the same generation into different spec-block sizes
        changes nothing (scan-carry correctness across host syncs)."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=4, repetitive=True)
        with use_mesh(setup[3]):
            a = _engine(setup, spec=True, spec_k=3)
            a.add_requests(prompts, gen_len=12)
            while a.live.any():
                a.step_many(4)
            b = _engine(setup, spec=True, spec_k=3)
            b.add_requests(prompts, gen_len=12)
            while b.live.any():
                b.step_many(1)
        assert a.outputs == b.outputs

    def test_eos_inside_accepted_drafts_kills_slot(self):
        """An EOS that arrives as an *accepted draft* mid-round stops
        the stream exactly where sequential decode would."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=2, repetitive=True)
        with use_mesh(setup[3]):
            probe = _engine(setup, spec=True, spec_k=3)
            probe.add_requests({0: prompts[0]}, gen_len=12)
            while probe.live.any():
                probe.step_many(2)
            stream = probe.outputs[0]
            cut = next((i for i in range(1, len(stream))
                        if stream[i] not in stream[:i]), None)
            if cut is None:
                pytest.skip("stream has no fresh token to use as eos")
            eos = stream[cut]

            base = _engine(setup, eos_id=eos)
            base.add_requests({0: prompts[0]}, gen_len=12)
            base.step_many(12)
            spec = _engine(setup, spec=True, spec_k=3, eos_id=eos)
            spec.add_requests({0: prompts[0]}, gen_len=12)
            while spec.live.any():
                spec.step_many(2)
        assert spec.outputs[0] == base.outputs[0] == stream[:cut]
        assert not spec.live[0]


# ===========================================================================
class TestAdversarialDrafter:
    """A drafter that proposes garbage must cost correctness nothing:
    ≥ 1 committed token per live round, byte-identical output (which is
    the proof that rejected tokens' KV writes / recurrent-state
    consumption were fully rolled back), isolated neighbours."""

    @staticmethod
    def _wrong(hist, tok, pos, k=3, vocab=512):
        # shift-by-prime proposals: essentially never the argmax
        j = jnp.arange(1, k + 1, dtype=jnp.int32)[None, :]
        return (tok + 7919 * j) % vocab

    @pytest.mark.parametrize("family,paged", [
        ("lm", False),
        ("lm", True),
        pytest.param("ssm", False, marks=pytest.mark.slow),
        pytest.param("ssm", True, marks=pytest.mark.slow),
        pytest.param("hybrid", False, marks=pytest.mark.slow),
        pytest.param("hybrid", True, marks=pytest.mark.slow),
    ])
    def test_full_rejection_degrades_to_plain_decode(self, family, paged):
        setup = _setup(family)
        kw = dict(paged=True, page_size=8) if paged else {}
        prompts = _prompts(setup[0], seed=5)
        with use_mesh(setup[3]):
            base = _engine(setup, **kw)
            base.add_requests(prompts, gen_len=8)
            base.step_many(8)

            spec = _engine(setup, spec=True, spec_k=3,
                           drafter_fn=self._wrong, **kw)
            spec.add_requests(prompts, gen_len=8)
            rounds = 0
            while spec.live.any():
                spec.step_many(1)
                rounds += 1
        assert spec.outputs == base.outputs
        st = spec.stats()
        # every live round commits at least one token...
        assert st["gen_tokens"] >= st["verify_steps"]
        # ...and with this drafter, at most barely more (full rejection)
        assert st["accepted_per_step"] <= 0.25
        assert rounds <= 8

    def test_recycled_slot_after_rejections_starts_clean(self):
        """finish() + re-admission under speculation: the new request
        must see none of the previous occupant's state, and the live
        neighbour must be undisturbed (same invariants as the plain
        decode loop, now with k+1-row writes per round)."""
        setup = _setup("lm")
        cfg = setup[0]
        rs = np.random.RandomState(6)
        p_old, p_live, p_new = (rs.randint(0, cfg.vocab, (n,))
                                for n in (7, 6, 8))
        with use_mesh(setup[3]):
            eng = _engine(setup, spec=True, spec_k=3)
            eng.add_requests({0: p_old, 1: p_live}, gen_len=12)
            eng.step_many(2)
            eng.finish(0)
            eng.add_requests({0: p_new}, gen_len=6)
            while eng.live.any():
                eng.step_many(2)

            solo = _engine(setup, spec=True, spec_k=3)
            solo.add_requests({0: p_new}, gen_len=6)
            while solo.live.any():
                solo.step_many(2)

            undisturbed = _engine(setup, spec=True, spec_k=3)
            undisturbed.add_requests({0: p_old, 1: p_live}, gen_len=12)
            while undisturbed.live.any():
                undisturbed.step_many(2)
        assert eng.outputs[0] == solo.outputs[0]
        assert eng.outputs[1] == undisturbed.outputs[1]


# ===========================================================================
class TestModelDrafter:
    @pytest.mark.parametrize("draft_family", [
        "lm",
        pytest.param("ssm", marks=pytest.mark.slow),
    ])
    def test_draft_model_preserves_greedy_stream(self, draft_family):
        """A second-model drafter (KV or recurrent) with different
        weights: partial acceptance, identical output — exercising the
        drafter's own family-aware rollback path."""
        setup = _setup("lm")
        cfg, ctx, params, mesh = setup
        d_cfg = get_config(ARCHS[draft_family]).smoke()
        assert d_cfg.vocab == cfg.vocab
        d_params = get_family(d_cfg).init(jax.random.PRNGKey(11), d_cfg)
        prompts = _prompts(cfg, seed=7)
        with use_mesh(mesh):
            base = _engine(setup)
            base.add_requests(prompts, gen_len=8)
            base.step_many(8)

            spec = _engine(setup, spec=True, spec_k=3,
                           spec_draft=(d_cfg, d_params, ctx))
            spec.add_requests(prompts, gen_len=8)
            while spec.live.any():
                spec.step_many(2)
        assert spec.outputs == base.outputs

    def test_vocab_mismatch_rejected(self):
        import dataclasses
        setup = _setup("lm")
        cfg, ctx, params, mesh = setup
        d_cfg = dataclasses.replace(get_config("gemma-2b").smoke(),
                                    vocab=cfg.vocab + 1)
        with use_mesh(mesh):
            with pytest.raises(ValueError, match="vocab"):
                _engine(setup, spec=True,
                        spec_draft=(d_cfg, None, ctx))


# ===========================================================================
class TestSampledSpec:
    def test_deterministic_and_block_split_invariant(self):
        """Sampled speculation is reproducible under a fixed seed and
        invariant to how rounds are cut into blocks (per-round fold_in,
        same contract as the plain decode loop)."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=8, repetitive=True)
        outs = []
        for blocks in ([4], [1, 1, 1, 1], [2, 2]):
            with use_mesh(setup[3]):
                eng = _engine(setup, spec=True, spec_k=3, seed=13)
                eng.add_requests(prompts, gen_len=10,
                                 temperature={0: 0.9, 1: 1.2},
                                 top_k={0: 7, 1: 0})
                for nb in blocks:
                    eng.step_many(nb)
                while eng.live.any():
                    eng.step_many(1)
            outs.append([list(o) for o in eng.outputs])
        assert outs[0] == outs[1] == outs[2]

    def test_mixed_batch_keeps_greedy_lane_exact(self):
        """One spec batch mixing a greedy and a sampled slot: the greedy
        lane must still be byte-identical to the non-speculative engine
        (the sampled lane's noise consumption must not leak into it)."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=11, repetitive=True)
        kw = dict(gen_len=10, temperature={0: 0.0, 1: 1.1},
                  top_k={0: 0, 1: 5})
        with use_mesh(setup[3]):
            spec = _engine(setup, spec=True, spec_k=3, seed=5)
            spec.add_requests(prompts, **kw)
            while spec.live.any():
                spec.step_many(2)
            base = _engine(setup, seed=5)
            base.add_requests(prompts, **kw)
            base.step_many(10)
        assert spec.outputs[0] == base.outputs[0]
        assert len(spec.outputs[1]) == 10

    def test_top_k_one_equals_greedy_stream(self):
        """top_k=1 collapses the sampled path onto the argmax chain —
        the speculative sampled stream must equal the greedy one."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=9, repetitive=True)
        with use_mesh(setup[3]):
            greedy = _engine(setup, spec=True, spec_k=3)
            greedy.add_requests(prompts, gen_len=10)
            while greedy.live.any():
                greedy.step_many(2)
            sampled = _engine(setup, spec=True, spec_k=3)
            sampled.add_requests(prompts, gen_len=10, temperature=0.7,
                                 top_k=1)
            while sampled.live.any():
                sampled.step_many(2)
        assert sampled.outputs == greedy.outputs


# ===========================================================================
class TestTelemetry:
    def test_stats_and_request_log(self):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=10, repetitive=True)
        with use_mesh(setup[3]):
            eng = _engine(setup, spec=True, spec_k=3)
            for s, p in prompts.items():
                eng.submit(p, gen_len=6)
            eng.try_admit()
            while eng.live.any() or eng.waiting:
                eng.step_many(2)
            eng.retire_finished()
        st = eng.stats()
        assert st["requests"] == 2 and st["admitted"] == 2
        assert st["gen_tokens"] == 12
        assert st["decode_tok_per_s"] > 0
        assert st["verify_steps"] > 0
        assert 0 <= st["accepted_per_step"] <= 3
        assert len(eng.request_log) == 2
        for row in eng.request_log:
            assert row["ttft_s"] >= 0 and row["gen_tokens"] == 6

    def test_drafter_without_spec_rejected(self):
        """A drafter with spec=False would silently never run — the
        engine must refuse the inconsistent combination."""
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            with pytest.raises(ValueError, match="spec"):
                _engine(setup, drafter_fn=lambda h, t, p: t)

    def test_deferred_retirement_does_not_skew_throughput(self):
        """finish() long after generation ended must report the decode
        window (admission → live drop), not the idle gap."""
        import time as _time
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], seed=14)
        with use_mesh(setup[3]):
            eng = _engine(setup)
            eng.add_requests({0: prompts[0]}, gen_len=4)
            eng.step_many(4)               # jit warmup round
            eng.finish(0)
            eng.add_requests({0: prompts[0]}, gen_len=4)
            eng.step_many(4)
            assert not eng.live[0]
            _time.sleep(0.3)               # idle gap before retirement
            eng.finish(0)
        row = eng.request_log[-1]
        assert row["decode_s"] < 0.25, \
            f"idle gap leaked into decode_s ({row['decode_s']:.3f}s)"

    def test_spec_with_continuous_batching(self):
        """More requests than lanes, through the admission queue, under
        speculation: every request's stream matches the non-speculative
        engine's (retirement timing differs, so compare as sets)."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        rs = np.random.RandomState(12)
        prompts = [rs.randint(0, cfg.vocab, (4 + (i % 3),))
                   for i in range(5)]
        with use_mesh(setup[3]):
            base = _engine(setup)
            for p in prompts:
                base.submit(p, gen_len=6)
            base.try_admit()
            _drain(base, block=4)
            base.retire_finished()

            spec = _engine(setup, spec=True, spec_k=3)
            for p in prompts:
                spec.submit(p, gen_len=6)
            spec.try_admit()
            _drain(spec, block=2)
            spec.retire_finished()
        assert sorted(map(tuple, spec.done)) == sorted(map(tuple,
                                                           base.done))
