"""The roofline's HLO analyzer: loop correction, dot flops, collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import HW, RooflineReport, roofline


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestLoopCorrection:
    def test_scan_equals_unroll(self):
        W = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)
        X = jax.ShapeDtypeStruct((64, 128), jnp.float32)

        def body(x, w):
            return jnp.tanh(x @ w), None

        def scanned(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        def unrolled(x, ws):
            for i in range(16):
                x, _ = body(x, ws[i])
            return x

        fs = analyze_hlo(_compile(scanned, X, W).as_text(), 1).flops
        fu = analyze_hlo(_compile(unrolled, X, W).as_text(), 1).flops
        assert abs(fs - fu) / fu < 0.01
        expected = 2 * 64 * 128 * 128 * 16
        assert abs(fs - expected) / expected < 0.02

    def test_nested_scans_multiply(self):
        W = jax.ShapeDtypeStruct((4, 8, 32, 32), jnp.float32)
        X = jax.ShapeDtypeStruct((16, 32), jnp.float32)

        def inner(x, w):
            return x @ w, None

        def outer(x, ws):
            def step(x, wstack):
                return jax.lax.scan(inner, x, wstack)[0], None
            return jax.lax.scan(step, x, ws)[0]

        f = analyze_hlo(_compile(outer, X, W).as_text(), 1).flops
        expected = 2 * 16 * 32 * 32 * 8 * 4
        assert abs(f - expected) / expected < 0.05

    def test_dot_general_batched(self):
        A = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
        B = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)

        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        flops = analyze_hlo(_compile(f, A, B).as_text(), 1).flops
        expected = 2 * 4 * 64 * 32 * 16
        assert abs(flops - expected) / expected < 0.02


class TestCollectiveParsing:
    HLO = """
HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main.1 (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256]{1,0} parameter(0)
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%p), replica_groups=[16,16]<=[256], to_apply=%add
  %all-gather.2 = f32[128,4096]{1,0} all-gather(%all-reduce.1), replica_groups=[16,16]<=[256], dimensions={1}
  ROOT %collective-permute.3 = f32[128,256]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
}
"""

    def test_wire_bytes_ring_model(self):
        a = analyze_hlo(self.HLO, 256)
        kinds = {c["kind"]: c for c in a.collectives}
        t_ar = 128 * 256 * 4
        assert kinds["all-reduce"]["wire_bytes"] == pytest.approx(
            2 * t_ar * 15 / 16)
        t_ag = 128 * 4096 * 4
        assert kinds["all-gather"]["wire_bytes"] == pytest.approx(
            t_ag * 15 / 16)
        assert kinds["collective-permute"]["wire_bytes"] == \
            pytest.approx(128 * 256 * 4)


class TestRooflineReport:
    def test_terms_and_bottleneck(self):
        rep = RooflineReport(
            arch="x", shape="train_4k", mesh="single", chips=256,
            flops_per_chip=197e12, bytes_per_chip=819e9,
            wire_bytes_per_chip=0.0, bytes_all_per_chip=1e12,
            compute_s=1.0, memory_s=1.0, collective_s=0.1,
            model_flops=197e12 * 256 * 0.5)
        assert rep.bottleneck in ("compute", "memory")
        assert rep.step_time == 1.0
        assert rep.mfu == pytest.approx(0.5)

    def test_roofline_from_text(self):
        rep = roofline(arch="t", shape="s", mesh="single", chips=256,
                       cost={"flops": 1.0},
                       hlo_text=TestCollectiveParsing.HLO,
                       model_flops=1e12)
        assert rep.collective_s > 0
        assert rep.raw_cost_analysis["flops"] == 1.0
