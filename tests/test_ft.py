"""Fault tolerance: injected failures, restore-and-replay, stragglers."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.ft import FaultInjector, ResilientLoop, StragglerMonitor


def quad_step(state, batch):
    """Tiny quadratic-descent 'training' step with deterministic data."""
    w = state["params"]
    g = 2 * (w - batch["target"])
    w2 = w - 0.1 * g
    loss = jnp.sum((w2 - batch["target"]) ** 2)
    return ({"params": w2, "step": state["step"] + 1},
            {"loss": loss})


def batch_fn(step):
    return {"target": jnp.asarray(float(step % 3), jnp.float32)}


class TestResilientLoop:
    def test_fault_recovery_resumes_from_checkpoint(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        loop = ResilientLoop(quad_step, batch_fn, mgr, checkpoint_every=5,
                             fault_injector=FaultInjector(fail_at=[12, 23]))
        state = {"params": jnp.asarray(10.0), "step": jnp.asarray(0)}
        out = loop.run(state, num_steps=30)
        assert out["restores"] == 2
        assert int(out["step"]) == 30
        assert np.isfinite(float(out["metrics"]["loss"]))

    def test_no_checkpoint_to_restore_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        loop = ResilientLoop(quad_step, batch_fn, mgr, checkpoint_every=100,
                             fault_injector=FaultInjector(fail_at=[0]))
        state = {"params": jnp.asarray(1.0), "step": jnp.asarray(0)}
        with pytest.raises(RuntimeError):
            loop.run(state, num_steps=5)

    def test_max_restores_enforced(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))

        def nan_step(state, batch):
            return state, {"loss": jnp.asarray(float("nan"))}

        loop = ResilientLoop(nan_step, batch_fn, mgr, checkpoint_every=1,
                             max_restores=3)
        state = {"params": jnp.asarray(1.0), "step": jnp.asarray(0)}
        # first step checkpoints? no — nan raises before any checkpoint;
        # seed one checkpoint manually so restores can proceed
        mgr.save(state, 0, blocking=True)
        with pytest.raises(FloatingPointError):
            loop.run(state, num_steps=10)

    def test_deterministic_replay(self, tmp_path):
        """Restored run produces the same final state as an unfailed run
        (data pipeline is a pure function of step)."""
        mgr1 = CheckpointManager(str(tmp_path / "a"))
        clean = ResilientLoop(quad_step, batch_fn, mgr1,
                              checkpoint_every=4)
        s0 = {"params": jnp.asarray(5.0), "step": jnp.asarray(0)}
        out_clean = clean.run(dict(s0), num_steps=20)

        mgr2 = CheckpointManager(str(tmp_path / "b"))
        faulty = ResilientLoop(quad_step, batch_fn, mgr2,
                               checkpoint_every=4,
                               fault_injector=FaultInjector(fail_at=[9, 17]))
        out_faulty = faulty.run(dict(s0), num_steps=20)
        np.testing.assert_allclose(
            float(out_clean["state"]["params"]),
            float(out_faulty["state"]["params"]), rtol=1e-6)


class TestStraggler:
    def test_detects_persistent_straggler(self):
        fired = []
        mon = StragglerMonitor(ratio=1.5, patience=2,
                               on_straggler=lambda s, d: fired.append(s))
        for i in range(16):
            mon.record(i, 0.1)
        for i in range(16, 20):
            mon.record(i, 0.5)
        assert fired, "straggler not detected"

    def test_tolerates_single_blip(self):
        mon = StragglerMonitor(ratio=1.5, patience=3)
        for i in range(16):
            mon.record(i, 0.1)
        assert not mon.record(16, 0.9)   # one slow step: no mitigation
        for i in range(17, 30):
            assert not mon.record(i, 0.1)
        assert mon.events == []
