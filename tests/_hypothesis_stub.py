"""Minimal deterministic stand-in for `hypothesis` (not installed in the
CI container; no new deps allowed).

Implements exactly the API surface the test-suite uses — ``given``,
``settings``, and the ``integers / floats / lists / sampled_from / just /
builds`` strategies — as a seeded random sampler.  Each decorated test
runs ``max_examples`` times with examples drawn from a fixed-seed RNG, so
runs are reproducible (no shrinking, no database).  If the real
hypothesis is ever installed, conftest prefers it and this module is
never imported.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np

__all__ = ["given", "settings", "strategies"]


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: np.random.RandomState):
        return self._sample(rng)

    def filter(self, pred, _max_tries: int = 1000):
        def sample(rng):
            for _ in range(_max_tries):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate never satisfied")
        return _Strategy(sample)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._sample(rng)))


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.randint(min_value,
                                                     max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(lo + (hi - lo) * rng.rand()))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            n = int(rng.randint(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(n)]
        return _Strategy(sample)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.randint(len(seq)))])

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)

    @staticmethod
    def builds(target, **kwargs):
        def sample(rng):
            return target(**{k: v.sample(rng) for k, v in kwargs.items()})
        return _Strategy(sample)


strategies = _Strategies()


def settings(max_examples: int = 20, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        sig = inspect.signature(fn)
        names = [p for p in sig.parameters]
        # strategies bind to the trailing positional params (after self)
        n_pos = len(strats)
        bound = (names[-n_pos:] if n_pos else []) + list(kw_strats)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 20)
            rng = np.random.RandomState(0)
            for _ in range(n):
                drawn = [s.sample(rng) for s in strats]
                kw = {k: s.sample(rng) for k, s in kw_strats.items()}
                fn(*args, *drawn, **kw, **kwargs)

        # hide strategy params from pytest's fixture resolution
        kept = [p for name, p in sig.parameters.items() if name not in bound]
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper
    return deco
