"""SLO priority classes: a scheduling property, never a sampling one.

The PR 9 tentpole pins four contracts:

* **Coercion/validation** — unknown class names, out-of-range values
  and malformed per-class SLO targets are rejected at the API boundary
  (``submit``/``add_requests``/engine construction), PR 6 style.
* **Admission order** — the queue serves REALTIME > STANDARD > BATCH,
  FIFO within a class; a page-blocked head still blocks every lower
  class (no skipping downward); with a single class the queue is
  byte-for-byte the old FIFO.
* **Victim order** — preempt-and-spill ranks victims by class before
  deadline slack, and the preempting head's class is a floor: a BATCH
  admission can never spill a REALTIME stream.
* **Observability** — per-class counters and latency percentiles in
  ``Engine.stats()``; straggler blocks attribute to the classes that
  were actually decoding through them; SLO-risk shedding charges the
  at-risk class.

Everything here is scheduling-shape only: greedy token streams must be
identical (as a multiset; completion ORDER legitimately changes) to an
unprioritized engine's.
"""

import numpy as np
import pytest

from repro.dist.constrain import use_mesh
from repro.ft import ServingFaultInjector, StragglerMonitor
from repro.launch.lifecycle import (PriorityClass, RequestStatus,
                                    coerce_priority, normalize_slo_targets)

from test_paged_serving import _prompts, _setup
from test_serving_lifecycle import FakeClock, _drain, _engine

RT, STD, BATCH = (PriorityClass.REALTIME, PriorityClass.STANDARD,
                  PriorityClass.BATCH)


# ===========================================================================
class TestCoercion:
    def test_accepts_enum_name_and_int(self):
        assert coerce_priority(RT) is RT
        assert coerce_priority("batch") is BATCH
        assert coerce_priority("ReAlTiMe") is RT        # any case
        assert coerce_priority(1) is STD
        assert coerce_priority(np.int64(2)) is BATCH

    def test_none_defaults_to_standard(self):
        assert coerce_priority(None) is STD

    def test_unknown_name_rejected_with_choices(self):
        with pytest.raises(ValueError, match="realtime"):
            coerce_priority("urgent")

    def test_out_of_range_int_rejected(self):
        with pytest.raises(ValueError, match="out of range"):
            coerce_priority(3)
        with pytest.raises(ValueError, match="out of range"):
            coerce_priority(-1)

    def test_garbage_types_rejected(self):
        # bool is an int subclass but True-as-STANDARD would be a silent
        # caller bug, not a convenience
        for bad in (True, 1.5, [0], {"cls": 0}):
            with pytest.raises(ValueError, match="priority"):
                coerce_priority(bad)

    def test_ordering_is_load_bearing(self):
        """Lower value = more important; scheduling compares directly."""
        assert RT < STD < BATCH


class TestSloTargetValidation:
    def test_normalizes_keys_to_classes(self):
        out = normalize_slo_targets(
            {"realtime": {"ttft_s": 0.5}, BATCH: {"tok_per_s": 3}})
        assert out == {RT: {"ttft_s": 0.5}, BATCH: {"tok_per_s": 3.0}}

    def test_empty_and_none_targets_drop_out(self):
        assert normalize_slo_targets(None) == {}
        assert normalize_slo_targets({"realtime": None}) == {}
        assert normalize_slo_targets(
            {"realtime": {"ttft_s": None}}) == {}

    def test_unknown_target_keys_rejected(self):
        with pytest.raises(ValueError, match="unknown SLO target"):
            normalize_slo_targets({"realtime": {"p99": 1.0}})

    def test_non_positive_targets_rejected(self):
        with pytest.raises(ValueError, match="positive"):
            normalize_slo_targets({"realtime": {"ttft_s": 0.0}})
        with pytest.raises(ValueError, match="positive"):
            normalize_slo_targets({"batch": {"tok_per_s": -1}})

    def test_non_dict_target_rejected(self):
        with pytest.raises(ValueError, match="must be a dict"):
            normalize_slo_targets({"realtime": 0.5})


# ===========================================================================
class TestAdmissionOrder:
    def test_realtime_overtakes_fifo(self):
        """Three queued classes, one lane: the lane serves REALTIME
        first although it was submitted LAST."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        prompts = _prompts(cfg, (5, 5, 5), seed=11)
        with use_mesh(setup[3]):
            eng = _engine(setup, batch=1)
            rid_b = eng.submit(prompts[0], gen_len=2, priority="batch")
            rid_s = eng.submit(prompts[1], gen_len=2)   # standard
            rid_r = eng.submit(prompts[2], gen_len=2, priority="realtime")
            eng.try_admit()
            assert eng.status(rid_r) is RequestStatus.RUNNING
            assert eng.status(rid_s) is RequestStatus.QUEUED
            _drain(eng, block=2)
        # completion order follows class order, not submission order
        order = [eng.results[r]["tokens"] for r in (rid_r, rid_s, rid_b)]
        assert order == eng.done

    def test_fifo_within_class(self):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (5, 5, 5), seed=12)
        with use_mesh(setup[3]):
            eng = _engine(setup, batch=1)
            ids = [eng.submit(p, gen_len=2, priority="batch")
                   for p in prompts]
            _drain(eng, block=2)
        assert [eng.results[r]["tokens"] for r in ids] == eng.done

    def test_blocked_head_blocks_lower_classes(self):
        """A page-blocked REALTIME head must NOT be starved by a small
        BATCH request slipping into the pages it is waiting for."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        prompts = _prompts(cfg, (10, 3), seed=13)
        with use_mesh(setup[3]):
            # pool of 6 pages: the running request holds 3 (6+4+1
            # rows), the big REALTIME head needs 5 and blocks on the 3
            # free; the tiny BATCH request (2 pages) would fit in them
            # but must wait behind the blocked head
            eng = _engine(setup, batch=3, paged=True, page_size=4,
                          num_pages=6)
            rid_live = eng.submit(_prompts(cfg, (6,), seed=9)[0],
                                  gen_len=4)
            eng.try_admit()
            assert eng.status(rid_live) is RequestStatus.RUNNING
            rid_rt = eng.submit(prompts[0], gen_len=8, priority="realtime")
            rid_bat = eng.submit(prompts[1], gen_len=2, priority="batch")
            eng.try_admit()
            assert eng.status(rid_rt) is RequestStatus.QUEUED
            assert eng.status(rid_bat) is RequestStatus.QUEUED
            _drain(eng)
        for rid in (rid_live, rid_rt, rid_bat):
            assert eng.status(rid) is RequestStatus.COMPLETED

    def test_single_class_queue_is_the_old_fifo(self):
        """Conformance safety net: when every request shares one class
        the priority queue degenerates to the seed FIFO — identical
        streams in identical order."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=0)

        def serve(prio):
            with use_mesh(setup[3]):
                eng = _engine(setup, max_len=32)
                for p in prompts:
                    eng.submit(p, gen_len=6, priority=prio)
                _drain(eng)
            return eng.done

        base = serve(None)
        for prio in ("realtime", "batch"):
            assert serve(prio) == base

    def test_mixed_classes_keep_stream_content(self):
        """Priorities reorder completions, never change token bytes."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=0)
        with use_mesh(setup[3]):
            base = _engine(setup, max_len=32)
            for p in prompts:
                base.submit(p, gen_len=6)
            _drain(base)
            pri = _engine(setup, max_len=32)
            for p, cls in zip(prompts, ("batch", "realtime", "standard",
                                        "batch")):
                pri.submit(p, gen_len=6, priority=cls)
            _drain(pri)
        assert sorted(pri.done) == sorted(base.done)


# ===========================================================================
class TestVictimOrder:
    def _pressure_engine(self, setup, **kw):
        kw.setdefault("paged", True)
        kw.setdefault("page_size", 4)
        kw.setdefault("num_pages", 8)
        kw.setdefault("preempt", True)
        kw.setdefault("preempt_after", 1)
        kw.setdefault("max_len", 24)
        return _engine(setup, **kw)

    def test_batch_spills_before_realtime(self):
        """Running BATCH + REALTIME, a STANDARD head escalates: the
        BATCH victim loses its pages, the REALTIME stream keeps every
        one."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        with use_mesh(setup[3]):
            eng = self._pressure_engine(setup, batch=3)
            rid_bat = eng.submit(_prompts(cfg, (8,), seed=14)[0],
                                 gen_len=6, priority="batch")
            rid_rt = eng.submit(_prompts(cfg, (8,), seed=15)[0],
                                gen_len=6, priority="realtime")
            eng.try_admit()      # both run: 3+3 of 8 pages
            rid_std = eng.submit(_prompts(cfg, (10,), seed=16)[0],
                                 gen_len=6, priority="standard")
            eng.try_admit()      # head needs 5 pages, 2 free: escalate
            assert eng.status(rid_bat) is RequestStatus.PREEMPTED
            assert eng.status(rid_rt) is RequestStatus.RUNNING
            assert eng.status(rid_std) is RequestStatus.RUNNING
            assert eng.class_counters[BATCH]["preemptions"] == 1
            assert eng.class_counters[RT]["preemptions"] == 0
            _drain(eng)
        for rid in (rid_bat, rid_rt, rid_std):
            assert eng.status(rid) is RequestStatus.COMPLETED

    def test_class_floor_lower_head_cannot_spill_higher(self):
        """A BATCH head blocked on pages held ONLY by more important
        classes never escalates past them — it waits for a natural
        retire instead of spilling work the operator paid more for."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        with use_mesh(setup[3]):
            eng = self._pressure_engine(setup, batch=3)
            rid_rt = eng.submit(_prompts(cfg, (8,), seed=17)[0],
                                gen_len=6, priority="realtime")
            rid_std = eng.submit(_prompts(cfg, (8,), seed=18)[0],
                                 gen_len=6)
            eng.try_admit()
            rid_bat = eng.submit(_prompts(cfg, (10,), seed=19)[0],
                                 gen_len=6, priority="batch")
            for _ in range(4):   # well past preempt_after
                eng.try_admit()
            assert eng.status(rid_bat) is RequestStatus.QUEUED
            assert eng.status(rid_rt) is RequestStatus.RUNNING
            assert eng.status(rid_std) is RequestStatus.RUNNING
            assert eng.counters["preemptions"] == 0
            _drain(eng)
        for rid in (rid_rt, rid_std, rid_bat):
            assert eng.status(rid) is RequestStatus.COMPLETED

    def test_ttft_slo_escalates_immediately(self):
        """A REALTIME head already past its class TTFT target preempts
        on the FIRST blocked sweep — ``preempt_after`` patience is
        budget the SLO says it doesn't have."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        clock = FakeClock()
        with use_mesh(setup[3]):
            eng = self._pressure_engine(
                setup, batch=2, preempt_after=50, clock=clock,
                slo_targets={"realtime": {"ttft_s": 5.0}})
            rid_bat = eng.submit(_prompts(cfg, (8,), seed=20)[0],
                                 gen_len=8, priority="batch")
            eng.try_admit()
            rid_rt = eng.submit(_prompts(cfg, (12,), seed=21)[0],
                                gen_len=8, priority="realtime")
            clock.advance(10.0)          # TTFT target blown in queue
            eng.try_admit()              # sweep 1 << preempt_after
            assert eng.status(rid_rt) is RequestStatus.RUNNING
            assert eng.status(rid_bat) is RequestStatus.PREEMPTED
            _drain(eng)
        assert eng.status(rid_rt) is RequestStatus.COMPLETED
        assert eng.status(rid_bat) is RequestStatus.COMPLETED


# ===========================================================================
class TestSloShed:
    def test_ttft_risk_sheds_speculation_not_streams(self):
        """A queued REALTIME request past its TTFT target puts the
        engine in shed mode: speculation drops (counted, charged to
        the at-risk class) while greedy bytes stay identical to the
        unshedded engine."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (8, 8, 8), seed=22)

        def serve(**kw):
            clock = kw.pop("clock", None)
            with use_mesh(setup[3]):
                eng = _engine(setup, batch=1, max_len=24, spec=True,
                              clock=clock, **kw)
                eng.submit(prompts[0], gen_len=6)
                eng.try_admit()          # the lane is taken FIRST —
                eng.submit(prompts[1], gen_len=6)
                eng.submit(prompts[2], gen_len=6, priority="realtime")
                if clock is not None:    # — so REALTIME queues behind it
                    clock.advance(60.0)  # and blows its TTFT target
                _drain(eng)
            return eng

        base = serve()
        shed = serve(clock=FakeClock(),
                     slo_targets={"realtime": {"ttft_s": 1.0}})
        assert sorted(shed.done) == sorted(base.done)
        assert shed.counters["shed_spec_rounds"] > 0
        assert shed.class_counters[RT]["shed_rounds"] > 0

    def test_no_risk_no_shed(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup, spec=True,
                          slo_targets={"realtime": {"ttft_s": 1e6}})
            eng.submit(_prompts(setup[0], (6,))[0], gen_len=4,
                       priority="realtime")
            _drain(eng)
        assert eng.counters["shed_spec_rounds"] == 0
        assert eng.class_counters[RT]["shed_rounds"] == 0


# ===========================================================================
class TestPerClassStats:
    def test_stats_rows_counters_and_percentiles(self):
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (6, 6, 6), seed=23)
        with use_mesh(setup[3]):
            eng = _engine(setup, clock=FakeClock(tick=0.01))
            for p, cls in zip(prompts, ("realtime", "batch", "batch")):
                eng.submit(p, gen_len=3, priority=cls)
            _drain(eng, block=3)
        st = eng.stats()
        classes = st["classes"]
        assert classes["realtime"]["requests"] == 1
        assert classes["batch"]["requests"] == 2
        assert "standard" not in classes         # no activity, no row
        for row in classes.values():
            assert row["queued"] == 0
            assert row["ttft_p50_s"] <= row["ttft_p99_s"]
        # request_log rows carry the class name for offline aggregation
        assert sorted(r["priority"] for r in eng.request_log) == \
            ["batch", "batch", "realtime"]

    def test_slo_targets_surface_in_stats(self):
        setup = _setup("lm", "f32")
        with use_mesh(setup[3]):
            eng = _engine(setup,
                          slo_targets={"realtime": {"ttft_s": 0.25}})
            eng.submit(_prompts(setup[0], (4,))[0], gen_len=2,
                       priority="realtime")
            _drain(eng, block=2)
        assert eng.stats()["slo_targets"] == {
            "realtime": {"ttft_s": 0.25}}

    def test_straggler_blocks_attribute_to_running_classes(self):
        """An injected-slow block is charged to the classes DECODING
        through it — the classes whose latency actually paid — and not
        to classes that were merely queued."""
        setup = _setup("lm", "f32")
        cfg = setup[0]
        with use_mesh(setup[3]):
            eng = _engine(
                setup, batch=1,
                fault_injector=ServingFaultInjector({8: "slow"}),
                # ratio far above real scheduling jitter: only the
                # injector's synthetic +1s penalty (~100x a smoke-model
                # block) can flag, so a loaded CI host can't produce a
                # spurious straggler while the BATCH request is running
                straggler=StragglerMonitor(window=8, ratio=50.0,
                                           patience=1))
            # REALTIME runs; BATCH sits queued behind the single lane
            eng.submit(_prompts(cfg, (4,), seed=24)[0], gen_len=12,
                       priority="realtime")
            eng.submit(_prompts(cfg, (4,), seed=25)[0], gen_len=2,
                       priority="batch")
            eng.try_admit()
            for _ in range(20):
                if not (eng.live.any() or eng.waiting):
                    break
                eng.step_many(1)
            eng.retire_finished()
        assert eng.fault_injector.events == [(8, "slow")]
        assert eng.class_counters[RT]["straggler_blocks"] >= 1
        assert eng.class_counters[BATCH]["straggler_blocks"] == 0
        # engine-level counter still carries the block total
        assert eng.stats()["straggler_blocks"] >= 1
