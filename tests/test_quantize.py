"""Quantizer tests: STE gradients, dynamic-range int8, whole-tree PTQ."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.precision import LayerPrecision, PrecisionPolicy
from repro.core.qtypes import E4M3, FixedPointType, QTensor
from repro.core.quantize import (calibrate_scale, dequantize_params,
                                 fake_quant, ptq_params, quantize_dynamic)


class TestSTE:
    def test_identity_gradient_in_range(self):
        t = FixedPointType(8, 3)
        g = jax.grad(lambda x: jnp.sum(fake_quant(x, t)))(
            jnp.asarray([0.5, -2.0, 3.9]))
        np.testing.assert_array_equal(np.asarray(g), [1.0, 1.0, 1.0])

    def test_zero_gradient_out_of_range(self):
        t = FixedPointType(8, 3)  # range ±8
        g = jax.grad(lambda x: jnp.sum(fake_quant(x, t)))(
            jnp.asarray([100.0, -50.0, 1.0]))
        np.testing.assert_array_equal(np.asarray(g), [0.0, 0.0, 1.0])

    def test_minifloat_ste(self):
        g = jax.grad(lambda x: jnp.sum(fake_quant(x, E4M3)))(
            jnp.asarray([1.0, 1000.0]))
        np.testing.assert_array_equal(np.asarray(g), [1.0, 0.0])

    def test_qat_reduces_loss(self):
        """Fake-quant training actually optimizes (STE works end-to-end)."""
        t = FixedPointType(8, 2)
        w = jnp.asarray(np.random.RandomState(0).randn(8, 8), jnp.float32)
        x = jnp.asarray(np.random.RandomState(1).randn(32, 8), jnp.float32)
        y = x @ jnp.asarray(np.random.RandomState(2).randn(8, 8),
                            jnp.float32)

        def loss(w):
            return jnp.mean((x @ fake_quant(w, t) - y) ** 2)

        l0 = float(loss(w))
        for _ in range(60):
            w = w - 0.05 * jax.grad(loss)(w)
        assert float(loss(w)) < 0.5 * l0


class TestDynamicQuant:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 8), st.integers(1, 6))
    def test_roundtrip_error_bound(self, rows, cols):
        t = FixedPointType(8, 1)
        x = jnp.asarray(np.random.RandomState(rows * 7 + cols)
                        .randn(rows, cols).astype(np.float32))
        q = quantize_dynamic(x, t, channel_axes=(1,))
        err = np.abs(np.asarray(q.dequantize()) - np.asarray(x))
        # per-channel scale: error ≤ scale/2 per column
        bound = np.asarray(q.scale)[0] * 0.5 + 1e-7
        assert np.all(err <= bound + 1e-6)

    def test_scale_shapes(self):
        x = jnp.ones((4, 8, 16))
        t = FixedPointType(8, 1)
        assert calibrate_scale(x, t).shape == (1, 1, 1)
        assert calibrate_scale(x, t, channel_axes=(2,)).shape == (1, 1, 16)
        assert calibrate_scale(x, t, channel_axes=(-1,)).shape == (1, 1, 16)


class TestPTQ:
    def test_ptq_tree_roundtrip(self):
        params = {"layer": {"w": jnp.asarray(np.random.RandomState(0)
                                             .randn(16, 8), jnp.float32),
                            "b": jnp.zeros((8,))},
                  "norm": {"scale": jnp.ones((16,))}}
        qp = ptq_params(params, FixedPointType(8, 1))
        assert isinstance(qp["layer"]["w"], QTensor)
        assert qp["layer"]["b"] is params["layer"]["b"]       # untouched
        assert qp["norm"]["scale"] is params["norm"]["scale"]
        deq = dequantize_params(qp)
        err = np.abs(np.asarray(deq["layer"]["w"])
                     - np.asarray(params["layer"]["w"]))
        assert err.max() < 0.05

    def test_ptq_per_layer_policy(self):
        pol = PrecisionPolicy(
            default=LayerPrecision(weights=FixedPointType(8, 1)),
            overrides=(("*critical*", LayerPrecision(weights=None)),))
        params = {"critical_proj": {"w": jnp.ones((4, 4))},
                  "normal": {"w": jnp.ones((4, 4))}}
        qp = ptq_params(params, pol)
        assert not isinstance(qp["critical_proj"]["w"], QTensor)
        assert isinstance(qp["normal"]["w"], QTensor)

    def test_policy_resolution_order(self):
        a, b = LayerPrecision(), LayerPrecision(weights=E4M3)
        pol = PrecisionPolicy(overrides=(("*", a), ("*attn*", b)))
        assert pol.resolve("block/attn/wq").weights is E4M3
        assert pol.resolve("block/mlp/up").weights is None
