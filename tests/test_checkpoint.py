"""Checkpoint store: atomicity, retention, async, elastic restore —
plus the journal's crash-fuzz contract and the follower cursor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (BlobLog, BlobLogFollower, CheckpointManager,
                              latest_step, restore_state, save_state)


def make_state(seed=0):
    rng = np.random.RandomState(seed)
    return {"params": {"w": jnp.asarray(rng.randn(8, 16), jnp.float32),
                       "stack": {"k": jnp.asarray(rng.randn(3, 4, 4),
                                                  jnp.float32)}},
            "opt": {"m": jnp.zeros((8, 16)), "count": jnp.asarray(7)},
            "step": jnp.asarray(100)}


class TestStore:
    def test_roundtrip(self, tmp_path):
        st = make_state()
        save_state(st, str(tmp_path), 100)
        assert latest_step(str(tmp_path)) == 100
        rt = restore_state(st, str(tmp_path), 100)
        for a, b in zip(jax.tree_util.tree_leaves(st),
                        jax.tree_util.tree_leaves(rt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial(self, tmp_path):
        st = make_state()
        save_state(st, str(tmp_path), 1)
        # a stale .tmp directory must never count as a checkpoint
        os.makedirs(tmp_path / "step_00000002.tmp", exist_ok=True)
        assert latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        st = make_state()
        save_state(st, str(tmp_path), 5)
        bad = dict(st, step=jnp.zeros((2,)))
        with pytest.raises(ValueError):
            restore_state(bad, str(tmp_path), 5)


class TestManager:
    def test_async_save_and_restore_latest(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        st = make_state()
        m.save(st, 10)
        m.save(st, 20)
        m.wait()
        restored, step = m.restore_latest(st)
        assert step == 20
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(st["params"]["w"]))

    def test_retention(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        st = make_state()
        for s in (1, 2, 3, 4):
            m.save(st, s, blocking=True)
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert kept == ["step_00000003", "step_00000004"]

    def test_restore_none_when_empty(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        restored, step = m.restore_latest(make_state())
        assert restored is None and step is None

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto an explicit sharding (single-device here; the
        512-device equivalence is exercised by the dry-run path)."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.dist.sharding import named, param_specs
        st = make_state()
        m = CheckpointManager(str(tmp_path))
        m.save(st, 50, blocking=True)
        sh = named(param_specs(st, mesh), mesh)
        restored, step = m.restore_latest(st, shardings=sh)
        assert step == 50
        assert restored["params"]["w"].sharding is not None


# ===========================================================================
def _small_journal(path):
    """A journal of four distinct records, small enough that the fuzz
    sweeps below can afford every single-byte mutation."""
    log = BlobLog(str(path))
    recs = [("submit", {"id": i, "gen_len": 4 + i}) for i in range(3)]
    recs.append(("block", 4))
    for r in recs:
        log.append(r)
    log.close()
    return recs


class TestJournalCrashFuzz:
    """Every byte-level mutation of a journal must yield either a clean
    torn-tail truncation (a strict prefix of the original records) or
    an explicit corruption error — NEVER a silent misparse.  This is
    the promise the standby's byte-identity rests on: a journal that
    opens clean replays true history."""

    def _check(self, path, recs):
        """Open the mutated journal; it must either refuse loudly or
        produce a strict prefix of the true record sequence."""
        try:
            log = BlobLog(str(path))
        except (IOError, OSError):
            return "refused"
        got = log.read()
        log.close()
        assert got == recs[:len(got)], \
            "journal misparsed a mutated file into non-prefix records"
        return "prefix"

    def test_truncation_at_every_byte_offset(self, tmp_path):
        recs = _small_journal(tmp_path / "j.log")
        data = (tmp_path / "j.log").read_bytes()
        outcomes = set()
        for cut in range(len(data) + 1):
            p = tmp_path / f"t{cut}.log"
            p.write_bytes(data[:cut])
            outcomes.add(self._check(p, recs))
        # truncation is exactly what a torn tail looks like: every cut
        # must open as a clean prefix, none may be refused
        assert outcomes == {"prefix"}

    def test_bit_flip_at_every_byte_offset(self, tmp_path):
        recs = _small_journal(tmp_path / "j.log")
        data = bytearray((tmp_path / "j.log").read_bytes())
        outcomes = set()
        for off in range(len(data)):
            mutated = bytearray(data)
            mutated[off] ^= 0x80
            p = tmp_path / f"f{off}.log"
            p.write_bytes(bytes(mutated))
            outcomes.add(self._check(p, recs))
        # both outcomes occur across the sweep (a flip in the last
        # frame's bytes is a torn tail; earlier damage must refuse),
        # and no flip anywhere silently misparses (asserted per-file)
        assert outcomes == {"prefix", "refused"}

    def test_flip_then_append_never_drops_committed_history(self,
                                                            tmp_path):
        """The killer case for a length-bound check alone: a flip that
        ENLARGES a mid-file length field makes everything after it look
        like one giant torn frame.  The resync scan must spot the
        intact committed frames inside the 'tail' and refuse."""
        path = tmp_path / "j.log"
        recs = _small_journal(path)
        data = bytearray(path.read_bytes())
        # enlarge record 0's length field (low byte of the u32)
        data[0] ^= 0x40
        path.write_bytes(bytes(data))
        assert len(recs) == 4                  # all committed, none torn
        with pytest.raises(IOError, match="corrupt"):
            BlobLog(str(path))


class TestBlobLogFollower:
    def test_poll_tails_incremental_appends(self, tmp_path):
        log = BlobLog(str(tmp_path / "j.log"))
        f = log.follow()
        assert f.poll() == []
        log.append("a")
        log.append("b")
        assert f.poll() == ["a", "b"]
        assert f.poll() == []
        log.append("c")
        assert f.poll(max_records=1) == ["c"]
        assert (f.count, log.count) == (3, 3)
        log.close()

    def test_short_frame_is_an_append_in_flight(self, tmp_path):
        """A half-written frame at the tail is NOT an error for a
        follower — the writer is mid-append; the cursor holds and the
        record arrives whole on a later poll."""
        path = tmp_path / "j.log"
        log = BlobLog(str(path))
        log.append("whole")
        log.close()
        with open(path, "ab") as fh:
            fh.write(b"\x40\x00\x00\x00\x99")  # header + 1 of 64 bytes
        f = BlobLogFollower(str(path))
        assert f.poll() == ["whole"]
        assert f.poll() == []                  # waits, no error

    def test_complete_frame_crc_mismatch_raises(self, tmp_path):
        path = tmp_path / "j.log"
        log = BlobLog(str(path))
        log.append("one")
        off = os.path.getsize(path)
        log.append("two" * 10)
        log.close()
        with open(path, "r+b") as fh:
            fh.seek(off + 8)
            b = fh.read(1)
            fh.seek(off + 8)
            fh.write(bytes([b[0] ^ 0xFF]))
        f = BlobLogFollower(str(path))
        with pytest.raises(IOError, match="CRC"):
            f.poll()

    def test_missing_file_polls_empty(self, tmp_path):
        f = BlobLogFollower(str(tmp_path / "nope.log"))
        assert f.poll() == []
