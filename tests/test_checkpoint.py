"""Checkpoint store: atomicity, retention, async, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore_state,
                              save_state)


def make_state(seed=0):
    rng = np.random.RandomState(seed)
    return {"params": {"w": jnp.asarray(rng.randn(8, 16), jnp.float32),
                       "stack": {"k": jnp.asarray(rng.randn(3, 4, 4),
                                                  jnp.float32)}},
            "opt": {"m": jnp.zeros((8, 16)), "count": jnp.asarray(7)},
            "step": jnp.asarray(100)}


class TestStore:
    def test_roundtrip(self, tmp_path):
        st = make_state()
        save_state(st, str(tmp_path), 100)
        assert latest_step(str(tmp_path)) == 100
        rt = restore_state(st, str(tmp_path), 100)
        for a, b in zip(jax.tree_util.tree_leaves(st),
                        jax.tree_util.tree_leaves(rt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial(self, tmp_path):
        st = make_state()
        save_state(st, str(tmp_path), 1)
        # a stale .tmp directory must never count as a checkpoint
        os.makedirs(tmp_path / "step_00000002.tmp", exist_ok=True)
        assert latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        st = make_state()
        save_state(st, str(tmp_path), 5)
        bad = dict(st, step=jnp.zeros((2,)))
        with pytest.raises(ValueError):
            restore_state(bad, str(tmp_path), 5)


class TestManager:
    def test_async_save_and_restore_latest(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        st = make_state()
        m.save(st, 10)
        m.save(st, 20)
        m.wait()
        restored, step = m.restore_latest(st)
        assert step == 20
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(st["params"]["w"]))

    def test_retention(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        st = make_state()
        for s in (1, 2, 3, 4):
            m.save(st, s, blocking=True)
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert kept == ["step_00000003", "step_00000004"]

    def test_restore_none_when_empty(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        restored, step = m.restore_latest(make_state())
        assert restored is None and step is None

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore onto an explicit sharding (single-device here; the
        512-device equivalence is exercised by the dry-run path)."""
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.dist.sharding import named, param_specs
        st = make_state()
        m = CheckpointManager(str(tmp_path))
        m.save(st, 50, blocking=True)
        sh = named(param_specs(st, mesh), mesh)
        restored, step = m.restore_latest(st, shardings=sh)
        assert step == 50
        assert restored["params"]["w"].sharding is not None
