"""Property tests for the flash (blocked online-softmax) attention kernel.

``flash_attention_pallas`` runs in interpret mode on CPU and must match
the ``flash_attention_ref`` oracle across the cases its blocking logic
actually has to handle:

* ``Sq``/``Skv`` that are NOT multiples of the ``bq``/``bk`` block shape
  (the padded-tail mask path);
* queries sitting at the tail of a longer KV context (decode-style
  ``Skv > Sq`` with the diagonal shifted by ``q_off``);
* GQA group sizes > 1 (the BlockSpec ``h // group`` index fold);
* block shapes smaller than, equal to, and larger than the sequence.

Sweeps run through the deterministic hypothesis stub.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def _qkv(b, hq, hkv, sq, skv, d, seed=0):
    rs = np.random.RandomState(seed)
    q = jnp.asarray(rs.randn(b, hq, sq, d), jnp.float32)
    k = jnp.asarray(rs.randn(b, hkv, skv, d), jnp.float32)
    v = jnp.asarray(rs.randn(b, hkv, skv, d), jnp.float32)
    return q, k, v


def _check(q, k, v, *, causal, bq, bk):
    got = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


# ===========================================================================
class TestPaddedTails:
    """Sq/Skv not multiples of the block shape → masked padding rows."""

    @pytest.mark.parametrize("sq,skv,bq,bk", [
        (5, 5, 4, 4),       # one ragged tail block on both axes
        (9, 9, 4, 4),       # tail of 1 — the off-by-one magnet
        (7, 13, 4, 4),      # ragged AND sq != skv (diagonal shifted)
        (3, 17, 8, 8),      # sq smaller than one block
        (13, 13, 16, 16),   # whole sequence inside one padded block
        (6, 11, 4, 8),      # asymmetric block shapes
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_non_multiple_shapes(self, sq, skv, bq, bk, causal):
        q, k, v = _qkv(2, 2, 2, sq, skv, 8)
        _check(q, k, v, causal=causal, bq=bq, bk=bk)

    @settings(max_examples=10)
    @given(st.integers(1, 20), st.integers(0, 12),
           st.sampled_from([2, 4, 8]), st.sampled_from([2, 4, 8]),
           st.integers(0, 2 ** 16))
    def test_sweep_ragged_shapes(self, sq, extra_kv, bq, bk, seed):
        """Random (Sq, Skv >= Sq) against random block shapes: the
        causal diagonal must sit at q_off = Skv - Sq regardless of how
        the blocks tile."""
        skv = sq + extra_kv
        q, k, v = _qkv(1, 2, 1, sq, skv, 8, seed=seed)
        _check(q, k, v, causal=True, bq=bq, bk=bk)


# ===========================================================================
class TestDiagonalBlocks:
    def test_diagonal_mask_within_block(self):
        """bq == bk == Sq: the whole causal mask is elementwise inside
        one diagonal block (no block skipping at all)."""
        q, k, v = _qkv(1, 1, 1, 8, 8, 8, seed=1)
        _check(q, k, v, causal=True, bq=8, bk=8)

    def test_blocks_above_diagonal_are_skipped_correctly(self):
        """Strictly-above-diagonal blocks contribute nothing: a huge
        value planted in a future kv position must not leak."""
        q, k, v = _qkv(1, 1, 1, 8, 8, 4, seed=2)
        v = v.at[0, 0, 6].set(1e4)       # only visible to queries >= 6
        got = flash_attention_pallas(q, k, v, causal=True, bq=2, bk=2,
                                     interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
        assert np.abs(np.asarray(got)[0, 0, :6]).max() < 100

    def test_decode_style_tail_queries(self):
        """Skv > Sq: queries are the LAST sq positions (serving chunk)."""
        q, k, v = _qkv(2, 2, 2, 3, 29, 8, seed=3)
        _check(q, k, v, causal=True, bq=2, bk=8)


# ===========================================================================
class TestGQAGroups:
    @pytest.mark.parametrize("hq,hkv", [(2, 1), (4, 2), (8, 2), (6, 3)])
    def test_group_folding(self, hq, hkv):
        """K/V heads are indexed h // group — never broadcast: every
        query head must read its own group's KV."""
        q, k, v = _qkv(2, hq, hkv, 9, 9, 8, seed=4)
        _check(q, k, v, causal=True, bq=4, bk=4)

    def test_groups_see_distinct_kv(self):
        """Give each KV head a distinct constant V: outputs per query
        head must equal their group's constant (softmax mixes only
        within one head's rows)."""
        b, hq, hkv, s, d = 1, 4, 2, 6, 8
        q, k, _ = _qkv(b, hq, hkv, s, s, d, seed=5)
        v = jnp.stack([jnp.full((s, d), float(h + 1))
                       for h in range(hkv)])[None]
        out = np.asarray(flash_attention_pallas(q, k, v, causal=True,
                                                bq=4, bk=4, interpret=True))
        group = hq // hkv
        for h in range(hq):
            np.testing.assert_allclose(out[0, h], h // group + 1.0,
                                       rtol=1e-6)

    @settings(max_examples=8)
    @given(st.sampled_from([(2, 1), (4, 1), (4, 2), (6, 2)]),
           st.integers(2, 12), st.integers(0, 2 ** 16))
    def test_sweep_gqa_vs_ref(self, heads, sq, seed):
        hq, hkv = heads
        q, k, v = _qkv(2, hq, hkv, sq, sq + 4, 8, seed=seed)
        _check(q, k, v, causal=True, bq=4, bk=4)
