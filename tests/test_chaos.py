"""Chaos conformance: faulted serving runs ≡ fault-free runs, byte for byte.

The engine's recovery loop (pre-block snapshot → detect → restore →
replay) must make every injected fault *observationally invisible*:
for a deterministic fault schedule, the token streams equal the
fault-free run's exactly — the validate-under-perturbation discipline
the training stack already applies (``ft.FaultInjector``), turned on
the serving engine itself.

Also here: snapshot/restore round-trips (in-memory and on-disk via the
checkpoint store's atomics), the no-recovery FAILED path, and the
preempt-and-spill degradation that replaces the seed's MemoryError on
over-committed pools.
"""

import numpy as np
import pytest

from repro.dist.constrain import use_mesh
from repro.ft import (FAULT_KINDS, InjectedFault, PageCorruptionError,
                      ServingFaultInjector)
from repro.launch.lifecycle import RequestStatus
from repro.launch.serve import Engine

from test_paged_serving import _prompts, _serve, _setup

#: one of each fault kind, early enough that every cell's drain hits all
#: four rounds: a step exception, NaN cache poison (device fault lane),
#: finite corruption (delayed integrity report), and a straggler block
FULL_SCHEDULE = {1: "raise", 2: "nan", 3: "corrupt", 4: "slow"}


def _mode_kw(mode, spec):
    kw = {}
    if mode == "paged":
        kw.update(paged=True, page_size=8)
    if spec:
        kw.update(spec=True)
    return kw


# ===========================================================================
class TestChaosConformance:
    """Every (family × cache layout × speculation) cell: streams under
    the full fault schedule equal the fault-free run's."""

    @pytest.mark.parametrize("family,mode,spec", [
        ("lm", "dense", False),
        ("lm", "paged", False),
        ("lm", "paged", True),
        pytest.param("lm", "dense", True, marks=pytest.mark.slow),
        pytest.param("ssm", "dense", False, marks=pytest.mark.slow),
        pytest.param("ssm", "paged", False, marks=pytest.mark.slow),
        pytest.param("ssm", "dense", True, marks=pytest.mark.slow),
        pytest.param("ssm", "paged", True, marks=pytest.mark.slow),
        pytest.param("hybrid", "dense", False, marks=pytest.mark.slow),
        pytest.param("hybrid", "paged", False, marks=pytest.mark.slow),
        pytest.param("hybrid", "dense", True, marks=pytest.mark.slow),
        pytest.param("hybrid", "paged", True, marks=pytest.mark.slow),
    ])
    def test_faulted_run_matches_fault_free(self, family, mode, spec):
        setup = _setup(family, "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3))
        kw = _mode_kw(mode, spec)
        block = 1 if spec else 2          # spec blocks count verify rounds
        clean = _serve(setup, prompts, gen_len=6, block=block, **kw)
        injector = ServingFaultInjector(FULL_SCHEDULE)
        chaos = _serve(setup, prompts, gen_len=6, block=block,
                       fault_injector=injector, **kw)
        assert chaos.done == clean.done
        assert all(r["status"] is RequestStatus.COMPLETED
                   for r in chaos.results.values())
        # raise/nan/corrupt each cost exactly one replay; slow costs none
        assert chaos.counters["replays"] == 3
        assert sorted(k for _, k in injector.events) == sorted(FAULT_KINDS)

    def test_each_kind_alone_is_invisible(self):
        """Per-kind isolation: any single fault recovers on its own."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5), seed=8)
        clean = _serve(setup, prompts, gen_len=6, block=2,
                       paged=True, page_size=8)
        for kind in FAULT_KINDS:
            injector = ServingFaultInjector({2: kind})
            chaos = _serve(setup, prompts, gen_len=6, block=2,
                           paged=True, page_size=8,
                           fault_injector=injector)
            assert chaos.done == clean.done, kind
            assert injector.events == [(2, kind)]

    @pytest.mark.slow
    def test_randomized_seeded_schedules_conform(self):
        """Longer sweep: random (round, kind) schedules, every one must
        still produce the fault-free streams — seeded, so a failure is
        exactly reproducible from the printed seed."""
        setup = _setup("lm", "f32")
        prompts = _prompts(setup[0], (9, 5, 12, 3), seed=9)
        clean = _serve(setup, prompts, gen_len=6, block=2,
                       paged=True, page_size=8)
        for seed in range(6):
            rs = np.random.RandomState(seed)
            sched = [(int(rs.randint(1, 9)),
                      FAULT_KINDS[rs.randint(len(FAULT_KINDS))])
                     for _ in range(rs.randint(2, 5))]
            sched = list({rk: None for rk in sched})     # dedup, keep order
            injector = ServingFaultInjector(sched)
            chaos = _serve(setup, prompts, gen_len=6, block=2,
                           paged=True, page_size=8, fault_injector=injector)
            assert chaos.done == clean.done, f"seed={seed} sched={sched}"

    def test_int8_weights_chaos(self):
        setup = _setup("lm", "int8")
        prompts = _prompts(setup[0], (9, 5), seed=10)
        clean = _serve(setup, prompts, gen_len=6, block=2,
                       paged=True, page_size=8)
        chaos = _serve(setup, prompts, gen_len=6, block=2,
                       paged=True, page_size=8,
                       fault_injector=ServingFaultInjector(FULL_SCHEDULE))
        assert chaos.done == clean.done


# ===========================================================================
class TestSnapshotRestore:
    def test_in_memory_round_trip_replays_identically(self):
        """snapshot → keep decoding → restore → decode again: the two
        futures from the same snapshot are byte-identical, including
        allocator free-list order and block tables."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (9, 5, 12), seed=11)
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                         paged=True, page_size=4, recover=True)
            for p in prompts:
                eng.submit(p, gen_len=6)
            eng.try_admit()
            eng.step_many(2)
            snap = eng.snapshot()

            def run_out():
                while eng.live.any() or eng.waiting:
                    eng.step_many(2)
                eng.retire_finished()
                return (list(eng.done),
                        {k: (v["status"], tuple(v["tokens"]))
                         for k, v in eng.results.items()},
                        eng.allocator.state(), eng.block_tables.copy(),
                        eng.pos.copy(), eng._gen_step)

            first = run_out()
            eng.restore(snap)
            # restore rewinds the observable state to the snapshot
            assert np.array_equal(eng.pos, snap["pos"])
            assert eng.allocator.state() == snap["allocator"]
            assert len(eng.waiting) == len(snap["waiting"])
            second = run_out()
        assert first[0] == second[0]
        assert first[1] == second[1]
        assert first[2] == second[2]
        assert np.array_equal(first[3], second[3])
        assert np.array_equal(first[4], second[4])
        assert first[5] == second[5]

    def test_disk_snapshot_resumes_in_fresh_engine(self, tmp_path):
        """save_snapshot mid-stream, load into a NEW engine built from
        the same constructor args: the continuation equals the original
        engine's — a process restart is invisible to the streams."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (9, 5, 12), seed=12)
        kw = dict(batch=2, max_len=24, paged=True, page_size=4)
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, **kw)
            for p in prompts:
                eng.submit(p, gen_len=6)
            eng.try_admit()
            eng.step_many(2)
            eng.save_snapshot(str(tmp_path), step=7)
            while eng.live.any() or eng.waiting:
                eng.step_many(2)
            eng.retire_finished()

            eng2 = Engine(cfg, ctx, params, mesh, **kw)
            eng2.load_snapshot(str(tmp_path))        # newest = step 7
            while eng2.live.any() or eng2.waiting:
                eng2.step_many(2)
            eng2.retire_finished()
        assert eng2.done == eng.done
        assert {k: v["tokens"] for k, v in eng2.results.items()} \
            == {k: v["tokens"] for k, v in eng.results.items()}
        assert eng2.allocator.used_pages == 0

    def test_load_snapshot_missing_raises(self, tmp_path):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24)
            with pytest.raises(FileNotFoundError):
                eng.load_snapshot(str(tmp_path / "nope"))


# ===========================================================================
class TestNoRecoveryPath:
    def test_device_fault_fails_slots_with_partial_output(self):
        """recover=False: a NaN-poisoned block freezes the affected
        slots on device (commits nothing for the faulted step) and the
        host finishes them FAILED with their valid prefix — no
        exception escapes step_many."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (9, 5), seed=13)
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                         fault_injector=ServingFaultInjector({2: "nan"}),
                         recover=False)
            ids = [eng.submit(p, gen_len=6) for p in prompts]
            eng.try_admit()
            eng.step_many(2)                 # round 1: clean, 2 tokens
            eng.step_many(2)                 # round 2: poisoned
        for rid in ids:
            assert eng.status(rid) is RequestStatus.FAILED
            assert eng.results[rid]["tokens"] != []
            assert len(eng.results[rid]["tokens"]) == 2
        assert eng.counters["failures"] == 2
        assert eng.counters["replays"] == 0

    def test_host_fault_propagates_without_recovery(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                         fault_injector=ServingFaultInjector({1: "raise"}),
                         recover=False)
            eng.submit(_prompts(cfg, (6,))[0], gen_len=4)
            eng.try_admit()
            with pytest.raises(InjectedFault):
                eng.step_many(2)

    def test_corruption_report_propagates_without_recovery(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                         fault_injector=ServingFaultInjector({1: "corrupt"}),
                         recover=False)
            eng.submit(_prompts(cfg, (6,))[0], gen_len=4)
            eng.try_admit()
            with pytest.raises(PageCorruptionError):
                eng.step_many(2)


# ===========================================================================
class TestPreemptAndSpill:
    """Over-committed pools degrade gracefully instead of raising."""

    def test_seed_path_raises_where_preempt_completes(self):
        """The acceptance contrast: direct admission onto an exhausted
        pool raises MemoryError without preemption; with preempt=True
        the same admission spills a victim, serves the newcomer, then
        resumes the victim — and every stream still matches a run on an
        uncontended pool."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (10, 10, 10), seed=14)
        kw = dict(batch=3, max_len=24, paged=True, page_size=4,
                  num_pages=8)        # 4 pages per request: pool fits two
        with use_mesh(mesh):
            seed_eng = Engine(cfg, ctx, params, mesh, **kw)
            seed_eng.add_requests({0: prompts[0], 1: prompts[1]}, gen_len=6)
            with pytest.raises(MemoryError, match="exhausted"):
                seed_eng.add_requests({2: prompts[2]}, gen_len=6)

            eng = Engine(cfg, ctx, params, mesh, preempt=True, **kw)
            eng.add_requests({0: prompts[0], 1: prompts[1]}, gen_len=6)
            eng.add_requests({2: prompts[2]}, gen_len=6)   # spills a victim
            assert eng.counters["preemptions"] == 1
            assert len(eng.waiting) == 1        # the victim, re-queued
            while eng.live.any() or eng.waiting:
                eng.step_many(2)
            eng.retire_finished()

            ample = _serve(setup, prompts, gen_len=6, max_len=24, batch=3,
                           paged=True, page_size=4, num_pages=12, block=2)
        assert sorted(map(tuple, eng.done)) \
            == sorted(map(tuple, ample.done))
        assert all(r["status"] is RequestStatus.COMPLETED
                   for r in eng.results.values())
        assert eng.allocator.used_pages == 0

    @pytest.mark.parametrize("family", [
        "lm",
        pytest.param("ssm", marks=pytest.mark.slow),
        pytest.param("hybrid", marks=pytest.mark.slow),
    ])
    def test_bursty_overcommit_streams_are_byte_identical(self, family):
        """A burst of submits over an under-provisioned pool completes
        through preempt-and-spill with every stream equal to the
        uncontended reference — resumed requests pick up exactly where
        their spilled pages and recurrent lanes left off (no
        recompute)."""
        setup = _setup(family, "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (10, 10, 10, 10), seed=15)
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=3, max_len=24,
                         paged=True, page_size=4, num_pages=8,
                         preempt=True, preempt_after=2)
            ids = [eng.submit(p, gen_len=6) for p in prompts]
            eng.try_admit()
            seen = set()
            while eng.live.any() or eng.waiting:
                eng.step_many(2)
                seen.update(eng.status(i) for i in ids)
            eng.retire_finished()
        assert eng.counters["preemptions"] > 0
        assert eng.counters["spilled_pages"] > 0
        assert RequestStatus.PREEMPTED in seen       # observable mid-run
        assert all(eng.status(i) is RequestStatus.COMPLETED for i in ids)

        # _serve submits in the same order, so ids mint identically
        reference = _serve(setup, prompts, gen_len=6, max_len=24, batch=3,
                           paged=True, page_size=4, num_pages=16, block=2)
        for rid in ids:
            assert eng.results[rid]["tokens"] \
                == reference.results[rid]["tokens"]

    def test_preempt_requires_paged(self):
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        with use_mesh(mesh):
            with pytest.raises(ValueError, match="paged"):
                Engine(cfg, ctx, params, mesh, batch=2, max_len=24,
                       preempt=True)

    def test_preempt_under_chaos_still_conforms(self):
        """Preemption and fault recovery compose: spills + replays in
        the same run, streams still byte-identical to the uncontended
        fault-free reference."""
        setup = _setup("lm", "f32")
        cfg, ctx, params, mesh = setup
        prompts = _prompts(cfg, (10, 10, 10, 10), seed=16)
        with use_mesh(mesh):
            eng = Engine(cfg, ctx, params, mesh, batch=3, max_len=24,
                         paged=True, page_size=4, num_pages=8,
                         preempt=True, preempt_after=2,
                         fault_injector=ServingFaultInjector(
                             {2: "raise", 3: "nan"}))
            ids = [eng.submit(p, gen_len=6) for p in prompts]
            eng.try_admit()
            while eng.live.any() or eng.waiting:
                eng.step_many(2)
            eng.retire_finished()
        assert eng.counters["replays"] == 2
        assert eng.counters["preemptions"] > 0
        reference = _serve(setup, prompts, gen_len=6, max_len=24, batch=3,
                           paged=True, page_size=4, num_pages=16, block=2)
        for rid in ids:
            assert eng.results[rid]["tokens"] \
                == reference.results[rid]["tokens"]
